"""Paper Fig. 2: number of test samples vs false-positive kernels.

The paper seeds a pool of SIP-optimized kernels, some subtly broken, and
shows the count passing all tests stabilizes once ~5000 samples are used.
We reproduce the mechanism with seeded fault injection: a population of
"optimized kernels" where a fraction carry a data-dependent fault that only
fires on rare inputs (max|x| above a threshold), then sweep the sample
budget.  Expected: pass-count decreases with samples, then plateaus at the
number of genuinely correct kernels.
"""

from __future__ import annotations

import numpy as np

from repro.core.testing import FaultInjector, InputSpec, probabilistic_test

N_KERNELS = 24
N_FAULTY = 6
SAMPLE_BUDGETS = (1, 5, 20, 100, 500, 2000)


def make_population(seed: int = 0):
    """(kernels, oracle).  Faulty kernels use increasing thresholds — some
    easy to catch, some needing thousands of samples."""
    oracle = lambda x: np.asarray(x) * 2.0 + 1.0
    kernels = []
    thresholds = np.linspace(2.2, 4.2, N_FAULTY)   # rarer and rarer faults
    for i in range(N_KERNELS):
        if i < N_FAULTY:
            kernels.append(FaultInjector(oracle, threshold=float(thresholds[i]),
                                         corruption=0.1))
        else:
            kernels.append(oracle)
    return kernels, oracle


def run(full: bool = True):
    budgets = SAMPLE_BUDGETS if full else SAMPLE_BUDGETS[:4]
    kernels, oracle = make_population()
    spec = [InputSpec((16,))]
    rows = []
    for budget in budgets:
        rng = np.random.default_rng(123)
        passing = sum(
            probabilistic_test(k, oracle, spec, budget, rng,
                               rtol=1e-3, atol=1e-3).passed
            for k in kernels)
        rows.append((f"fig2/pass_at_{budget}_samples", float(passing),
                     f"{passing}/{N_KERNELS} kernels pass "
                     f"({N_KERNELS - N_FAULTY} genuinely correct)"))
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.0f},{derived}")
