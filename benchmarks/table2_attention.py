"""Paper Table 2: fused attention (flash-attention), SIP vs baseline.

Paper setting: A100 fp16, input [1, 4, 16384, 64] (batch, heads, seq, hd);
SIP reduced kernel duration 6.2% (1.37ms -> 1.29ms) by reordering global
memory instructions.  Here: the Pallas flash-attention body's instruction
stream is annealed under the TPU cost model at the paper's exact shape; the
discovered schedule is the classic V-prefetch/software-pipeline reorder
(printed as a before/after listing diff, cf. paper Listings 4 vs 5).
"""

from __future__ import annotations

import numpy as np

from repro.core import annealing, energy as energy_mod
from repro.core.mutation import MutationPolicy
from repro.core.schedule import Schedule
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref

PAPER_SHAPE = dict(b=1, hq=4, hkv=4, sq=16384, skv=16384, d=64, causal=False,
                   window=None, dtype="bfloat16")
PAPER_IMPROVEMENT = 0.062           # Table 2: 1.37ms -> 1.29ms


def _anneal(knob_prob: float = 0.0, seed: int = 0, cooling: float = 1.01):
    static = dict(PAPER_SHAPE)
    space = fa_ops.space(**static)
    program_for = lambda s: fa_ops.program_for(s, **static)
    energy = energy_mod.CostModelEnergy(program_for)
    policy = MutationPolicy(space=space, program_for=program_for,
                            knob_prob=knob_prob)
    knobs = space.default_knobs()
    knobs["n_chunks"] = 4            # expose per-chunk loads to the search
    x0 = Schedule(knobs=knobs)
    return annealing.anneal(x0, energy, policy.propose, t_max=1.0,
                            t_min=5e-3, cooling=cooling, seed=seed), program_for


def run(full: bool = True):
    rows = []
    res, program_for = _anneal(cooling=1.01 if full else 1.1)
    rows.append(("table2/attention_baseline_us", res.initial_raw * 1e6,
                 "whole-kernel cost-model latency, default schedule"))
    rows.append(("table2/attention_sip_us", res.best_raw * 1e6,
                 f"improvement={res.improvement:.2%} "
                 f"(paper: {PAPER_IMPROVEMENT:.2%}), evals={res.evals}"))

    # correctness of the tuned schedule on an executable (reduced) shape
    static = dict(PAPER_SHAPE, sq=256, skv=256, dtype="float32")
    sched = Schedule(knobs=dict(res.best.knobs))
    prog_small = fa_ops.program_for(sched, **static)
    order = res.best.order
    if order is not None and len(order) == len(prog_small):
        sched = sched.with_order(order)
    fn = fa_ops.build(sched, **static)
    rng = np.random.default_rng(0)
    q = rng.standard_normal((1, 4, 256, 64)).astype(np.float32)
    k = rng.standard_normal((1, 4, 256, 64)).astype(np.float32)
    v = rng.standard_normal((1, 4, 256, 64)).astype(np.float32)
    err = float(np.max(np.abs(np.asarray(fn(q, k, v)) -
                              np.asarray(fa_ref.attention(q, k, v,
                                                          causal=False)))))
    rows.append(("table2/attention_tuned_maxerr", err * 1e6,
                 "tuned-schedule output vs oracle (x1e-6; shape 1,4,256,64)"))
    return rows


def listing_diff() -> str:
    """Before/after schedule listing (paper Listings 4 vs 5 analogue)."""
    res, program_for = _anneal(cooling=1.05)
    prog = program_for(res.best)
    base = prog.listing()
    tuned = prog.listing(res.best.order)
    return ("=== baseline (compiler-like) ===\n" + base +
            "\n=== SIP-optimized ===\n" + tuned)


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
    print(listing_diff())
