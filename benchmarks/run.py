"""Benchmark harness — one module per paper table/figure + system benches.

Prints ``name,value,derived`` CSV rows.  ``--quick`` shrinks anneal budgets
for CI-speed runs; the default reproduces the full budgets used in
EXPERIMENTS.md."""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: table2,table3,fig2,roofline,throughput,"
                         "guided,search,serve,train_ckpt")
    args = ap.parse_args()
    full = not args.quick

    from benchmarks import (fig2_testing, guided_search, roofline,
                            search_throughput, serve_throughput,
                            table2_attention, table3_gemm, throughput,
                            train_ckpt)
    suites = {
        "table2": table2_attention.run,
        "table3": table3_gemm.run,
        "fig2": fig2_testing.run,
        "roofline": roofline.run,
        "throughput": throughput.run,
        "guided": guided_search.run,
        "search": search_throughput.run,
        "serve": serve_throughput.run,
        "train_ckpt": train_ckpt.run,
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    print("name,value,derived")
    failed = False
    for name, fn in suites.items():
        if name not in only:
            continue
        try:
            for row in fn(full=full):
                n, v, derived = row
                print(f"{n},{v},{derived}")
        except Exception as e:
            failed = True
            print(f"{name}/ERROR,nan,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
