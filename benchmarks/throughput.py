"""Host-measurable throughput microbenchmarks (CPU; relative numbers).

These time the REAL jitted production steps on a reduced config — useful for
regression tracking and for validating that the SIP-tuned schedule cache
introduces zero steady-state dispatch overhead (paper §4.1's deployment
claim)."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro import configs
from repro.data.pipeline import DataConfig, batch_for_model
from repro.launch import steps
from repro.models import modules as nn
from repro.models import model as M
from repro.optim import adamw
from repro.serve.engine import Engine, ServeConfig


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(full: bool = True):
    rows = []
    cfg = configs.get_smoke("qwen3-1.7b")
    dcfg = DataConfig(global_batch=4, seq_len=64, vocab=cfg.vocab)
    params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
    opt = adamw.init_opt_state(params)
    batch = batch_for_model(cfg, dcfg, 0)
    jfn = jax.jit(lambda p, o, b: steps.train_step(
        p, o, b, cfg=cfg, opt_cfg=adamw.OptConfig()))
    dt = _time(jfn, params, opt, batch)
    toks = dcfg.global_batch * dcfg.seq_len
    rows.append(("throughput/train_step_us", dt * 1e6,
                 f"{toks / dt:.0f} tokens/s (smoke cfg, CPU)"))

    eng = Engine(params, cfg, ServeConfig(max_len=96))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (4, 32)).astype(np.int32)
    eng.generate(prompts, max_new_tokens=4)          # warmup/compile
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new_tokens=16)
    dt = time.perf_counter() - t0
    rows.append(("throughput/decode_us_per_token", dt / out.size * 1e6,
                 f"{out.size / dt:.0f} tokens/s decode (smoke cfg, CPU)"))

    # paper §4.1: deployment via the schedule cache adds no per-call overhead
    from repro.kernels.gemm_fused import ops as gemm_ops
    x = np.random.default_rng(0).standard_normal((64, 64)).astype(np.float32)
    w = np.random.default_rng(1).standard_normal((64, 64)).astype(np.float32)
    gemm_ops.gemm_leaky_relu(x, w)                   # build+cache
    t_cached = _time(gemm_ops.gemm_leaky_relu, x, w, iters=20)
    fn = gemm_ops.build(gemm_ops.gemm_leaky_relu.schedule_for(
        gemm_ops.gemm_leaky_relu.static_of(x, w)), m=64, n=64, k=64)
    t_direct = _time(fn, x, w, iters=20)
    rows.append(("throughput/sip_cache_overhead_us",
                 (t_cached - t_direct) * 1e6,
                 "cached-schedule dispatch vs direct call (≈0 = paper §4.1)"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
