"""Roofline report: reads the dry-run results JSON and emits the per-cell
three-term table (compute / memory / collective seconds, dominant term,
useful-FLOPs ratio) that EXPERIMENTS.md §Roofline embeds."""

from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
HEADER = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
          "dominant", "useful_flops", "mem_GiB/dev")


def load(path: str = RESULTS) -> dict:
    with open(path) as f:
        return json.load(f)


def rows(results: dict, mesh: str | None = None):
    out = []
    for key in sorted(results):
        r = results[key]
        if r.get("status") != "ok":
            continue
        if mesh and r["mesh"] != mesh:
            continue
        t = r["roofline"]
        mem = r.get("memory_per_device_bytes", 0) / 2 ** 30
        uf = r.get("useful_flops_ratio")
        out.append((r["arch"], r["shape"], r["mesh"],
                    f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
                    f"{t['collective_s']:.4f}", t["dominant"].replace("_s", ""),
                    f"{uf:.3f}" if uf else "-", f"{mem:.2f}"))
    return out


def markdown_table(results: dict, mesh: str = "single") -> str:
    lines = ["| " + " | ".join(HEADER) + " |",
             "|" + "|".join(["---"] * len(HEADER)) + "|"]
    for row in rows(results, mesh):
        lines.append("| " + " | ".join(row) + " |")
    skips = [f"| {r['arch']} | {r['shape']} | - | skipped: {r['skip_reason'][:60]}... |"
             for r in results.values()
             if r.get("status") == "skipped" and r["mesh"] == mesh]
    return "\n".join(lines + skips)


def run(full: bool = True):
    if not os.path.exists(RESULTS):
        return [("roofline/cells_ok", 0.0, "dryrun_results.json missing — "
                 "run python -m repro.launch.dryrun --all --mesh both")]
    results = load()
    ok = [r for r in results.values() if r.get("status") == "ok"]
    skipped = [r for r in results.values() if r.get("status") == "skipped"]
    errors = [r for r in results.values() if r.get("status") == "error"]
    out = [("roofline/cells_ok", float(len(ok)),
            f"skipped={len(skipped)} errors={len(errors)}")]
    for dom in ("compute_s", "memory_s", "collective_s"):
        n = sum(1 for r in ok if r["roofline"]["dominant"] == dom)
        out.append((f"roofline/dominated_by_{dom.replace('_s', '')}",
                    float(n), f"of {len(ok)} compiled cells"))
    if ok:
        worst = min((r for r in ok if r.get("useful_flops_ratio")),
                    key=lambda r: r["useful_flops_ratio"])
        out.append(("roofline/worst_useful_flops",
                    worst["useful_flops_ratio"],
                    f"{worst['arch']}x{worst['shape']}x{worst['mesh']}"))
    return out


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v},{derived}")
    if os.path.exists(RESULTS):
        print(markdown_table(load()))
