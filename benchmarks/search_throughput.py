"""Search throughput: sequential SIP vs population(4 chains)+memoization.

The tuning hot path before this benchmark existed re-built the kernel IR on
every proposal AND every energy evaluation, re-simulated revisited schedules,
and ran 4 independent sequential restarts.  The batched engine shares one
memoized ``program_for``, one :class:`~repro.core.energy.CachedEnergy`, and
runs 4 lockstep chains on a temperature ladder with best-state exchange
(:func:`~repro.core.population.population_anneal`).

Measured per workload (gemm + attention, costmodel backend):

* ``evals/sec`` — energy queries per wall-clock second (cache hits count as
  queries: a hit answers the same question a full evaluation would) plus
  ``real_evals_per_sec`` (hits excluded), so a rising hit rate cannot
  masquerade as real-throughput gains across PRs;
* cache hit rate and best normalized energy for both engines;
* a single-chain equivalence check — ``population_anneal(chains=1)`` must
  reproduce ``anneal()`` bit-for-bit under the same seed.

``python benchmarks/search_throughput.py`` writes ``BENCH_search.json`` so
the perf trajectory is tracked across PRs; ``--smoke`` shrinks shapes and
budgets for CI.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.core import (CachedEnergy, CostModelEnergy, MutationPolicy,
                        Schedule, anneal, multi_round, population_anneal)

CHAINS = 4
ROUNDS = 4          # sequential baseline: the legacy multi_round restarts


def _workloads(full: bool):
    from repro.kernels.flash_attention import ops as attn_ops
    from repro.kernels.gemm_fused import ops as gemm_ops
    gemm = dict(m=256, n=256, k=1024, dtype="float32") if full else \
        dict(m=32, n=32, k=64, dtype="float32")
    attn = dict(b=1, hq=4, hkv=2, sq=128, skv=128, d=32, causal=True,
                window=None, dtype="float32") if full else \
        dict(b=1, hq=2, hkv=1, sq=32, skv=32, d=16, causal=True,
             window=None, dtype="float32")
    return {"gemm": (gemm_ops, gemm), "attention": (attn_ops, attn)}


def _memoized(program_for):
    programs = {}

    def memo(s: Schedule):
        key = s.knob_signature()
        prog = programs.get(key)
        if prog is None:
            prog = programs[key] = program_for(s)
        return prog

    return memo


def bench_workload(ops, shape: dict, *, cooling: float, t_min: float,
                   seed: int = 0) -> dict:
    space = ops.space(**shape)
    x0 = Schedule(knobs=space.default_knobs())
    plain = lambda s: ops.program_for(s, **shape)
    kw = dict(t_max=1.0, t_min=t_min, cooling=cooling, seed=seed)

    # --- sequential baseline: the pre-population tuning hot path ---------
    policy = MutationPolicy(space=space, program_for=plain)
    t0 = time.perf_counter()
    seq = multi_round(x0, CostModelEnergy(plain), policy.propose,
                      rounds=ROUNDS, **kw)
    t_seq = time.perf_counter() - t0
    q_seq = sum(r.evals for r in seq)

    # --- population + memoization: the batched engine --------------------
    memo_pf = _memoized(plain)
    policy = MutationPolicy(space=space, program_for=memo_pf)
    cached = CachedEnergy(CostModelEnergy(memo_pf))
    t0 = time.perf_counter()
    pop = population_anneal(x0, cached, policy.propose, chains=CHAINS,
                            exchange_every=16, ladder=1.5, **kw)
    t_pop = time.perf_counter() - t0
    stats = pop.cache_stats or {"hits": 0, "misses": 1}

    # --- single-chain equivalence: population(1) == anneal() -------------
    ref = anneal(x0, CostModelEnergy(plain), policy.propose, **kw)
    one = population_anneal(x0, CachedEnergy(CostModelEnergy(memo_pf)),
                            policy.propose, chains=1, **kw)
    identical = (ref.best == one.best
                 and ref.best_energy == one.chains[0].best_energy
                 and ref.evals == one.chains[0].evals)

    seq_eps = q_seq / t_seq
    pop_eps = pop.evals / t_pop
    return {
        "sequential": {"evals": q_seq, "secs": round(t_seq, 4),
                       "evals_per_sec": round(seq_eps, 1),
                       "best_energy": min(r.best_energy for r in seq)},
        "population": {"evals": pop.evals, "secs": round(t_pop, 4),
                       "evals_per_sec": round(pop_eps, 1),
                       "real_evals_per_sec": round(stats["misses"] / t_pop, 1),
                       "best_energy": pop.best_energy,
                       "cache_hits": stats["hits"],
                       "cache_misses": stats["misses"],
                       "hit_rate": round(stats["hits"]
                                         / max(1, stats["hits"] + stats["misses"]), 4),
                       "exchanges": pop.exchanges},
        "speedup_evals_per_sec": round(pop_eps / seq_eps, 2),
        "speedup_real_evals_per_sec": round((stats["misses"] / t_pop)
                                            / seq_eps, 2),
        "single_chain_identical": bool(identical),
    }


def bench(full: bool = True) -> dict:
    cooling, t_min = (1.02, 1e-3) if full else (1.2, 0.05)
    out = {"config": {"chains": CHAINS, "rounds": ROUNDS, "cooling": cooling,
                      "t_min": t_min, "exchange_every": 16, "ladder": 1.5,
                      "mode": "full" if full else "smoke"},
           "workloads": {}}
    for name, (ops, shape) in _workloads(full).items():
        out["workloads"][name] = bench_workload(ops, shape,
                                                cooling=cooling, t_min=t_min)
    return out


def run(full: bool = True):
    """benchmarks.run harness entry — CSV rows."""
    res = bench(full)
    rows = []
    for name, w in res["workloads"].items():
        rows.append((f"search/{name}_speedup_evals_per_sec",
                     w["speedup_evals_per_sec"],
                     f"seq={w['sequential']['evals_per_sec']}/s "
                     f"pop={w['population']['evals_per_sec']}/s "
                     f"real_speedup={w['speedup_real_evals_per_sec']}x "
                     f"hit_rate={w['population']['hit_rate']:.0%} "
                     f"single_chain_identical={w['single_chain_identical']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + short anneal budgets (CI)")
    ap.add_argument("--out", default="BENCH_search.json")
    args = ap.parse_args()
    res = bench(full=not args.smoke)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
        f.write("\n")
    for name, w in res["workloads"].items():
        print(f"{name}: {w['speedup_evals_per_sec']}x evals/sec "
              f"(seq {w['sequential']['evals_per_sec']}/s -> "
              f"pop {w['population']['evals_per_sec']}/s), "
              f"hit_rate={w['population']['hit_rate']:.0%}, "
              f"single_chain_identical={w['single_chain_identical']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
