"""Checkpoint overhead: overlapped async saves vs blocking saves.

Times the SAME jitted train loop (donated buffers, fixed batches) three
ways: no checkpointing, blocking ``save``, and overlapped async ``save``
(device-side snapshot + background host write).

The headline metric is the reduction of the *step-time penalty* — the
seconds the train loop is stalled inside ``save()`` per pass.  A blocking
save stalls for the full device_get + hash + serialize + write; the async
path stalls only for join + snapshot + transfer start:

    hidden = 1 - blocked_async / blocked_blocking         (target >= 0.5)

``overlap_wall`` is the end-to-end view (how much of the blocking wall-time
penalty disappears).  On a multi-core host the two agree; on a single-core
host (this CI container: XLA compute and the writer thread share one core)
wall time cannot improve no matter when the hashing runs, so
``host_cores`` is recorded alongside and the wall number is reported but
not gated.  On a real accelerator deployment the device keeps computing
while the host writes — the call-site stall is the penalty that remains.

``python benchmarks/train_ckpt.py`` writes ``BENCH_train.json``;
``--smoke`` shrinks the model for CI.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import tempfile
import time

import numpy as np

REPS = 3        # timed repetitions; best-of-N suppresses machine noise
STEPS = 16
EVERY = 4       # checkpoint cadence (4 saves per timed pass)


def _setup(full: bool):
    import jax
    import jax.numpy as jnp
    from repro.launch import steps
    from repro.models.config import ModelConfig
    from repro.optim import adamw
    from repro.train.loop import make_train_state

    cfg = ModelConfig(
        name="ckpt-bench", family="dense", vocab=1024, dtype="float32",
        **(dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=512)
           if full else
           dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)),
    ).validate()
    ocfg = adamw.OptConfig(peak_lr=1e-3, warmup_steps=4, decay_steps=1000)
    jfn = jax.jit(functools.partial(steps.train_step, cfg=cfg, opt_cfg=ocfg),
                  donate_argnums=(0, 1))
    rng = np.random.default_rng(0)
    b, s = (8, 64) if full else (2, 16)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    state = lambda: make_train_state(cfg, None, 0)   # fresh per pass: the
    #                                                  loop donates buffers
    return jfn, batch, state


def _pass(jfn, batch, state, ckpt_dir=None, blocking=True):
    """One timed pass of STEPS steps; returns (wall_s, caller_blocked_s)."""
    import jax
    from repro.checkpoint.ckpt import CheckpointManager

    params, opt = state()
    mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    blocked = 0.0
    t0 = time.perf_counter()
    for i in range(STEPS):
        params, opt, metrics = jfn(params, opt, batch)
        if mgr is not None and (i + 1) % EVERY == 0:
            blocked += mgr.save(i + 1, {"params": params, "opt": opt},
                                blocking=blocking)
    jax.block_until_ready(metrics)
    if mgr is not None:
        mgr.wait()                   # in-flight write counts against async
    return time.perf_counter() - t0, blocked


def _best(fn, reps=REPS):
    walls, blocks = [], []
    for _ in range(reps):
        w, b = fn()
        walls.append(w)
        blocks.append(b)
    i = int(np.argmin(walls))
    return walls[i], blocks[i]


def bench(full: bool = True) -> dict:
    jfn, batch, state = _setup(full)
    _pass(jfn, batch, state)                        # warm the jit cache
    with tempfile.TemporaryDirectory() as db, \
            tempfile.TemporaryDirectory() as da:
        wall_off, _ = _best(lambda: _pass(jfn, batch, state))
        wall_blk, blocked_blk = _best(
            lambda: _pass(jfn, batch, state, ckpt_dir=db, blocking=True))
        wall_async, blocked_async = _best(
            lambda: _pass(jfn, batch, state, ckpt_dir=da, blocking=False))
    penalty_blk = max(wall_blk - wall_off, 1e-9)
    penalty_async = wall_async - wall_off
    return {
        "config": {"mode": "full" if full else "smoke", "steps": STEPS,
                   "ckpt_every": EVERY, "saves_per_pass": STEPS // EVERY,
                   "reps": REPS, "host_cores": os.cpu_count()},
        "no_ckpt": {"wall_s": round(wall_off, 4)},
        "blocking": {"wall_s": round(wall_blk, 4),
                     "penalty_s": round(penalty_blk, 4),
                     "caller_blocked_s": round(blocked_blk, 4)},
        "async": {"wall_s": round(wall_async, 4),
                  "penalty_s": round(penalty_async, 4),
                  "caller_blocked_s": round(blocked_async, 4)},
        "hidden": round(1.0 - blocked_async / max(blocked_blk, 1e-9), 3),
        "overlap_wall": round(1.0 - penalty_async / penalty_blk, 3),
    }


def run(full: bool = True):
    """benchmarks.run harness entry — CSV rows."""
    res = bench(full)
    if res["hidden"] < 0.5:
        raise AssertionError(
            f"async checkpointing hides only {res['hidden']:.0%} of the "
            f"save-stall step-time penalty (target >= 50%): {res}")
    return [("train/ckpt_stall_hidden", res["hidden"],
             f"blocked_blocking={res['blocking']['caller_blocked_s']}s "
             f"blocked_async={res['async']['caller_blocked_s']}s "
             f"overlap_wall={res['overlap_wall']:.0%} "
             f"cores={res['config']['host_cores']}")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny model (CI)")
    ap.add_argument("--out", default="BENCH_train.json")
    args = ap.parse_args()
    res = bench(full=not args.smoke)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"save stall per pass: blocking "
          f"{res['blocking']['caller_blocked_s']}s vs async "
          f"{res['async']['caller_blocked_s']}s -> {res['hidden']:.0%} "
          f"hidden; wall overlap {res['overlap_wall']:.0%} "
          f"({res['config']['host_cores']} host core(s))")
    print(f"wrote {args.out}")
    if res["hidden"] < 0.5:
        raise SystemExit("async stall-hiding target (>=50%) NOT met")


if __name__ == "__main__":
    main()
