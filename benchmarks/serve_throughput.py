"""Serving throughput: continuous batching vs the static-batch baseline.

Mixed-length traffic (uniform prompt lengths and decode budgets) is where
continuous batching earns its keep: the static engine pads every prompt in a
batch to the longest and decodes the whole batch to the largest token budget,
so short requests burn slots as padding; the continuous engine refills each
slot the moment its request finishes.  Both engines run the SAME request
stream at the SAME slot capacity, timed after a warmup pass so jit compiles
are excluded (steady-state serving, the regime the ROADMAP north-star cares
about).

Also re-verifies the engine's correctness contract per run: greedy outputs
must be token-identical to single-request ``Engine.generate`` for every
request across 3 arrival orderings (submit order, reversed, shuffled) — for
the contiguous engine AND the paged one (prefix sharing + chunked prefill
on), which is the differential gate the paged KV cache lands behind.

Two paged-specific sections:

* ``capacity_at_equal_memory`` — the page pool gets exactly the contiguous
  allocation's token memory but twice the slots; page-granular reservations
  (a request holds ceil((plen+new)/page) pages, not a max_len segment) must
  sustain strictly more concurrent requests on the same bytes.
* ``ttft_mixed`` — two long prompts ahead of a burst of short ones; chunked
  prefill must keep the shorts' TTFT p99 no worse than the contiguous
  engine, whose monolithic long prefills stall the admission step.

A ``mesh`` axis reports tensor-parallel serving throughput (contiguous and
paged) at each of ``MESH_SHAPES`` device counts — each shape runs in a
subprocess with ``--xla_force_host_platform_device_count`` because this
process's jax is already initialized single-device.

``python benchmarks/serve_throughput.py`` writes ``BENCH_serve.json``;
``--smoke`` shrinks the model and stream for CI.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

CAPACITY = 8


def _model(full: bool):
    import jax
    from repro.models import model as M
    from repro.models import modules as nn
    from repro.models.config import ModelConfig
    cfg = ModelConfig(
        name="serve-bench", family="dense", vocab=1024, dtype="float32",
        **(dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=512)
           if full else
           dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)),
    ).validate()
    params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
    return params, cfg


def _traffic(full: bool, rng: np.random.Generator, vocab: int):
    n = 32 if full else 10
    # bucketed prompt lengths: realistic mixed traffic, bounded prefill
    # retraces for both engines; decode budgets spread wide — the straggler
    # effect static batching pays for
    lens = (8, 16, 24, 32) if full else (4, 8, 12)
    new_lo, new_hi = (8, 48) if full else (3, 8)
    prompts = [rng.integers(0, vocab, int(rng.choice(lens))).astype(np.int32)
               for _ in range(n)]
    budgets = [int(rng.integers(new_lo, new_hi + 1)) for _ in range(n)]
    return prompts, budgets


REPS = 3        # timed repetitions; best-of-N suppresses machine noise


def _run_continuous(params, cfg, scfg, prompts, budgets, mesh=None):
    from repro.serve.engine import ContinuousEngine
    eng = ContinuousEngine(params, cfg, scfg, mesh=mesh)
    wall = float("inf")
    for rep in range(1 + REPS):             # pass 0 warms jit caches
        for p, n in zip(prompts, budgets):
            eng.submit(p, n)
        t0 = time.perf_counter()
        eng.run(max_steps=100_000)
        if rep == 0:
            eng.reset_stats()   # metrics describe the timed (warm) passes
        else:
            wall = min(wall, time.perf_counter() - t0)
    toks = sum(budgets)
    m = eng.metrics()
    return {"wall_s": round(wall, 3), "useful_tokens": toks,
            "tokens_per_s": round(toks / wall, 1),
            "mean_occupancy": round(m["mean_occupancy"], 2),
            "prefill_frac": round(m["prefill_frac"], 3),
            "prefill_compiles": eng.stats["prefill_compiles"]}


def _run_static(params, cfg, scfg, prompts, budgets):
    from repro.serve.engine import Engine, static_batches
    eng = Engine(params, cfg, scfg)
    wall = float("inf")
    for rep in range(1 + REPS):             # pass 0 warms jit caches
        t0 = time.perf_counter()
        decoded = 0
        for padded, new, idxs in static_batches(prompts, budgets,
                                                scfg.capacity):
            decoded += new * len(idxs)
            eng.generate(padded, new)
        if rep > 0:
            wall = min(wall, time.perf_counter() - t0)
    toks = sum(budgets)
    return {"wall_s": round(wall, 3), "useful_tokens": toks,
            "decoded_tokens": decoded,
            "tokens_per_s": round(toks / wall, 1),
            "decode_waste": round(1 - toks / decoded, 3)}


PAGE = 16


def _paged_scfg(scfg, capacity=None, num_pages=None):
    import dataclasses
    from repro.serve.engine import ServeConfig  # noqa: F401 (doc anchor)
    return dataclasses.replace(
        scfg, paged=True, page_size=PAGE, prefill_chunk=PAGE,
        capacity=capacity if capacity is not None else scfg.capacity,
        num_pages=num_pages)


def _run_paged(params, cfg, scfg, prompts, budgets, mesh=None):
    from repro.serve.engine import ContinuousEngine
    eng = ContinuousEngine(params, cfg, _paged_scfg(scfg), mesh=mesh)
    wall = float("inf")
    for rep in range(1 + REPS):             # pass 0 warms jit caches
        for p, n in zip(prompts, budgets):
            eng.submit(p, n)
        t0 = time.perf_counter()
        eng.run(max_steps=100_000)
        if rep == 0:
            eng.reset_stats()   # metrics describe the timed (warm) passes
        else:
            wall = min(wall, time.perf_counter() - t0)
    toks = sum(budgets)
    m = eng.metrics()
    return {"wall_s": round(wall, 3), "useful_tokens": toks,
            "tokens_per_s": round(toks / wall, 1),
            "mean_occupancy": round(m["mean_occupancy"], 2),
            "prefill_compiles": eng.stats["prefill_compiles"],
            "prefix_hits": int(m["prefix_hits"]),
            "prefix_tokens_saved": int(m["prefix_tokens_saved"]),
            "chunk_steps": int(m["chunk_steps"]),
            "page_size": PAGE}


def _drive_peak(eng, prompts, budgets):
    """Submit everything, step to drain; returns (wall_s, peak and mean
    concurrent requests) — the steady-state capacity measure."""
    for p, n in zip(prompts, budgets):
        eng.submit(p, n)
    peak, occ_sum, steps = 0, 0, 0
    t0 = time.perf_counter()
    while not eng.pool.idle:
        eng.step()
        peak = max(peak, eng.pool.occupancy)
        occ_sum += eng.pool.occupancy
        steps += 1
    return time.perf_counter() - t0, peak, occ_sum / max(steps, 1)


def _capacity_at_equal_memory(params, cfg, scfg, prompts, budgets) -> dict:
    """Same KV bytes, page-granular bookkeeping: the paged pool holds
    exactly the contiguous engine's capacity*max_len token memory (plus the
    one trash page) but twice the slots — page-rounded per-request
    reservations are what let extra requests fit."""
    from repro.serve.engine import ContinuousEngine
    token_mem = scfg.capacity * (-(-scfg.max_len // PAGE)) * PAGE
    pscfg = _paged_scfg(scfg, capacity=2 * scfg.capacity,
                        num_pages=token_mem // PAGE + 1)
    out = {}
    for name, sc in (("contiguous", scfg), ("paged", pscfg)):
        eng = ContinuousEngine(params, cfg, sc)
        _drive_peak(eng, prompts, budgets)          # warm the jit caches
        wall, peak, mean = float("inf"), 0, 0.0
        for _ in range(REPS):
            w, p, m = _drive_peak(eng, prompts, budgets)
            if w < wall:
                wall, peak, mean = w, p, m
        out[name] = {"wall_s": round(wall, 3), "slots": sc.capacity,
                     "kv_token_memory": token_mem,
                     "peak_concurrency": peak,
                     "mean_concurrency": round(mean, 2),
                     "tokens_per_s": round(sum(budgets) / wall, 1)}
    out["paged_higher_capacity"] = (
        out["paged"]["peak_concurrency"]
        > out["contiguous"]["peak_concurrency"])
    return out


def _ttft_mixed(params, cfg, scfg, full: bool) -> dict:
    """Two long prompts submitted ahead of a short burst: the shorts' TTFT
    p99 gates the chunked-prefill claim (no worse than contiguous, whose
    long prefills run monolithically inside the admission step)."""
    from repro.serve.engine import ContinuousEngine
    rng = np.random.default_rng(11)
    long_len = scfg.max_len - (16 if full else 8)
    n_short = 24 if full else 8
    longs = [(rng.integers(0, cfg.vocab, long_len).astype(np.int32), 8)
             for _ in range(2)]
    shorts = [(rng.integers(0, cfg.vocab, 8).astype(np.int32), 4)
              for _ in range(n_short)]
    out = {}
    for name, sc in (("contiguous", scfg), ("paged", _paged_scfg(scfg))):
        best = None
        for rep in range(1 + REPS):         # pass 0 warms jit caches
            eng = ContinuousEngine(params, cfg, sc)
            hl = [eng.submit(p, n) for p, n in longs]
            hs = [eng.submit(p, n) for p, n in shorts]
            eng.run(max_steps=100_000)
            if rep == 0:
                continue
            ttft = sorted(r.admitted_at - r.submitted_at for r in hs)
            p99 = float(np.percentile(ttft, 99))
            overall = float(np.percentile(
                [r.admitted_at - r.submitted_at for r in hl + hs], 99))
            if best is None or p99 < best["short_ttft_p99_ms"] / 1e3:
                best = {"short_ttft_p99_ms": round(p99 * 1e3, 1),
                        "all_ttft_p99_ms": round(overall * 1e3, 1)}
        out[name] = best
    # 10% head-room absorbs scheduler noise on a shared machine
    out["paged_no_worse"] = (out["paged"]["short_ttft_p99_ms"]
                             <= 1.10 * out["contiguous"]["short_ttft_p99_ms"])
    return out


MESH_SHAPES = (2, 4)   # tensor-parallel widths benchmarked per run


def _mesh_args(full: bool):
    from repro.serve.engine import ServeConfig
    params, cfg = _model(full)
    rng = np.random.default_rng(0)
    prompts, budgets = _traffic(full, rng, cfg.vocab)
    scfg = ServeConfig(max_len=max(len(p) for p in prompts) + max(budgets),
                       capacity=CAPACITY if full else 4)
    return params, cfg, scfg, prompts, budgets


def _mesh_one(full: bool, n: int) -> dict:
    """Subprocess entry: the bench stream served tensor-parallel over an
    n-device ("model",) mesh — contiguous and paged.  Runs out-of-process
    because multi-device CPU needs XLA_FLAGS set before jax initializes."""
    from repro.dist import tp
    from repro.launch.mesh import mesh_for
    params, cfg, scfg, prompts, budgets = _mesh_args(full)
    mesh = mesh_for((n,), ("model",))
    ok, reason = tp.tp_eligible(cfg, n)
    out = {"devices": n, "tp_path": "shard_map" if ok else "gspmd",
           "tp_reason": reason}
    out["continuous"] = _run_continuous(params, cfg, scfg, prompts, budgets,
                                        mesh=mesh)
    out["paged"] = _run_paged(params, cfg, scfg, prompts, budgets, mesh=mesh)
    return out


def _bench_mesh(full: bool) -> dict:
    """Fan the mesh shapes out to subprocesses (this process's jax is
    already initialized single-device); one JSON line back per shape."""
    import os
    import subprocess
    import sys
    out = {}
    for n in MESH_SHAPES:
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        cmd = [sys.executable, os.path.abspath(__file__),
               "--_mesh-one", str(n)]
        if not full:
            cmd.append("--smoke")
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=3600)
        if r.returncode != 0:
            out[f"mesh{n}"] = {"error": r.stderr[-1000:]}
            continue
        out[f"mesh{n}"] = json.loads(r.stdout.strip().splitlines()[-1])
    return out


def _differential(params, cfg, scfg, prompts, budgets) -> dict:
    """Greedy token-identity vs single-request generate, 3 arrival orders."""
    from repro.serve.engine import ContinuousEngine, Engine
    ref = Engine(params, cfg, scfg)
    want = [ref.generate(p[None], n)[0] for p, n in zip(prompts, budgets)]
    rng = np.random.default_rng(7)
    orders = [list(range(len(prompts))),
              list(range(len(prompts)))[::-1],
              list(rng.permutation(len(prompts)))]
    identical = 0
    for order in orders:
        eng = ContinuousEngine(params, cfg, scfg)
        handles = {j: eng.submit(prompts[j], budgets[j]) for j in order}
        out = eng.run(max_steps=100_000)
        if all(np.array_equal(out[handles[j].uid], want[j])
               for j in range(len(prompts))):
            identical += 1
    return {"orderings": len(orders), "identical": identical,
            "token_identical": identical == len(orders)}


def bench(full: bool = True) -> dict:
    from repro.serve.engine import ServeConfig
    params, cfg = _model(full)
    rng = np.random.default_rng(0)
    prompts, budgets = _traffic(full, rng, cfg.vocab)
    scfg = ServeConfig(max_len=max(len(p) for p in prompts) + max(budgets),
                       capacity=CAPACITY if full else 4)
    # differential first (small subset in full mode keeps the reference pass
    # cheap without weakening the orderings check)
    k = 12 if full else len(prompts)
    diff = _differential(params, cfg, scfg, prompts[:k], budgets[:k])
    paged_diff = _differential(params, cfg, _paged_scfg(scfg),
                               prompts[:k], budgets[:k])
    cont = _run_continuous(params, cfg, scfg, prompts, budgets)
    stat = _run_static(params, cfg, scfg, prompts, budgets)
    paged = _run_paged(params, cfg, scfg, prompts, budgets)
    cap = _capacity_at_equal_memory(params, cfg, scfg, prompts, budgets)
    ttft = _ttft_mixed(params, cfg, scfg, full)
    mesh = _bench_mesh(full)
    return {
        "config": {"mode": "full" if full else "smoke",
                   "capacity": scfg.capacity, "requests": len(prompts),
                   "model": cfg.name, "max_len": scfg.max_len},
        "continuous": cont, "static": stat, "differential": diff,
        "paged": paged, "paged_differential": paged_diff,
        "capacity_at_equal_memory": cap, "ttft_mixed": ttft, "mesh": mesh,
        "speedup_tokens_per_s": round(cont["tokens_per_s"]
                                      / stat["tokens_per_s"], 2),
    }


def run(full: bool = True):
    """benchmarks.run harness entry — CSV rows."""
    res = bench(full)
    for key in ("differential", "paged_differential"):
        if not res[key]["token_identical"]:
            raise AssertionError(
                f"{key}: engine diverged from single-request generation "
                f"({res[key]['identical']}/{res[key]['orderings']} "
                f"orderings identical)")
    cap = res["capacity_at_equal_memory"]
    return [("serve/continuous_vs_static_speedup",
             res["speedup_tokens_per_s"],
             f"cont={res['continuous']['tokens_per_s']}tok/s "
             f"static={res['static']['tokens_per_s']}tok/s "
             f"occupancy={res['continuous']['mean_occupancy']} "
             f"decode_waste={res['static']['decode_waste']:.0%} "
             f"diff_identical={res['differential']['token_identical']}"),
            ("serve/paged_peak_concurrency_at_equal_memory",
             cap["paged"]["peak_concurrency"],
             f"contiguous={cap['contiguous']['peak_concurrency']} "
             f"paged={cap['paged']['peak_concurrency']} on "
             f"{cap['paged']['kv_token_memory']} cached tokens; "
             f"short_ttft_p99 paged="
             f"{res['ttft_mixed']['paged']['short_ttft_p99_ms']}ms vs "
             f"contiguous="
             f"{res['ttft_mixed']['contiguous']['short_ttft_p99_ms']}ms")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short stream (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--_mesh-one", type=int, default=0, dest="mesh_one",
                    help=argparse.SUPPRESS)   # internal subprocess entry
    args = ap.parse_args()
    if args.mesh_one:
        print(json.dumps(_mesh_one(not args.smoke, args.mesh_one)))
        return
    res = bench(full=not args.smoke)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
        f.write("\n")
    cap = res["capacity_at_equal_memory"]
    print(f"continuous {res['continuous']['tokens_per_s']} tok/s vs "
          f"static {res['static']['tokens_per_s']} tok/s "
          f"({res['speedup_tokens_per_s']}x), differential "
          f"{res['differential']['identical']}/"
          f"{res['differential']['orderings']} orderings identical, paged "
          f"{res['paged_differential']['identical']}/"
          f"{res['paged_differential']['orderings']}")
    print(f"equal-memory concurrency: paged "
          f"{cap['paged']['peak_concurrency']} vs contiguous "
          f"{cap['contiguous']['peak_concurrency']} "
          f"(higher={cap['paged_higher_capacity']}); mixed-trace short "
          f"TTFT p99 paged {res['ttft_mixed']['paged']['short_ttft_p99_ms']}"
          f"ms vs contiguous "
          f"{res['ttft_mixed']['contiguous']['short_ttft_p99_ms']}ms "
          f"(no_worse={res['ttft_mixed']['paged_no_worse']})")
    for key, m in sorted(res["mesh"].items()):
        if "error" in m:
            print(f"{key}: FAILED ({m['error'][:200]})")
        else:
            print(f"{key} ({m['tp_path']}): continuous "
                  f"{m['continuous']['tokens_per_s']} tok/s, paged "
                  f"{m['paged']['tokens_per_s']} tok/s")
    print(f"wrote {args.out}")
    for key in ("differential", "paged_differential"):
        if not res[key]["token_identical"]:
            raise SystemExit(f"{key} correctness check FAILED")
    if not cap["paged_higher_capacity"]:
        raise SystemExit("equal-memory capacity check FAILED")
    if not res["ttft_mixed"]["paged_no_worse"]:
        raise SystemExit("mixed-trace TTFT p99 check FAILED")


if __name__ == "__main__":
    main()
