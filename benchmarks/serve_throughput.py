"""Serving throughput: continuous batching vs the static-batch baseline.

Mixed-length traffic (uniform prompt lengths and decode budgets) is where
continuous batching earns its keep: the static engine pads every prompt in a
batch to the longest and decodes the whole batch to the largest token budget,
so short requests burn slots as padding; the continuous engine refills each
slot the moment its request finishes.  Both engines run the SAME request
stream at the SAME slot capacity, timed after a warmup pass so jit compiles
are excluded (steady-state serving, the regime the ROADMAP north-star cares
about).

Also re-verifies the engine's correctness contract per run: greedy outputs
must be token-identical to single-request ``Engine.generate`` for every
request across 3 arrival orderings (submit order, reversed, shuffled).

``python benchmarks/serve_throughput.py`` writes ``BENCH_serve.json``;
``--smoke`` shrinks the model and stream for CI.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

CAPACITY = 8


def _model(full: bool):
    import jax
    from repro.models import model as M
    from repro.models import modules as nn
    from repro.models.config import ModelConfig
    cfg = ModelConfig(
        name="serve-bench", family="dense", vocab=1024, dtype="float32",
        **(dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=512)
           if full else
           dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)),
    ).validate()
    params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
    return params, cfg


def _traffic(full: bool, rng: np.random.Generator, vocab: int):
    n = 32 if full else 10
    # bucketed prompt lengths: realistic mixed traffic, bounded prefill
    # retraces for both engines; decode budgets spread wide — the straggler
    # effect static batching pays for
    lens = (8, 16, 24, 32) if full else (4, 8, 12)
    new_lo, new_hi = (8, 48) if full else (3, 8)
    prompts = [rng.integers(0, vocab, int(rng.choice(lens))).astype(np.int32)
               for _ in range(n)]
    budgets = [int(rng.integers(new_lo, new_hi + 1)) for _ in range(n)]
    return prompts, budgets


REPS = 3        # timed repetitions; best-of-N suppresses machine noise


def _run_continuous(params, cfg, scfg, prompts, budgets):
    from repro.serve.engine import ContinuousEngine
    eng = ContinuousEngine(params, cfg, scfg)
    wall = float("inf")
    for rep in range(1 + REPS):             # pass 0 warms jit caches
        for p, n in zip(prompts, budgets):
            eng.submit(p, n)
        t0 = time.perf_counter()
        eng.run(max_steps=100_000)
        if rep == 0:
            eng.reset_stats()   # metrics describe the timed (warm) passes
        else:
            wall = min(wall, time.perf_counter() - t0)
    toks = sum(budgets)
    m = eng.metrics()
    return {"wall_s": round(wall, 3), "useful_tokens": toks,
            "tokens_per_s": round(toks / wall, 1),
            "mean_occupancy": round(m["mean_occupancy"], 2),
            "prefill_frac": round(m["prefill_frac"], 3),
            "prefill_compiles": eng.stats["prefill_compiles"]}


def _run_static(params, cfg, scfg, prompts, budgets):
    from repro.serve.engine import Engine, static_batches
    eng = Engine(params, cfg, scfg)
    wall = float("inf")
    for rep in range(1 + REPS):             # pass 0 warms jit caches
        t0 = time.perf_counter()
        decoded = 0
        for padded, new, idxs in static_batches(prompts, budgets,
                                                scfg.capacity):
            decoded += new * len(idxs)
            eng.generate(padded, new)
        if rep > 0:
            wall = min(wall, time.perf_counter() - t0)
    toks = sum(budgets)
    return {"wall_s": round(wall, 3), "useful_tokens": toks,
            "decoded_tokens": decoded,
            "tokens_per_s": round(toks / wall, 1),
            "decode_waste": round(1 - toks / decoded, 3)}


def _differential(params, cfg, scfg, prompts, budgets) -> dict:
    """Greedy token-identity vs single-request generate, 3 arrival orders."""
    from repro.serve.engine import ContinuousEngine, Engine
    ref = Engine(params, cfg, scfg)
    want = [ref.generate(p[None], n)[0] for p, n in zip(prompts, budgets)]
    rng = np.random.default_rng(7)
    orders = [list(range(len(prompts))),
              list(range(len(prompts)))[::-1],
              list(rng.permutation(len(prompts)))]
    identical = 0
    for order in orders:
        eng = ContinuousEngine(params, cfg, scfg)
        handles = {j: eng.submit(prompts[j], budgets[j]) for j in order}
        out = eng.run(max_steps=100_000)
        if all(np.array_equal(out[handles[j].uid], want[j])
               for j in range(len(prompts))):
            identical += 1
    return {"orderings": len(orders), "identical": identical,
            "token_identical": identical == len(orders)}


def bench(full: bool = True) -> dict:
    from repro.serve.engine import ServeConfig
    params, cfg = _model(full)
    rng = np.random.default_rng(0)
    prompts, budgets = _traffic(full, rng, cfg.vocab)
    scfg = ServeConfig(max_len=max(len(p) for p in prompts) + max(budgets),
                       capacity=CAPACITY if full else 4)
    # differential first (small subset in full mode keeps the reference pass
    # cheap without weakening the orderings check)
    k = 12 if full else len(prompts)
    diff = _differential(params, cfg, scfg, prompts[:k], budgets[:k])
    cont = _run_continuous(params, cfg, scfg, prompts, budgets)
    stat = _run_static(params, cfg, scfg, prompts, budgets)
    return {
        "config": {"mode": "full" if full else "smoke",
                   "capacity": scfg.capacity, "requests": len(prompts),
                   "model": cfg.name, "max_len": scfg.max_len},
        "continuous": cont, "static": stat, "differential": diff,
        "speedup_tokens_per_s": round(cont["tokens_per_s"]
                                      / stat["tokens_per_s"], 2),
    }


def run(full: bool = True):
    """benchmarks.run harness entry — CSV rows."""
    res = bench(full)
    if not res["differential"]["token_identical"]:
        raise AssertionError(
            f"continuous engine diverged from single-request generation "
            f"({res['differential']['identical']}/"
            f"{res['differential']['orderings']} orderings identical)")
    return [("serve/continuous_vs_static_speedup",
             res["speedup_tokens_per_s"],
             f"cont={res['continuous']['tokens_per_s']}tok/s "
             f"static={res['static']['tokens_per_s']}tok/s "
             f"occupancy={res['continuous']['mean_occupancy']} "
             f"decode_waste={res['static']['decode_waste']:.0%} "
             f"diff_identical={res['differential']['token_identical']}")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short stream (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    res = bench(full=not args.smoke)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"continuous {res['continuous']['tokens_per_s']} tok/s vs "
          f"static {res['static']['tokens_per_s']} tok/s "
          f"({res['speedup_tokens_per_s']}x), differential "
          f"{res['differential']['identical']}/"
          f"{res['differential']['orderings']} orderings identical")
    print(f"wrote {args.out}")
    if not res["differential"]["token_identical"]:
        raise SystemExit("differential correctness check FAILED")


if __name__ == "__main__":
    main()
