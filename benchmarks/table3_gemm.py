"""Paper Table 3: fused GEMM + LeakyReLU, SIP vs baseline schedule.

Paper setting: A100, fp16, (M, N, K) = (512, 512, 2048); SIP found a 12.27%
lower-latency sass schedule.  Here the kernel is the Pallas GEMM and the
energy is the TPU-v5e analytic cost model evaluated at the paper's exact
shape (the cost model does not execute the kernel, so the full shape is
cheap); probabilistic testing gates each step at a reduced shape.

Two search modes are reported:
  * paper-faithful  — order-only mutations (the paper's §3.1 space)
  * beyond-paper    — order + BlockSpec tile knobs (TPU macro schedule)
"""

from __future__ import annotations

import numpy as np

from repro.core import annealing, energy as energy_mod
from repro.core.jit import TuneConfig
from repro.core.mutation import MutationPolicy
from repro.core.schedule import Schedule
from repro.kernels.gemm_fused import ops as gemm_ops

PAPER_SHAPE = dict(m=512, n=512, k=2048, dtype="bfloat16")
PAPER_IMPROVEMENT = 0.1227          # Table 3: 26.91us -> 23.97us


def _anneal(knob_prob: float, seed: int = 0, cooling: float = 1.01):
    static = dict(PAPER_SHAPE)
    space = gemm_ops.space(**static)
    program_for = lambda s: gemm_ops.program_for(s, **static)
    energy = energy_mod.CostModelEnergy(program_for)
    policy = MutationPolicy(space=space, program_for=program_for,
                            knob_prob=knob_prob)
    x0 = Schedule(knobs=space.default_knobs())
    return annealing.anneal(x0, energy, policy.propose, t_max=1.0,
                            t_min=5e-3, cooling=cooling, seed=seed)


def run(full: bool = True):
    rows = []
    res_f = _anneal(knob_prob=0.0, cooling=1.01 if full else 1.1)
    rows.append(("table3/gemm_baseline_us", res_f.initial_raw * 1e6,
                 "whole-kernel cost-model latency, default (compiler-like) schedule"))
    rows.append(("table3/gemm_sip_faithful_us", res_f.best_raw * 1e6,
                 f"improvement={res_f.improvement:.2%} "
                 f"(paper: {PAPER_IMPROVEMENT:.2%}), evals={res_f.evals}"))
    res_b = _anneal(knob_prob=0.25, cooling=1.01 if full else 1.1)
    rows.append(("table3/gemm_sip_beyond_us", res_b.best_raw * 1e6,
                 f"improvement={res_b.improvement:.2%} (order+tile knobs), "
                 f"knobs={dict(res_b.best.knobs)}"))

    # correctness: tuned schedule passes probabilistic testing end to end
    x = np.random.default_rng(0).standard_normal((64, 128)).astype(np.float32)
    w = np.random.default_rng(1).standard_normal((128, 64)).astype(np.float32)
    results = gemm_ops.gemm_leaky_relu.tune(
        [x, w], TuneConfig(rounds=1, t_min=0.2, cooling=1.2,
                           step_samples=1, final_samples=16))
    ent = gemm_ops.gemm_leaky_relu.cache.entries(
        gemm_ops.NAME, gemm_ops.gemm_leaky_relu.sig_str(
            gemm_ops.gemm_leaky_relu.static_of(x, w)))
    rows.append(("table3/gemm_tested_deploy_us", results[0].best_raw * 1e6,
                 f"tests_passed={all(e.tests_passed for e in ent)}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
