"""Beyond-paper: guided vs vanilla SIP search convergence.

Compares the paper's uniform mutation policy against the cost-model-guided
epsilon-greedy policy (core/guided.py) on the Table-3 GEMM workload:
best-found latency and evaluations-to-within-1%-of-best."""

from __future__ import annotations

from repro.core import annealing, energy as energy_mod
from repro.core.guided import GuidedMutationPolicy
from repro.core.mutation import MutationPolicy
from repro.core.schedule import Schedule
from repro.kernels.gemm_fused import ops as gemm_ops

SHAPE = dict(m=512, n=512, k=2048, dtype="bfloat16")


def _run(policy_cls, seed: int, cooling: float, **kw):
    space = gemm_ops.space(**SHAPE)
    program_for = lambda s: gemm_ops.program_for(s, **SHAPE)
    energy = energy_mod.CostModelEnergy(program_for)
    policy = policy_cls(space=space, program_for=program_for, **kw)
    res = annealing.anneal(Schedule(knobs=space.default_knobs()), energy,
                           policy.propose, t_max=1.0, t_min=5e-3,
                           cooling=cooling, seed=seed)
    # evals until within 1% of the final best
    target = res.best_energy * 1.01
    evals_to = next((i + 1 for i, h in enumerate(res.history)
                     if h.best_energy <= target), len(res.history))
    return res, evals_to


def run(full: bool = True):
    cooling = 1.01 if full else 1.1
    seeds = (0, 1, 2) if full else (0,)
    rows = []
    for name, cls, kw in (("vanilla", MutationPolicy, {}),
                          ("guided", GuidedMutationPolicy, {"greed": 0.5})):
        imps, evs = [], []
        for s in seeds:
            res, evals_to = _run(cls, s, cooling, **kw)
            imps.append(res.improvement)
            evs.append(evals_to)
        rows.append((f"guided/{name}_improvement_pct",
                     100 * sum(imps) / len(imps),
                     f"mean of {len(seeds)} seeds; evals_to_1pct="
                     f"{sum(evs) / len(evs):.0f}"))
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.2f},{derived}")
