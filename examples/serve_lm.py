"""Serve a small LM with batched requests through the production engine.

    PYTHONPATH=src python examples/serve_lm.py

Demonstrates: batched prefill -> greedy decode with a preallocated KV cache,
per-request EOS handling, throughput stats, and (via --use-pallas) routing
the prefill through the SIP-tunable Pallas flash-attention kernel.
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.serve.engine import Engine, ServeConfig

CFG = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=4, d_ff=512, vocab=8_000, dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--use-pallas", action="store_true")
    args = ap.parse_args()

    cfg = dataclasses.replace(CFG, use_pallas=args.use_pallas)
    params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
    eng = Engine(params, cfg,
                 ServeConfig(max_len=args.prompt_len + args.new_tokens,
                             temperature=args.temperature))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out = eng.generate(prompts, args.new_tokens)
    print(f"[serve] batch={args.batch} prompt={args.prompt_len} "
          f"generated={out.shape[1]} tokens/request")
    print(f"[serve] prefill {eng.stats['prefill_s']:.2f}s, decode "
          f"{eng.stats['tokens_out'] / max(eng.stats['decode_s'], 1e-9):.1f} tok/s")
    for i in range(min(3, args.batch)):
        print(f"  req{i}: ...{prompts[i, -5:].tolist()} -> "
              f"{out[i, :10].tolist()}...")


if __name__ == "__main__":
    main()
