"""Serve a small LM under continuous batching with streaming output.

    PYTHONPATH=src python examples/serve_lm.py

Demonstrates: a FIFO request queue over fixed-capacity decode slots,
prefill-on-arrival at each request's exact prompt length, per-request stop
budgets, streaming token emission, and the engine's queue/occupancy metrics.
``--static`` runs the same requests through the static-batch baseline engine
for comparison; ``--use-pallas`` routes prefill through the SIP-tunable
Pallas flash-attention kernel.
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.serve.engine import (ContinuousEngine, Engine, ServeConfig,
                                static_batches)

CFG = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=4, d_ff=512, vocab=8_000, dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--static", action="store_true",
                    help="static-batch baseline instead of continuous")
    args = ap.parse_args()

    cfg = dataclasses.replace(CFG, use_pallas=args.use_pallas)
    params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    lens = [int(rng.choice([16, 32, 64])) for _ in range(args.requests)]
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]
    budgets = [int(rng.integers(min(8, args.new_tokens), args.new_tokens + 1))
               for _ in range(args.requests)]
    scfg = ServeConfig(max_len=max(lens) + args.new_tokens,
                       temperature=args.temperature, capacity=args.capacity)

    if args.static:
        eng = Engine(params, cfg, scfg)
        for padded, new, _ in static_batches(prompts, budgets, args.capacity):
            eng.generate(padded, new)
        print(f"[serve:static] {args.requests} requests in batches of "
              f"{args.capacity} (padded to batch max), "
              f"{eng.stats['tokens_out'] / max(eng.stats['decode_s'], 1e-9):.1f} tok/s decode")
        return

    first_tokens: dict[int, int] = {}
    eng = ContinuousEngine(
        params, cfg, scfg,
        on_token=lambda r, t: first_tokens.setdefault(r.uid, t))
    handles = [eng.submit(p, n) for p, n in zip(prompts, budgets)]
    out = eng.run(max_steps=100_000)
    m = eng.metrics()
    print(f"[serve:continuous] {args.requests} requests "
          f"(prompts {min(lens)}-{max(lens)} tokens) over "
          f"{args.capacity} slots")
    print(f"[serve:continuous] {m['tokens_per_s']:.1f} tok/s, mean occupancy "
          f"{m['mean_occupancy']:.1f}, prefill {m['prefill_frac']:.0%} of "
          f"wall, {eng.stats['prefill_compiles']} prefill shapes compiled")
    for h in handles[:3]:
        print(f"  req{h.uid}: prompt[{len(h.prompt)}] -> first={first_tokens[h.uid]} "
              f"tokens={out[h.uid][:8].tolist()}...")


if __name__ == "__main__":
    main()
