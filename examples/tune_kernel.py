"""Inspect WHAT SIP discovers: before/after instruction listings for the
flash-attention kernel (the paper's Listings 4 vs 5 comparison).

    PYTHONPATH=src python examples/tune_kernel.py

Expected outcome: the annealer hoists the V-chunk loads (`ld_v*`) ahead of
the softmax chain and interleaves the K-chunk loads with the QK^T dots —
the latency-hiding schedule that hand-tuning produces on GPUs and that the
Pallas default ordering does not express.
"""

from repro.core import annealing, energy as energy_mod, registry
from repro.core.mutation import MutationPolicy
from repro.core.schedule import Schedule
from repro.kernels.flash_attention import ops as fa_ops

STATIC = dict(b=1, hq=4, hkv=4, sq=16384, skv=16384, d=64, causal=False,
              window=None, dtype="bfloat16")


def main() -> None:
    # the registry hands back the kernel's declarative spec — the same six
    # callables SipKernel.tune drives, usable piecemeal for inspection
    spec = registry.spec(fa_ops.variant_name(causal=False, window=None))
    space = spec.space_for(**STATIC)
    program_for = lambda s: spec.program_for(s, **STATIC)
    knobs = space.default_knobs()
    knobs["n_chunks"] = 4
    x0 = Schedule(knobs=knobs)

    energy = energy_mod.CostModelEnergy(program_for)
    policy = MutationPolicy(space=space, program_for=program_for)
    res = annealing.anneal(x0, energy, policy.propose,
                           t_max=1.0, t_min=5e-3, cooling=1.02, seed=0)

    prog = program_for(res.best)
    print("=== baseline (compiler-like emission order) ===")
    print(prog.listing())
    print(f"\ncost-model latency: {res.initial_raw * 1e6:.3f} us")
    print("\n=== SIP-optimized order ===")
    print(prog.listing(res.best.order))
    print(f"\ncost-model latency: {res.best_raw * 1e6:.3f} us "
          f"({res.improvement:+.2%})")
    print(f"\naccepted {sum(h.accepted for h in res.history)} of "
          f"{len(res.history)} proposals; "
          f"best found at eval {max(i for i, h in enumerate(res.history) if h.best_energy == res.best_energy)}")


if __name__ == "__main__":
    main()
