"""End-to-end driver: train a ~100M-parameter qwen3-family LM with the full
production stack (data pipeline, AdamW, checkpoint/restart, FT heartbeats).

    PYTHONPATH=src python examples/train_lm.py --steps 200

~100M params (12L, d=768, vocab 32k) — a few hundred steps on CPU takes tens
of minutes; pass --steps 20 for a fast sanity run.  Kill it mid-run and
relaunch: it resumes from the newest verified checkpoint, and the stateless
data pipeline guarantees the resumed trajectory is bit-identical to an
uninterrupted one (tested in tests/test_steps_and_loop.py).
"""

import argparse
import dataclasses

from repro.data.pipeline import DataConfig
from repro.ft.manager import FTManager
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train.loop import TrainConfig, train

CFG_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_000, qk_norm=True,
    dtype="float32", param_dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = CFG_100M
    n_params = (cfg.vocab * cfg.d_model * 2 +
                cfg.n_layers * (cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads)
                                * cfg.hd + cfg.n_heads * cfg.hd * cfg.d_model +
                                3 * cfg.d_model * cfg.d_ff))
    print(f"[example] ~{n_params / 1e6:.0f}M params")

    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq,
                      vocab=cfg.vocab)
    tcfg = TrainConfig(total_steps=args.steps, ckpt_every=50,
                       ckpt_dir=args.ckpt_dir, log_every=10)
    ocfg = adamw.OptConfig(peak_lr=3e-4, warmup_steps=20,
                           decay_steps=args.steps)
    ft = FTManager(n_workers=1)
    res = train(cfg, dcfg, tcfg, ocfg, ft=ft)
    first, last = res["history"][0]["loss"], res["final_loss"]
    print(f"[example] loss {first:.3f} -> {last:.3f} "
          f"over {len(res['history'])} steps")


if __name__ == "__main__":
    main()
