"""Quickstart: autotune a fused GEMM+LeakyReLU kernel with SIP, end to end.

    PYTHONPATH=src python examples/quickstart.py

This is the paper's Listing 2 workflow on the registry API: kernels are
*declared* (a ``@sip_kernel``-registered ``KernelSpec`` with their own
workloads), resolved by name, tuned offline (simulated annealing over
dependency-legal instruction reorderings, probabilistically tested against
the oracle at every step), and deployed from the persisted schedule cache
with zero runtime overhead.
"""

import numpy as np

from repro.core import TuneConfig, registry, schedule_cache
from repro.kernels.gemm_fused import ops as gemm_ops
from repro.kernels.gemm_fused import ref


def main() -> None:
    # a persistent cache — deployment reloads tuned schedules from here.
    # schedule_cache scopes the active store; registry.get resolves the ONE
    # shared kernel instance bound to it.
    with schedule_cache("/tmp/sip_cache.json"):
        kernel = registry.get(gemm_ops.NAME)

        x = np.random.default_rng(0).standard_normal((128, 256)).astype(np.float32)
        w = np.random.default_rng(1).standard_normal((256, 128)).astype(np.float32)

        # 1. baseline: compiler-like schedule
        y0 = kernel(x, w)
        assert np.allclose(y0, ref.gemm_leaky_relu(x, w), atol=1e-4)
        print("baseline schedule runs and is correct")

        # 2. offline SIP search (paper Alg. 1 + §4.2 testing), two rounds
        results = kernel.tune([x, w],
                              TuneConfig(rounds=2, cooling=1.05, t_min=0.05,
                                         step_samples=2, final_samples=32),
                              verbose=True)
        best = min(results, key=lambda r: r.best_raw)
        print(f"SIP improvement: {best.improvement:.2%} "
              f"({best.evals} schedules evaluated)")

        # 3. deployment: the tuned schedule loads from the cache transparently
        y1 = kernel(x, w)
        assert np.allclose(y1, ref.gemm_leaky_relu(x, w), atol=1e-4)
        print("tuned schedule deployed from cache and is correct")


if __name__ == "__main__":
    main()
