"""Production training loop: jitted step, checkpoint/restart, heartbeats,
SIP kernel-cache wiring, and metrics logging.

The loop is deliberately a plain function over explicit state so that the
supervisor (:mod:`repro.ft.supervisor`) can kill and relaunch it
idempotently: everything it needs to resume is (checkpoint dir, step) — the
data pipeline is stateless-resumable by construction (data/pipeline.py).

Failure contract: the loop RAISES (:mod:`repro.ft.errors`) and the
supervisor catches.  ``FTManager.decide()`` is consulted every step —
a dead worker raises ``RestartRequired`` or ``ReshapeRequired`` (with the
ladder target), a non-finite loss raises ``NonFiniteLossError``, and a
chaos plan (:mod:`repro.ft.chaos`) can inject any of these
deterministically.  Restores go through ``restore_latest`` so a corrupt
newest checkpoint falls back to the previous verified step instead of
killing the relaunch.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import time
from collections import deque
from typing import Any, Callable, Collection

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import DataConfig, batch_for_model
from repro.dist import partition
from repro.ft.chaos import ChaosEngine
from repro.ft.errors import (NonFiniteLossError, ReshapeRequired,
                             RestartRequired)
from repro.ft.manager import Action, FTManager
from repro.launch import steps
from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.optim import adamw


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    num_microbatches: int = 1
    async_ckpt: bool = True
    seed: int = 0
    # metrics history returned by train(): None keeps every step (small
    # runs/tests); an int keeps only the newest N entries (long runs must
    # not grow an unbounded list of per-step dicts)
    log_history: int | None = None


def make_train_state(mcfg: ModelConfig, mesh=None, seed: int = 0):
    """(params, opt_state) initialized (sharded when a mesh is given)."""
    key = jax.random.PRNGKey(seed)
    if mesh is None:
        params = nn.unwrap(M.init_lm(key, mcfg))
        return params, adamw.init_opt_state(params)
    ptree = M.init_lm_shapes(key, mcfg)
    pshard = steps.param_shardings(ptree, mesh)
    init = jax.jit(lambda k: nn.unwrap(M.init_lm(k, mcfg)),
                   out_shardings=pshard)
    params = init(key)
    oshard = steps.opt_shardings(pshard, mesh)
    opt_state = jax.jit(adamw.init_opt_state, out_shardings=oshard)(params)
    return params, opt_state


def _restore(ckpt: CheckpointManager, mcfg: ModelConfig, tcfg: TrainConfig,
             mesh, params, opt_state):
    """Newest VERIFIED checkpoint (corrupt steps are skipped, counted, and
    fall back), resharded onto the current mesh."""
    shardings = None
    if mesh is not None:
        ptree = M.init_lm_shapes(jax.random.PRNGKey(tcfg.seed), mcfg)
        pshard = steps.param_shardings(ptree, mesh)
        shardings = {"params": pshard,
                     "opt": steps.opt_shardings(pshard, mesh)}
    corrupt = obs_metrics.active_registry().counter("ft.ckpt_corrupt")

    def on_corrupt(step: int) -> None:
        corrupt.inc()
        obs_trace.instant("ft.ckpt_corrupt", step=step)
        print(f"[train] checkpoint step {step} failed verification; "
              f"falling back")

    step, state = ckpt.restore_latest(
        {"params": params, "opt": opt_state}, shardings,
        on_corrupt=on_corrupt)
    if step is None:
        return 0, params, opt_state
    print(f"[train] resumed from step {step}")
    return step, state["params"], state["opt"]


def train(mcfg: ModelConfig, dcfg: DataConfig, tcfg: TrainConfig,
          ocfg: adamw.OptConfig = adamw.OptConfig(), *, mesh=None,
          ft: FTManager | None = None,
          chaos: ChaosEngine | None = None,
          skip_data_steps: Collection[int] = frozenset(),
          on_metrics: Callable[[int, dict[str, Any]], None] | None = None):
    """Run (or resume) training to tcfg.total_steps.  Returns final metrics.

    ``skip_data_steps`` (supervisor-owned) replaces those steps' batches
    with a disjoint deterministic substitute (data step ``s +
    tcfg.total_steps``) — the rollback path for data-dependent non-finite
    losses.  With ``ft`` given, every step heartbeats all workers and
    consults ``ft.decide()``; RESTART/ELASTIC verdicts raise for the
    supervisor to handle.
    """
    ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
    params, opt_state = make_train_state(mcfg, mesh, tcfg.seed)
    start_step, params, opt_state = _restore(ckpt, mcfg, tcfg, mesh,
                                             params, opt_state)

    step_fn = functools.partial(steps.train_step, cfg=mcfg, opt_cfg=ocfg,
                                num_microbatches=tcfg.num_microbatches)
    jfn = jax.jit(step_fn, donate_argnums=(0, 1))

    history: Any = (deque(maxlen=tcfg.log_history)
                    if tcfg.log_history is not None else [])
    reg = obs_metrics.active_registry()
    m_steps = reg.counter("train.steps")
    h_step = reg.histogram("train.step_s")
    g_loss = reg.gauge("train.loss")
    skip = frozenset(skip_data_steps)
    ctx = (partition.mesh_rules(mesh) if mesh is not None
           else contextlib.nullcontext())
    with ctx:
        for step in range(start_step, tcfg.total_steps):
            if chaos is not None:
                chaos.on_step_start(step)      # may raise WorkerKilled
            substituted = step in skip
            data_step = step + tcfg.total_steps if substituted else step
            batch = batch_for_model(mcfg, dcfg, data_step)
            t0 = time.perf_counter()
            with obs_trace.span("train.step", step=step) as sp:
                params, opt_state, metrics = jfn(params, opt_state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                sp["loss"] = metrics.get("loss")
            dt = time.perf_counter() - t0
            loss = metrics.get("loss", 0.0)
            if chaos is not None:
                loss = chaos.filter_loss(step, loss, substituted=substituted)
                metrics["loss"] = loss
            if not math.isfinite(loss):
                # crashing later on garbage weights is strictly worse; the
                # supervisor rolls back to the last checkpoint and skips
                # this step's batch
                raise NonFiniteLossError(step, loss)
            metrics["step_s"] = dt
            m_steps.inc()
            h_step.record(dt)
            g_loss.set(loss)
            if ft is not None:
                _heartbeat_and_decide(ft, chaos, step, dt)
            if (step + 1) % tcfg.log_every == 0 or step == start_step:
                print(f"[train] step {step + 1}/{tcfg.total_steps} "
                      f"loss={metrics['loss']:.4f} "
                      f"lr={metrics['lr']:.2e} {dt * 1e3:.0f}ms")
            if on_metrics:
                on_metrics(step, metrics)
            history.append(metrics)
            if (step + 1) % tcfg.ckpt_every == 0 or step + 1 == tcfg.total_steps:
                with obs_trace.span("train.checkpoint", step=step + 1) as sp:
                    sp["blocked_s"] = ckpt.save(
                        step + 1, {"params": params, "opt": opt_state},
                        blocking=not tcfg.async_ckpt)
                if chaos is not None and chaos.wants_corrupt(step + 1):
                    ckpt.wait()            # the fault hits a finished write
                    chaos.corrupt_checkpoint(tcfg.ckpt_dir, step + 1)
    ckpt.wait()
    history = list(history)
    return {"history": history, "params": params, "opt_state": opt_state,
            "step": tcfg.total_steps,
            "final_loss": history[-1]["loss"] if history else float("nan")}


def _heartbeat_and_decide(ft: FTManager, chaos: ChaosEngine | None,
                          step: int, dt: float) -> None:
    """Feed this step's heartbeats (all workers — this single-process loop
    stands in for the fleet) and act on the coordinator's verdict."""
    for w in ft.workers:
        if chaos is not None and chaos.heartbeat_suppressed(w):
            continue
        factor = chaos.latency_factor(w, step) if chaos is not None else 1.0
        ft.heartbeat(w, dt * factor)
    action, info = ft.decide()
    if action is Action.RESTART_FROM_CKPT:
        raise RestartRequired(f"worker(s) {info.get('dead')} died at "
                              f"step {step}", step=step, info=info)
    if action is Action.ELASTIC_RESHAPE:
        raise ReshapeRequired(f"capacity lost at step {step}; reshaping "
                              f"to {info['mesh'][0]}",
                              target=info["mesh"], step=step, info=info)
    if info.get("stragglers"):
        obs_metrics.active_registry().counter("ft.stragglers").inc(
            len(info["stragglers"]))
        obs_trace.instant("ft.straggler", step=step,
                          workers=len(info["stragglers"]))
