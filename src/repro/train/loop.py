"""Production training loop: jitted step, checkpoint/restart, heartbeats,
SIP kernel-cache wiring, and metrics logging.

The loop is deliberately a plain function over explicit state so that the
FT manager can kill and relaunch it idempotently: everything it needs to
resume is (checkpoint dir, step) — the data pipeline is stateless-resumable
by construction (data/pipeline.py).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import DataConfig, batch_for_model
from repro.dist import partition
from repro.ft.manager import FTManager
from repro.launch import steps
from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.optim import adamw


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    num_microbatches: int = 1
    async_ckpt: bool = True
    seed: int = 0


def make_train_state(mcfg: ModelConfig, mesh=None, seed: int = 0):
    """(params, opt_state) initialized (sharded when a mesh is given)."""
    key = jax.random.PRNGKey(seed)
    if mesh is None:
        params = nn.unwrap(M.init_lm(key, mcfg))
        return params, adamw.init_opt_state(params)
    ptree = M.init_lm_shapes(key, mcfg)
    pshard = steps.param_shardings(ptree, mesh)
    init = jax.jit(lambda k: nn.unwrap(M.init_lm(k, mcfg)),
                   out_shardings=pshard)
    params = init(key)
    oshard = steps.opt_shardings(pshard, mesh)
    opt_state = jax.jit(adamw.init_opt_state, out_shardings=oshard)(params)
    return params, opt_state


def train(mcfg: ModelConfig, dcfg: DataConfig, tcfg: TrainConfig,
          ocfg: adamw.OptConfig = adamw.OptConfig(), *, mesh=None,
          ft: FTManager | None = None,
          on_metrics: Callable[[int, dict[str, Any]], None] | None = None):
    """Run (or resume) training to tcfg.total_steps.  Returns final metrics."""
    ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
    params, opt_state = make_train_state(mcfg, mesh, tcfg.seed)

    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        shardings = None
        if mesh is not None:
            ptree = M.init_lm_shapes(jax.random.PRNGKey(tcfg.seed), mcfg)
            pshard = steps.param_shardings(ptree, mesh)
            shardings = {"params": pshard,
                         "opt": steps.opt_shardings(pshard, mesh)}
        state = ckpt.restore(latest,
                             {"params": params, "opt": opt_state},
                             shardings)
        params, opt_state = state["params"], state["opt"]
        start_step = latest
        print(f"[train] resumed from step {latest}")

    step_fn = functools.partial(steps.train_step, cfg=mcfg, opt_cfg=ocfg,
                                num_microbatches=tcfg.num_microbatches)
    jfn = jax.jit(step_fn, donate_argnums=(0, 1))

    history = []
    reg = obs_metrics.active_registry()
    m_steps = reg.counter("train.steps")
    h_step = reg.histogram("train.step_s")
    g_loss = reg.gauge("train.loss")
    ctx = partition.mesh_rules(mesh) if mesh is not None else _nullctx()
    with ctx:
        for step in range(start_step, tcfg.total_steps):
            batch = batch_for_model(mcfg, dcfg, step)
            t0 = time.perf_counter()
            with obs_trace.span("train.step", step=step) as sp:
                params, opt_state, metrics = jfn(params, opt_state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                sp["loss"] = metrics.get("loss")
            dt = time.perf_counter() - t0
            metrics["step_s"] = dt
            m_steps.inc()
            h_step.record(dt)
            if "loss" in metrics:
                g_loss.set(metrics["loss"])
            if ft is not None:
                ft.heartbeat(0, dt)
            if (step + 1) % tcfg.log_every == 0 or step == start_step:
                print(f"[train] step {step + 1}/{tcfg.total_steps} "
                      f"loss={metrics['loss']:.4f} "
                      f"lr={metrics['lr']:.2e} {dt * 1e3:.0f}ms")
            if on_metrics:
                on_metrics(step, metrics)
            history.append(metrics)
            if (step + 1) % tcfg.ckpt_every == 0 or step + 1 == tcfg.total_steps:
                with obs_trace.span("train.checkpoint", step=step + 1):
                    ckpt.save(step + 1, {"params": params, "opt": opt_state},
                              blocking=not tcfg.async_ckpt)
    ckpt.wait()
    return {"history": history, "params": params, "opt_state": opt_state,
            "final_loss": history[-1]["loss"] if history else float("nan")}


class _nullctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
