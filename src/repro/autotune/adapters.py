"""Live-workload adapters: observed dispatch shapes -> tunable targets.

The recorder aggregates serving traffic into :class:`WorkloadKey`\\ s — a
(kind, prompt_len, batch, dtype) per distinct dispatch shape.  Each kernel
takes its own argument shapes, so someone has to say "a prefill of 16-token
prompts at batch 1 under THIS model is the causal flash-attention kernel at
(1, hq, 16, hd)".  That someone is this module: given the serving model and
engine configuration, :func:`serve_targets` maps each live key to the SIP
kernel the engine's hot path actually dispatches for it, with a
``make_args`` matching the observed shape.

Keys with no tunable kernel behind them (submit records, decode without the
paged gather) map to None and the service skips them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.registry import Workload
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.paged_attention import ops as pg_ops
from repro.models.config import ModelConfig
from repro.obs.recorder import WorkloadKey
from repro.serve.engine import ServeConfig


@dataclasses.dataclass(frozen=True)
class TuneTarget:
    """One tunable (kernel, workload) pair derived from a live key."""

    kernel: str
    workload: Workload


def _attn_args(b: int, hq: int, hkv: int, s: int, d: int, dtype: str):
    def make_args(rng: np.random.Generator) -> Sequence[np.ndarray]:
        dt = np.dtype(dtype)
        q = rng.standard_normal((b, hq, s, d)).astype(dt)
        k = rng.standard_normal((b, hkv, s, d)).astype(dt)
        v = rng.standard_normal((b, hkv, s, d)).astype(dt)
        return [q, k, v]
    return make_args


def _gather_args(p: int, ps: int, h: int, d: int, b: int, n: int, dtype: str):
    def make_args(rng: np.random.Generator) -> Sequence[np.ndarray]:
        store = rng.standard_normal((p, ps, h, d)).astype(np.dtype(dtype))
        pt = rng.integers(0, p, (b, n)).astype(np.int32)
        return [store, pt]
    return make_args


def serve_targets(cfg: ModelConfig, scfg: ServeConfig
                  ) -> Callable[[WorkloadKey], TuneTarget | None]:
    """The adapter for a serving deployment: live key -> tunable target.

    * ``prefill`` keys -> the flash-attention variant the model's SDPA path
      resolves (causal, ``cfg.window``), at the observed (batch, prompt_len)
      and the model's head geometry.  Under paged serving the engine
      prefills at page-rounded lengths, so the key's prompt_len is already
      the dispatched ``sq``.
    * ``decode`` keys -> the ``paged_gather`` kernel (paged serving's
      page-table-indirect cache read) at the pool geometry; contiguous-mode
      decode has no SIP kernel on its path, so those keys are skipped.
    * anything else (``submit`` bookkeeping) -> None.
    """
    hd = cfg.hd
    ps = scfg.page_size
    n_slot_pages = -(-scfg.max_len // ps)
    num_pages = (scfg.num_pages if scfg.num_pages is not None
                 else scfg.capacity * n_slot_pages + 1)

    def target_for(key: WorkloadKey) -> TuneTarget | None:
        if key.kind == "prefill" and key.prompt_len >= 1:
            name = fa_ops.ensure_registered(causal=True, window=cfg.window)
            make_args = _attn_args(key.batch, cfg.n_heads, cfg.n_kv_heads,
                                   key.prompt_len, hd, key.dtype)
            return TuneTarget(name, Workload(name=key.name,
                                             make_args=make_args,
                                             suites=("live",)))
        if key.kind == "decode" and scfg.paged:
            make_args = _gather_args(num_pages, ps, cfg.n_kv_heads, hd,
                                     key.batch, n_slot_pages, key.dtype)
            return TuneTarget(pg_ops.NAME, Workload(name=key.name,
                                                    make_args=make_args,
                                                    suites=("live",)))
        return None

    return target_for
