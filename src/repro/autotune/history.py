"""Cross-session tuning history — warm starts and a fitted search policy.

The offline store (:class:`~repro.core.cache.ScheduleCache`) remembers the
*winners*; this journal remembers the *searches*: every gated candidate the
autotune service produced, accepted or not, with the workload's signature
features.  Two things fall out of accumulating that across sessions:

* **warm starts** — a new workload seeds its search from the accepted
  schedule of its nearest already-tuned neighbor (feature distance over
  shape/dtype), instead of the space default.  Safety: a recalled schedule
  only ever seeds a space it is a legal point of
  (:meth:`SearchSpace.contains`), and its instruction order is kept only on
  an exact signature match — orders are per-program and meaningless across
  shapes (tests/test_autotune.py property-tests both).
* **a fitted policy** — the guided proposal's ``greed`` is fit per kernel on
  the accepted runs' improvements (:func:`repro.core.guided.fit_greed`):
  kernels where greedy proposals historically paid off search greedier.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
from typing import Any, Mapping

from repro.core.guided import fit_greed
from repro.core.schedule import Schedule, SearchSpace

HISTORY_VERSION = 1


def features_of(static: Mapping[str, Any]) -> dict[str, float]:
    """A signature dict as a feature vector for nearest-neighbor recall.

    Numeric fields land log2-scaled (a 2048-token prompt should be *near*
    1024, not 1024 units away); booleans are 0/1; any other value (dtype
    strings, window=None) becomes a one-hot ``key:value`` feature, so a
    categorical mismatch costs a fixed distance instead of being dropped.
    """
    feats: dict[str, float] = {}
    for key, value in static.items():
        if isinstance(value, bool):
            feats[f"{key}:{value}"] = 1.0
        elif isinstance(value, (int, float)) and math.isfinite(value):
            feats[key] = math.log2(1.0 + abs(float(value)))
        else:
            feats[f"{key}:{value}"] = 1.0
    return feats


def feature_distance(a: Mapping[str, float], b: Mapping[str, float]) -> float:
    """Euclidean distance over the union of feature keys (absent = 0.0, so a
    one-hot mismatch contributes sqrt(2))."""
    keys = set(a) | set(b)
    return math.sqrt(sum((a.get(k, 0.0) - b.get(k, 0.0)) ** 2 for k in keys))


@dataclasses.dataclass(frozen=True)
class HistoryRecord:
    """One gated search outcome."""

    kernel: str
    signature: str            # SipKernel.sig_str of the tuned workload
    workload: str
    schedule_json: str        # the candidate the search produced
    energy: float
    improvement: float        # AnnealResult.improvement of the run's best
    accepted: bool            # did the promotion gate take it?
    features: dict[str, float] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict[str, Any]) -> "HistoryRecord":
        return HistoryRecord(**d)


class TuneHistory:
    """Persistent (kernel, signature, schedule, energy) history.

    A single JSON file with atomic replace, like the schedule cache it sits
    next to; an unreadable file degrades to empty rather than taking the
    service down.
    """

    def __init__(self, path: str | None = None, *, max_records: int = 4096):
        self.path = path
        self.max_records = max_records
        self._records: list[HistoryRecord] = []
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    d = json.load(f)
                if d.get("version") == HISTORY_VERSION:
                    self._records = [HistoryRecord.from_dict(r)
                                     for r in d.get("records", [])]
            except (json.JSONDecodeError, OSError, TypeError, ValueError):
                self._records = []

    def __len__(self) -> int:
        return len(self._records)

    @property
    def records(self) -> list[HistoryRecord]:
        return list(self._records)

    def record(self, *, kernel: str, signature: str, workload: str,
               schedule: Schedule, energy: float, improvement: float,
               accepted: bool, features: Mapping[str, float]) -> HistoryRecord:
        rec = HistoryRecord(kernel=kernel, signature=signature,
                            workload=workload,
                            schedule_json=schedule.to_json(),
                            energy=float(energy),
                            improvement=float(improvement),
                            accepted=bool(accepted),
                            features=dict(features))
        self._records.append(rec)
        if len(self._records) > self.max_records:
            # drop oldest; recent traffic is what warm starts should mirror
            self._records = self._records[-self.max_records:]
        self.save()
        return rec

    # ------------------------------------------------------------- recall
    def warm_start(self, kernel: str, signature: str, space: SearchSpace,
                   features: Mapping[str, float]) -> Schedule | None:
        """The accepted schedule of the nearest tuned neighbor, as a legal
        warm start for ``space`` — or None when no compatible history exists.

        Only records whose knobs are a point of the TARGET space qualify
        (:meth:`SearchSpace.contains`); nearest feature distance among those
        wins, with an exact-signature record beating any neighbor.  The
        instruction order survives only on an exact signature match: orders
        index a specific program's instructions and would be silently
        re-defaulted (at best) against another shape's program.
        """
        best: HistoryRecord | None = None
        best_d = math.inf
        for rec in self._records:
            if rec.kernel != kernel or not rec.accepted:
                continue
            sched = Schedule.from_json(rec.schedule_json)
            if not space.contains(sched.knobs):
                continue
            d = -1.0 if rec.signature == signature \
                else feature_distance(features, rec.features)
            if d < best_d:
                best, best_d = rec, d
        if best is None:
            return None
        sched = Schedule.from_json(best.schedule_json)
        if best.signature != signature:
            sched = dataclasses.replace(sched, order=None)
        return sched

    def greed_for(self, kernel: str, default: float = 0.5) -> float:
        """Guided-policy greed fitted on this kernel's accepted runs."""
        return fit_greed([r.improvement for r in self._records
                          if r.kernel == kernel and r.accepted],
                         default=default)

    # ---------------------------------------------------------------- io
    def save(self) -> None:
        if not self.path:
            return
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".siphist")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": HISTORY_VERSION,
                           "records": [r.to_dict() for r in self._records]},
                          f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
