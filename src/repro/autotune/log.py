"""Autotune event journal — the decision audit trail (stdlib-only).

Every consequential decision the always-on service makes — a tuning round, a
promotion, a quarantine, an eviction — lands as one JSONL line, so operators
(and CI) can answer "what did the autotuner do, and why" without attaching a
debugger to a live server.  ``launch/obsreport.py --kind autotune`` renders
and ``--validate``\\ s this file; keeping the module stdlib-only (like the
rest of ``repro.obs``) means that report path never imports jax.

Schema: every event carries ``t`` (epoch seconds) and ``kind``; each kind
adds its own required fields (:data:`PER_KIND`).  Extra fields are always
allowed — the schema is a floor, not a ceiling.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Iterable

#: every event kind the service emits, with that kind's required fields
PER_KIND: dict[str, dict[str, type | tuple[type, ...]]] = {
    # one per run_once(): the cycle's aggregate outcome
    "cycle": {"cycle": int, "candidates": int, "tuned": int, "promoted": int,
              "quarantined": int},
    # one per tuned (kernel, workload): the search ran, whatever the verdict
    "tuned": {"kernel": str, "workload": str, "energy": (int, float)},
    # gate verdicts
    "promoted": {"kernel": str, "workload": str, "signature": str,
                 "schedule_sig": str, "energy": (int, float)},
    "quarantined": {"kernel": str, "workload": str, "schedule_sig": str,
                    "reason": str},
    "rejected": {"kernel": str, "workload": str, "reason": str},
    # history warm start actually seeded a search
    "warm_start": {"kernel": str, "workload": str},
    # a tuned key's traffic share decayed below the floor
    "evicted": {"kernel": str, "signature": str, "dropped": int},
    # a candidate failed outside the gate (adapter/registry errors)
    "error": {"error": str},
}

KINDS = frozenset(PER_KIND)


class EventLog:
    """Append-only JSONL event journal.

    ``path=None`` keeps events in memory only (tests, dry runs); with a path
    every emit appends one line and flushes, so a crashed service leaves a
    complete journal up to its last decision.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self.events: list[dict[str, Any]] = []
        self._file = None
        if path:
            d = os.path.dirname(os.path.abspath(path))
            os.makedirs(d, exist_ok=True)
            self._file = open(path, "a")

    def emit(self, kind: str, **fields: Any) -> dict[str, Any]:
        if kind not in KINDS:
            raise ValueError(f"unknown autotune event kind {kind!r}; "
                             f"known: {sorted(KINDS)}")
        ev = {"t": round(time.time(), 3), "kind": kind, **fields}
        self.events.append(ev)
        if self._file is not None:
            self._file.write(json.dumps(ev) + "\n")
            self._file.flush()
        return ev

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def load_events(path: str) -> list[dict[str, Any]]:
    """Parse an event JSONL.  Raises ``ValueError`` on a non-JSON line —
    unlike the recorder tail, a torn decision journal should be loud."""
    events = []
    with open(path) as f:
        for i, line in enumerate(f):
            if not line.strip():
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: invalid JSON ({e})") from e
    return events


def validate_events(events: Iterable[dict[str, Any]]) -> list[str]:
    """Schema-check a sequence of events; returns human-readable violations
    (empty = valid).  The CI autotune-smoke job gates on this."""
    errors: list[str] = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object: {ev!r}")
            continue
        kind = ev.get("kind")
        if kind not in KINDS:
            errors.append(f"event {i}: bad kind {kind!r}")
            continue
        if not isinstance(ev.get("t"), (int, float)):
            errors.append(f"event {i} ({kind}): bad 't': {ev.get('t')!r}")
        for field, ty in PER_KIND[kind].items():
            if not isinstance(ev.get(field), ty):
                errors.append(f"event {i} ({kind}): bad {field!r}: "
                              f"{ev.get(field)!r}")
    return errors
