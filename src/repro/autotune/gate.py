"""Promotion gate — no candidate reaches the live store unverified.

The paper validates every SIP-optimized schedule with a massive random-input
sweep before deployment (§4.2); ``launch/verify.py`` is that sweep at CI
scale.  This module points the same sweep at a CANDIDATE schedule *before*
promotion: the always-on service may only commit a schedule into the live
:class:`~repro.core.cache.ScheduleCache` if it

1. is not already quarantined for this (kernel, workload),
2. beats the incumbent's energy by a configurable margin (energies are
   analytic cost-model values, so they compare across sessions), and
3. passes the probabilistic correctness sweep built directly from the
   candidate (bypassing cache resolution — the incumbent keeps serving while
   the candidate is on trial).

A candidate that fails the sweep is quarantined in the service's
:class:`~repro.tuning.state.SearchState` journal — the same per-workload
quarantine crash-safe tuning uses — so no later search ever re-proposes or
re-promotes it.
"""

from __future__ import annotations

import dataclasses

from repro.core.cache import ScheduleCache
from repro.core.registry import KernelSpec, Workload
from repro.core.schedule import Schedule
from repro.launch.verify import verify_workload
from repro.tuning.state import SearchState


def incumbent_energy(cache: ScheduleCache, kernel: str,
                     signature: str) -> float | None:
    """Energy of the schedule currently serving this (kernel, signature) —
    the best passing entry — or None when the key is untuned (the default
    schedule serves)."""
    passing = [e for e in cache.entries(kernel, signature) if e.tests_passed]
    return min(e.energy for e in passing) if passing else None


@dataclasses.dataclass(frozen=True)
class GateDecision:
    """The gate's verdict on one candidate, journal-ready."""

    kernel: str
    workload: str
    signature: str
    schedule_sig: str
    promoted: bool
    reason: str                    # "promoted" | "quarantined_prior" |
    #                                "insufficient_margin" | "verify_failed"
    candidate_energy: float
    incumbent_energy: float | None = None
    samples: int = 0
    max_err: float = 0.0


class PromotionGate:
    """Safety gate between the shadow search and the live store.

    ``margin`` is the relative energy improvement a candidate must show over
    the incumbent (0.02 = at least 2% better); untuned keys have no
    incumbent, so any verified candidate promotes.  ``state`` (optional)
    persists quarantines across restarts.
    """

    def __init__(self, live: ScheduleCache, *, margin: float = 0.01,
                 samples: int = 8, seed: int = 0,
                 state: SearchState | None = None):
        if margin < 0:
            raise ValueError(f"margin must be >= 0, got {margin}")
        if samples < 1:
            raise ValueError(f"samples must be >= 1, got {samples}")
        self.live = live
        self.margin = margin
        self.samples = samples
        self.seed = seed
        self.state = state

    def _quarantine(self, kernel: str, workload: str,
                    schedule_sig: str) -> None:
        if self.state is None:
            return
        sigs = self.state.quarantine_for(kernel, workload)
        sigs.add(schedule_sig)
        self.state.save_quarantine(kernel, workload, sigs)

    def evaluate(self, spec: KernelSpec, workload: Workload,
                 signature: str, schedule: Schedule,
                 energy: float) -> GateDecision:
        """Gate one candidate; never mutates the live store (the service
        batches promoted decisions into ONE :meth:`ScheduleCache.commit`)."""
        ssig = schedule.signature()
        verdict = dict(kernel=spec.name, workload=workload.name,
                       signature=signature, schedule_sig=ssig,
                       candidate_energy=float(energy))
        # 1) a schedule already quarantined for this workload never promotes,
        #    whatever its energy claims — it crashed, timed out, or failed
        #    verification before
        if self.state is not None and \
                ssig in self.state.quarantine_for(spec.name, workload.name):
            return GateDecision(promoted=False, reason="quarantined_prior",
                                **verdict)
        # 2) energy margin vs the incumbent (analytic energies — comparable)
        inc = incumbent_energy(self.live, spec.name, signature)
        verdict["incumbent_energy"] = inc
        if inc is not None and not energy < inc * (1.0 - self.margin):
            return GateDecision(promoted=False, reason="insufficient_margin",
                                **verdict)
        # 3) the paper's pre-deployment correctness sweep, on the candidate
        #    itself (the incumbent keeps serving while this runs)
        res = verify_workload(spec, workload, samples=self.samples,
                              seed=self.seed, schedule=schedule)
        verdict.update(samples=int(res["samples"]),
                       max_err=float(res["max_err"]))
        if not res["passed"]:
            self._quarantine(spec.name, workload.name, ssig)
            return GateDecision(promoted=False, reason="verify_failed",
                                **verdict)
        return GateDecision(promoted=True, reason="promoted", **verdict)
