"""repro.autotune — always-on autotuning from live serving traffic.

Closes the record -> tune -> verify -> deploy loop inside one running
deployment: an :class:`AutotuneService` drains the live workload mix, tunes
the busiest shapes in a shadow store, gates every candidate through the
probabilistic correctness sweep plus an energy margin
(:class:`PromotionGate`), and commits survivors to the live
:class:`~repro.core.cache.ScheduleCache` in one atomic batch — running
engines hot-swap schedules on their next step, no restart.

Cross-session memory lives in :class:`TuneHistory` (warm starts from the
nearest tuned neighbor, fitted guided-search greed); every decision is
journaled via :class:`EventLog` for ``launch/obsreport.py --kind autotune``.

Exports resolve lazily so jax-free consumers (``obsreport`` validating an
event journal via :mod:`repro.autotune.log`) never pay for the service's
jax-backed modules.
"""

from __future__ import annotations

import importlib

_EXPORTS = {
    "TuneTarget": "repro.autotune.adapters",
    "serve_targets": "repro.autotune.adapters",
    "GateDecision": "repro.autotune.gate",
    "PromotionGate": "repro.autotune.gate",
    "incumbent_energy": "repro.autotune.gate",
    "TuneHistory": "repro.autotune.history",
    "feature_distance": "repro.autotune.history",
    "features_of": "repro.autotune.history",
    "EventLog": "repro.autotune.log",
    "load_events": "repro.autotune.log",
    "validate_events": "repro.autotune.log",
    "AutotuneConfig": "repro.autotune.service",
    "AutotuneService": "repro.autotune.service",
    "WorkloadDistribution": "repro.autotune.service",
    "jsonl_source": "repro.autotune.service",
    "recorder_source": "repro.autotune.service",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__() -> list[str]:
    return __all__
