"""AutotuneService — always-on tuning from live traffic (ROADMAP north star).

The offline story so far: record serving traffic, replay it into a tuning
session, restart the server on the new cache.  This module closes the loop
inside one deployment, no restart:

1. **drain** — a worker thread periodically snapshots the live
   :class:`~repro.obs.recorder.WorkloadRecorder` stream (or tails another
   process's recorder JSONL) into a drift-aware :class:`WorkloadDistribution`
   — per-key traffic counts, staleness-decayed by a half-life so yesterday's
   burst does not outrank the shape serving right now.
2. **prioritize** — each key maps through an adapter
   (:mod:`repro.autotune.adapters`) to the SIP kernel behind it; candidates
   rank by ``traffic share x energy headroom`` (incumbent energy over the
   default schedule's — untuned busy shapes first), decayed by how many
   rounds the key has already been tuned.
3. **search** — the top candidates get one incremental
   :meth:`TuningSession.run_workload` round each against a SHADOW
   :class:`ScheduleCache` — never the live store — warm-started from the
   cross-session :class:`~repro.autotune.history.TuneHistory` and searched
   with its fitted guided policy.
4. **gate & promote** — every shadow winner faces the
   :class:`~repro.autotune.gate.PromotionGate` (quarantine check, energy
   margin, probabilistic correctness sweep).  The cycle's survivors land in
   the live store as ONE :meth:`ScheduleCache.commit` — one version bump —
   and running engines pick them up on their next step
   (``ContinuousEngine._maybe_refresh_schedules``), restart-free.
5. **evict** — tuned keys whose traffic share decays below a floor are
   dropped from the live store; the engine falls back to the default
   schedule and the store stops accumulating dead shapes.

Every decision lands in the :class:`~repro.autotune.log.EventLog` journal
and the ``autotune.*`` metrics, so ``launch/obsreport.py --kind autotune``
can reconstruct what the service did and why.
"""

from __future__ import annotations

import dataclasses
import json
import threading
from typing import Callable, Mapping

import numpy as np

from repro.autotune.adapters import TuneTarget
from repro.autotune.gate import GateDecision, PromotionGate, incumbent_energy
from repro.autotune.history import TuneHistory, features_of
from repro.autotune.log import EventLog
from repro.core import energy as energy_mod
from repro.core.cache import PendingPut, ScheduleCache
from repro.core.jit import TuneConfig
from repro.core.registry import KernelRegistry, registry, workload_seed
from repro.core.schedule import Schedule
from repro.obs import metrics as obs_metrics
from repro.obs.recorder import WorkloadKey, WorkloadRecorder, tail_jsonl
from repro.tuning.session import TuningSession
from repro.tuning.state import SearchState

#: the service's metric names, registered eagerly so a snapshot shows zeros
#: rather than missing keys for quiet services
_COUNTERS = ("cycles", "tuned", "promotions", "quarantines", "rejections",
             "warm_start_hits", "evictions", "errors")


def _fast_tune_config(seed: int = 0) -> TuneConfig:
    """Default per-cycle search budget: ONE short guided round.  The service
    accumulates rounds across cycles in its shadow store, so each cycle's
    search can stay cheap without capping how far a hot key ever gets."""
    return TuneConfig(rounds=1, t_max=1.0, t_min=0.1, cooling=1.2,
                      step_samples=1, final_samples=4, guided=True,
                      seed=seed)


@dataclasses.dataclass
class AutotuneConfig:
    interval_s: float = 10.0       # worker cycle period
    budget: int = 2                # workloads tuned per cycle
    margin: float = 0.01           # relative energy win required to promote
    samples: int = 8               # correctness-sweep samples per candidate
    half_life_s: float = 120.0     # traffic staleness half-life
    share_floor: float = 0.01      # evict promoted keys decaying below this
    max_rounds: int = 8            # stop re-tuning a key after this many
    seed: int = 0
    tune: TuneConfig = dataclasses.field(
        default_factory=lambda: _fast_tune_config())

    def validate(self) -> "AutotuneConfig":
        if self.interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {self.interval_s}")
        if self.budget < 1:
            raise ValueError(f"budget must be >= 1, got {self.budget}")
        if self.half_life_s <= 0:
            raise ValueError(f"half_life_s must be > 0, got "
                             f"{self.half_life_s}")
        if not 0 <= self.share_floor < 1:
            raise ValueError(f"share_floor must be in [0, 1), got "
                             f"{self.share_floor}")
        return self


class WorkloadDistribution:
    """Drift-aware view of the live mix: cumulative per-key counts with
    last-seen times, staleness-weighted into shares.

    ``update`` takes a CUMULATIVE snapshot (``WorkloadRecorder.
    mix_snapshot``-shaped: key -> (count, last_t)); counts only move forward,
    so re-delivery of an old snapshot can never un-count traffic.
    """

    def __init__(self, half_life_s: float = 120.0):
        self.half_life_s = half_life_s
        self._counts: dict[WorkloadKey, int] = {}
        self._last_t: dict[WorkloadKey, float] = {}

    def update(self, snapshot: Mapping[WorkloadKey, tuple[int, float]]) -> None:
        for key, (count, last_t) in snapshot.items():
            if count > self._counts.get(key, 0):
                self._counts[key] = int(count)
            if last_t > self._last_t.get(key, -1.0):
                self._last_t[key] = float(last_t)

    def weights(self, now: float) -> dict[WorkloadKey, float]:
        """count x 0.5^(staleness / half_life) per key — the raw (unshared)
        drift-aware mass."""
        out = {}
        for key, count in self._counts.items():
            age = max(0.0, now - self._last_t.get(key, 0.0))
            out[key] = count * 0.5 ** (age / self.half_life_s)
        return out

    def shares(self, now: float) -> dict[WorkloadKey, float]:
        """Normalized staleness-weighted traffic shares (sum to 1.0, or
        empty when nothing has been observed)."""
        w = self.weights(now)
        total = sum(w.values())
        if total <= 0:
            return {}
        return {k: v / total for k, v in w.items()}

    def __len__(self) -> int:
        return len(self._counts)


# ------------------------------------------------------------------ sources
def recorder_source(recorder: WorkloadRecorder
                    ) -> Callable[[], tuple[dict, float]]:
    """In-process drain: the engine's own recorder, snapshotted live."""
    return lambda: (recorder.mix_snapshot(), recorder.clock)


def jsonl_source(path: str) -> Callable[[], tuple[dict, float]]:
    """Cross-process drain: tail another process's ``--record-workloads``
    JSONL (byte-offset resume, partial trailing lines left unconsumed) and
    aggregate it into the same cumulative snapshot shape.  ``now`` is the
    stream's own clock (max record t), so staleness is measured in the
    producer's timebase."""
    state = {"offset": 0, "now": 0.0}
    counts: dict[WorkloadKey, int] = {}
    last_t: dict[WorkloadKey, float] = {}

    def source() -> tuple[dict, float]:
        records, state["offset"] = tail_jsonl(path, state["offset"])
        for rec in records:
            try:
                key = WorkloadKey(kind=str(rec["kind"]),
                                  prompt_len=int(rec.get("prompt_len", 0)),
                                  batch=int(rec.get("batch", 1)),
                                  dtype=str(rec.get("dtype", "int32")))
                t = float(rec.get("t", 0.0))
            except (KeyError, TypeError, ValueError):
                continue
            counts[key] = counts.get(key, 0) + 1
            last_t[key] = max(last_t.get(key, 0.0), t)
            state["now"] = max(state["now"], t)
        return ({k: (n, last_t[k]) for k, n in counts.items()}, state["now"])

    return source


class AutotuneService:
    """The always-on background tuner (see module docstring).

    ``live`` is the deployment's ScheduleCache — the store serving engines
    resolve from; promotions commit there.  ``source`` yields
    ``(cumulative mix snapshot, now)`` (:func:`recorder_source` /
    :func:`jsonl_source`); ``target_for`` maps live keys to tunable targets
    (:func:`repro.autotune.adapters.serve_targets`).

    The worker thread holds explicit references to every store — worker
    threads do not inherit the ``schedule_cache`` contextvar scope, and must
    not depend on it.
    """

    def __init__(self, live: ScheduleCache, *,
                 source: Callable[[], tuple[dict, float]],
                 target_for: Callable[[WorkloadKey], TuneTarget | None],
                 config: AutotuneConfig | None = None,
                 history: TuneHistory | None = None,
                 state: SearchState | None = None,
                 log: EventLog | None = None,
                 obs: obs_metrics.MetricsRegistry | None = None,
                 registry_: KernelRegistry | None = None):
        self.live = live
        self.source = source
        self.target_for = target_for
        self.config = (config if config is not None
                       else AutotuneConfig()).validate()
        self.history = history if history is not None else TuneHistory()
        self.state = state
        self.log = log if log is not None else EventLog()
        self.obs = obs if obs is not None else obs_metrics.MetricsRegistry()
        self.registry = registry_ if registry_ is not None else registry
        self.gate = PromotionGate(live, margin=self.config.margin,
                                  samples=self.config.samples,
                                  seed=self.config.seed, state=state)
        self.dist = WorkloadDistribution(self.config.half_life_s)
        # shadow store: every search round lands here; only gated winners are
        # ever committed to `live`.  One session so kernel instances (and
        # their build caches) persist across cycles.
        self.shadow = ScheduleCache()
        self.session = TuningSession(self.shadow, self.config.tune,
                                     registry_=self.registry, state=state)
        self._c = {name: self.obs.counter(f"autotune.{name}")
                   for name in _COUNTERS}
        self._rounds: dict[WorkloadKey, int] = {}
        # key -> (kernel, signature) we promoted, for share-floor eviction
        self._promoted: dict[WorkloadKey, tuple[str, str]] = {}
        # (sig, static, space, features, default energy) per key
        self._info: dict[WorkloadKey, tuple] = {}
        self._bad_keys: set[WorkloadKey] = set()
        self._cycle = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        if self._thread is not None:
            raise RuntimeError("AutotuneService already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="autotune", daemon=True)
        self._thread.start()

    def stop(self, timeout: float | None = 30.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def _loop(self) -> None:
        # run immediately (smoke runs should not wait a full interval for
        # their first cycle), then on the period until stopped
        while True:
            try:
                self.run_once()
            except Exception as e:  # keep the service alive; journal it
                self._c["errors"].inc()
                self.log.emit("error", error=f"{type(e).__name__}: {e}"[:500])
            if self._stop.wait(self.config.interval_s):
                return

    # ---------------------------------------------------------- one cycle
    def _key_info(self, key: WorkloadKey, tgt: TuneTarget):
        """(signature, static, space, features, default energy) for a live
        key — derived once from the workload's deterministic example args."""
        info = self._info.get(key)
        if info is None:
            spec = self.registry.spec(tgt.kernel)
            seed = workload_seed(tgt.kernel, tgt.workload.name,
                                 self.config.tune.seed)
            example = list(tgt.workload.make_args(
                np.random.default_rng(seed)))
            static = spec.signature_fn(*example)
            sig = json.dumps(static, sort_keys=True)
            space = spec.space_for(**static)
            feats = features_of(static)
            default = Schedule(knobs=space.default_knobs())
            e_default = energy_mod.CostModelEnergy(
                lambda s: spec.program_for(s, **static))(default)
            info = self._info[key] = (sig, static, space, feats, e_default)
        return info

    def _priority(self, key: WorkloadKey, tgt: TuneTarget,
                  share: float) -> float:
        """share x energy headroom / (1 + rounds tuned).

        Headroom is the incumbent's energy relative to the default
        schedule's: an untuned key scores 1.0 (all the headroom), a
        well-tuned one scores its achieved ratio — so busy untuned shapes
        outrank shapes the service has already squeezed, and every key's
        priority decays as rounds accumulate."""
        sig, _, _, _, e_default = self._key_info(key, tgt)
        inc = incumbent_energy(self.live, tgt.kernel, sig)
        headroom = 1.0 if inc is None or e_default <= 0 \
            else min(1.0, inc / e_default)
        return share * headroom / (1.0 + self._rounds.get(key, 0))

    def _tune_and_gate(self, key: WorkloadKey,
                       tgt: TuneTarget) -> GateDecision | None:
        """One incremental search round for ``key`` + the gate's verdict.
        Returns None when the search produced no passing candidate."""
        spec = self.registry.spec(tgt.kernel)
        sig, _, space, feats, _ = self._key_info(key, tgt)
        x0 = self.history.warm_start(tgt.kernel, sig, space, feats)
        if x0 is not None:
            self._c["warm_start_hits"].inc()
            self.log.emit("warm_start", kernel=tgt.kernel,
                          workload=tgt.workload.name,
                          knobs=dict(x0.knobs))
        # fitted policy: greed per kernel from accumulated accepted history
        cfg_t = dataclasses.replace(
            self.config.tune,
            greed=self.history.greed_for(tgt.kernel,
                                         default=self.config.tune.greed))
        self.session.config = cfg_t
        run = self.session.run_workload(tgt.kernel, tgt.workload, x0=x0)
        self._rounds[key] = self._rounds.get(key, 0) + 1
        self._c["tuned"].inc()
        self.log.emit("tuned", kernel=tgt.kernel, workload=tgt.workload.name,
                      energy=run.best_energy, rounds=self._rounds[key],
                      warm_started=x0 is not None)
        candidate = self.shadow.best(tgt.kernel, run.signature)
        if candidate is None:
            return None
        cand_energy = incumbent_energy(self.shadow, tgt.kernel, run.signature)
        decision = self.gate.evaluate(spec, tgt.workload, run.signature,
                                      candidate, cand_energy)
        self.history.record(kernel=tgt.kernel, signature=run.signature,
                            workload=tgt.workload.name, schedule=candidate,
                            energy=cand_energy, improvement=run.improvement,
                            accepted=decision.promoted, features=feats)
        return decision

    def run_once(self) -> dict:
        """One full cycle: drain -> prioritize -> search -> gate -> commit ->
        evict.  Synchronous (the daemon and tests call it directly); the
        worker thread runs it on the interval."""
        self._cycle += 1
        snapshot, now = self.source()
        self.dist.update(snapshot)
        shares = self.dist.shares(now)

        ranked: list[tuple[float, WorkloadKey, TuneTarget]] = []
        for key, share in shares.items():
            if key in self._bad_keys or \
                    self._rounds.get(key, 0) >= self.config.max_rounds:
                continue
            try:
                tgt = self.target_for(key)
                if tgt is None:
                    self._bad_keys.add(key)
                    continue
                ranked.append((self._priority(key, tgt, share), key, tgt))
            except Exception as e:
                # a key the adapter/registry cannot serve must not wedge the
                # cycle — journal and never retry it
                self._bad_keys.add(key)
                self._c["errors"].inc()
                self.log.emit("error", key=key.name,
                              error=f"{type(e).__name__}: {e}"[:500])
        ranked.sort(key=lambda item: -item[0])

        staged: list[PendingPut] = []
        decisions: list[GateDecision] = []
        tuned = 0
        for _, key, tgt in ranked[:self.config.budget]:
            try:
                decision = self._tune_and_gate(key, tgt)
            except Exception as e:
                self._c["errors"].inc()
                self.log.emit("error", key=key.name,
                              error=f"{type(e).__name__}: {e}"[:500])
                continue
            tuned += 1
            if decision is None:
                self._c["rejections"].inc()
                self.log.emit("rejected", kernel=tgt.kernel,
                              workload=tgt.workload.name,
                              reason="no_passing_candidate")
                continue
            decisions.append(decision)
            if decision.promoted:
                staged.append(PendingPut(
                    kernel_name=decision.kernel,
                    signature=decision.signature,
                    schedule=Schedule.from_json(decision.schedule_sig),
                    energy=decision.candidate_energy, tests_passed=True,
                    test_samples=decision.samples, round_id=self._cycle,
                    meta={"autotune": True, "workload": decision.workload,
                          "incumbent_energy": decision.incumbent_energy}))
                self._promoted[key] = (decision.kernel, decision.signature)
                self._c["promotions"].inc()
                self.log.emit("promoted", kernel=decision.kernel,
                              workload=decision.workload,
                              signature=decision.signature,
                              schedule_sig=decision.schedule_sig,
                              energy=decision.candidate_energy,
                              incumbent_energy=decision.incumbent_energy,
                              samples=decision.samples)
            elif decision.reason == "verify_failed":
                self._c["quarantines"].inc()
                self.log.emit("quarantined", kernel=decision.kernel,
                              workload=decision.workload,
                              schedule_sig=decision.schedule_sig,
                              reason=decision.reason,
                              max_err=decision.max_err)
            else:
                self._c["rejections"].inc()
                self.log.emit("rejected", kernel=decision.kernel,
                              workload=decision.workload,
                              reason=decision.reason,
                              energy=decision.candidate_energy,
                              incumbent_energy=decision.incumbent_energy)

        # one commit = one version bump = one engine re-trace per cycle,
        # however many schedules promoted
        self.live.commit(staged)
        evicted = self._evict(shares)
        if self.state is not None and len(self.state.completed) > 256:
            # the journal's completed list only matters to tune-session
            # resumes; the service reuses the journal for quarantine, so
            # bound its growth over a long-running deployment
            self.state.completed = self.state.completed[-128:]
            self.state.save()

        quarantined = sum(1 for d in decisions
                          if d.reason == "verify_failed")
        self._c["cycles"].inc()
        summary = {"cycle": self._cycle, "candidates": len(ranked),
                   "tuned": tuned, "promoted": len(staged),
                   "quarantined": quarantined, "evicted": evicted,
                   "keys": len(self.dist)}
        self.log.emit("cycle", **summary)
        return summary

    def _evict(self, shares: Mapping[WorkloadKey, float]) -> int:
        """Retire promoted keys whose staleness-weighted share fell below
        the floor: their entries leave the live store (engines fall back to
        the default schedule on the next swap) and their round budget
        resets, so returning traffic re-earns its tuning."""
        evicted = 0
        for key in list(self._promoted):
            if shares.get(key, 0.0) >= self.config.share_floor:
                continue
            kernel, sig = self._promoted.pop(key)
            dropped = self.live.drop(kernel, sig)
            self._rounds.pop(key, None)
            if dropped:
                evicted += 1
                self._c["evictions"].inc()
                self.log.emit("evicted", kernel=kernel, signature=sig,
                              dropped=dropped, key=key.name)
        return evicted

    # ------------------------------------------------------------- surface
    def metrics(self) -> dict[str, float]:
        return {name: float(c.value) for name, c in self._c.items()}
