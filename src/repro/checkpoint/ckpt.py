"""Sharded checkpointing with integrity hashes, async save, and ELASTIC
restore (a checkpoint written on one mesh restores onto any other mesh).

Layout: ``<dir>/step_<n>/{arrays.npz, manifest.json}`` + ``<dir>/LATEST``.
Arrays are stored as full (unsharded) numpy buffers keyed by pytree path —
simple, host-filesystem portable, and mesh-independent by construction; the
restore path re-shards every leaf onto the *current* mesh's NamedShardings
(ZeRO-style resharding is therefore free).  For multi-host deployments each
host would write only the shards it owns; on this single-process container
the gather is a device_get.

Integrity: every array's SHA-256 is recorded in the manifest and verified on
restore; a truncated/corrupt checkpoint is detected and skipped, falling back
to the previous LATEST (crash-during-save safety: LATEST is flipped only
after a fully verified write).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Callable

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, blocking: bool = True) -> None:
        flat = _flatten(tree)          # device_get on the main thread
        if blocking:
            self._write(step, flat)
        else:
            self.wait()                # one async save in flight at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, flat), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict[str, np.ndarray]) -> None:
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "hashes": {k: _sha(v) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
        # LATEST flips only after a complete, verifiable write
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(path))
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        latest = os.path.join(self.dir, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                name = f.read().strip()
            if os.path.exists(os.path.join(self.dir, name)):
                return int(name.split("_")[1])
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify(self, step: int) -> bool:
        path = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            with np.load(os.path.join(path, "arrays.npz")) as z:
                for k, h in manifest["hashes"].items():
                    if _sha(z[k]) != h:
                        return False
            return True
        except Exception:
            return False

    def restore(self, step: int, template: Any,
                shardings: Any | None = None) -> Any:
        """Restore onto ``template``'s structure.  With ``shardings`` (a
        matching NamedSharding tree for the CURRENT mesh) every leaf is
        device_put with its new sharding — elastic re-meshing."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        if not self.verify(step):
            raise IOError(f"checkpoint {path} failed integrity verification")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
            if shardings is not None else [None] * len(leaves_p))
        out = []
        for (path_k, leaf), sh in zip(leaves_p, shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path_k)
            if key not in flat:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = flat[key].astype(leaf.dtype) if hasattr(leaf, "dtype") else flat[key]
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, template: Any, shardings: Any | None = None,
                       on_corrupt: Callable[[int], None] | None = None):
        """Restore the newest verifiable checkpoint (skipping corrupt ones).
        Returns (step, tree) or (None, None)."""
        for step in reversed(self.all_steps()):
            if self.verify(step):
                return step, self.restore(step, template, shardings)
            if on_corrupt:
                on_corrupt(step)
        return None, None
