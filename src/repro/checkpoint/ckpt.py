"""Sharded checkpointing with integrity hashes, async save, and ELASTIC
restore (a checkpoint written on one mesh restores onto any other mesh).

Layout: ``<dir>/step_<n>/{arrays.npz, manifest.json}`` + ``<dir>/LATEST``.
Arrays are stored as full (unsharded) numpy buffers keyed by pytree path —
simple, host-filesystem portable, and mesh-independent by construction; the
restore path re-shards every leaf onto the *current* mesh's NamedShardings
(ZeRO-style resharding is therefore free).  For multi-host deployments each
host would write only the shards it owns; on this single-process container
the gather is a device_get.

Integrity: every array's SHA-256 is recorded in the manifest and verified on
restore; a truncated/corrupt checkpoint is detected and skipped, falling back
to the previous LATEST (crash-during-save safety: LATEST is flipped only
after a fully verified write).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _snapshot(tree: Any) -> Any:
    """Donated-safe async snapshot of ``tree``.

    Every jax leaf becomes a fresh device buffer (``jnp.copy`` — dispatched
    asynchronously, and owned only by the checkpointer, so the train loop is
    free to donate the originals to the next step) and its device-to-host
    transfer is kicked off immediately (``copy_to_host_async``).  Nothing
    here blocks: the host-side materialization happens on the writer thread.
    """
    snap = jax.tree.map(
        lambda x: jnp.copy(x) if isinstance(x, jax.Array) else x, tree)
    for leaf in jax.tree_util.tree_leaves(snap):
        if hasattr(leaf, "copy_to_host_async"):
            leaf.copy_to_host_async()
    return snap


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._io_lock = threading.Lock()   # serializes _write + _gc

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, blocking: bool = True) -> float:
        """Persist ``tree`` as ``step``.  Returns the seconds the CALLER was
        blocked — for ``blocking=False`` that is only the time to join any
        previous in-flight save, snapshot the device buffers, and start the
        host transfer; hashing, serialization, and file I/O all overlap the
        caller's next steps on the writer thread."""
        reg = obs_metrics.active_registry()
        t0 = time.perf_counter()
        if blocking:
            self._write(step, _flatten(tree))
        else:
            self.wait()                # one async save in flight at a time
            snap = _snapshot(tree)     # donated-safe, transfer in flight
            self._thread = threading.Thread(
                target=self._write, args=(step,), kwargs={"snap": snap},
                daemon=True)
            self._thread.start()
        blocked = time.perf_counter() - t0
        reg.counter("ckpt.saves").inc()
        reg.histogram("ckpt.save_block_s").record(blocked)
        return blocked

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict[str, np.ndarray] | None = None,
               snap: Any = None) -> None:
        if flat is None:               # async path: materialize on this thread
            flat = _flatten(snap)
        with self._io_lock:
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            manifest = {
                "step": step,
                "hashes": {k: _sha(v) for k, v in flat.items()},
                "shapes": {k: list(v.shape) for k, v in flat.items()},
                "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.replace(tmp, path)
            # LATEST flips only after a complete, verifiable write
            latest_tmp = os.path.join(self.dir, "LATEST.tmp")
            with open(latest_tmp, "w") as f:
                f.write(os.path.basename(path))
            os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
            self._gc()

    def _gc(self) -> None:
        """Delete old steps, but never the only *verified* checkpoint.

        If none of the ``keep`` newest steps passes verification (e.g. the
        newest write was corrupted on disk), the newest verified older step
        is retained — GC must not leave the directory unrestorable.  The
        common case verifies only the just-written step (short-circuit)."""
        steps = sorted(self.all_steps())
        doomed = steps[:-self.keep] if self.keep > 0 else list(steps)
        if not doomed:
            return
        kept = steps[len(doomed):]
        if not any(self.verify(s) for s in reversed(kept)):
            for s in reversed(doomed):
                if self.verify(s):
                    doomed = [d for d in doomed if d != s]
                    break
        for s in doomed:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    continue           # stray file racing the async writer
        return sorted(out)

    def latest_step(self) -> int | None:
        latest = os.path.join(self.dir, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                name = f.read().strip()
            if os.path.exists(os.path.join(self.dir, name)):
                try:
                    return int(name.split("_")[1])
                except (IndexError, ValueError):
                    pass
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify(self, step: int) -> bool:
        path = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            with np.load(os.path.join(path, "arrays.npz")) as z:
                for k, h in manifest["hashes"].items():
                    if _sha(z[k]) != h:
                        return False
            return True
        except Exception:
            return False

    def restore(self, step: int, template: Any,
                shardings: Any | None = None) -> Any:
        """Restore onto ``template``'s structure.  With ``shardings`` (a
        matching NamedSharding tree for the CURRENT mesh) every leaf is
        device_put with its new sharding — elastic re-meshing."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        if not self.verify(step):
            raise IOError(f"checkpoint {path} failed integrity verification")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
            if shardings is not None else [None] * len(leaves_p))
        out = []
        for (path_k, leaf), sh in zip(leaves_p, shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path_k)
            if key not in flat:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = flat[key].astype(leaf.dtype) if hasattr(leaf, "dtype") else flat[key]
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, template: Any, shardings: Any | None = None,
                       on_corrupt: Callable[[int], None] | None = None):
        """Restore the newest verifiable checkpoint (skipping corrupt ones).
        Returns (step, tree) or (None, None)."""
        for step in reversed(self.all_steps()):
            if self.verify(step):
                return step, self.restore(step, template, shardings)
            if on_corrupt:
                on_corrupt(step)
        return None, None
