"""Deterministic synthetic data pipeline — sharded, stateless-resumable.

Every batch is a pure function of (seed, step): restart/elastic events need
no pipeline state beyond the step counter (checkpoint restores `step`, the
pipeline resumes exactly).  Token streams follow a Zipfian unigram mixture
with document structure (BOS-delimited segments) so losses are non-trivial.

At scale each host generates only its slice (`host_slice`); under pjit the
global batch is assembled via `jax.make_array_from_process_local_data` — on
this single-process container that reduces to a device_put.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    mean_doc_len: int = 256
    zipf_a: float = 1.2


def _rng_for(cfg: DataConfig, step: int, host: int = 0) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host]))


def batch_at(cfg: DataConfig, step: int, *, host: int = 0,
             n_hosts: int = 1) -> dict[str, np.ndarray]:
    """The (host-sliced) batch for ``step``.  tokens/labels: (B_host, S)."""
    assert cfg.global_batch % n_hosts == 0
    b = cfg.global_batch // n_hosts
    rng = _rng_for(cfg, step, host)
    # zipf unigrams, clipped into vocab; 0 reserved for BOS
    toks = rng.zipf(cfg.zipf_a, size=(b, cfg.seq_len + 1)) % (cfg.vocab - 1) + 1
    # document boundaries
    bos = rng.random((b, cfg.seq_len + 1)) < (1.0 / cfg.mean_doc_len)
    toks = np.where(bos, 0, toks).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
            "mask": np.ones((b, cfg.seq_len), np.float32)}


def batch_for_model(mcfg: ModelConfig, dcfg: DataConfig, step: int,
                    dtype=None) -> dict[str, jnp.ndarray]:
    """Model-aware batch (adds stub frontend embeddings where required)."""
    raw = batch_at(dcfg, step)
    dt = dtype or jnp.dtype(mcfg.dtype)
    rng = _rng_for(dcfg, step, host=10_000)
    out: dict[str, jnp.ndarray] = {
        "labels": jnp.asarray(raw["labels"]),
        "mask": jnp.asarray(raw["mask"]),
    }
    if mcfg.family == "enc_dec":
        out["tokens"] = jnp.asarray(raw["tokens"])
        out["enc_embeds"] = jnp.asarray(
            rng.standard_normal((dcfg.global_batch, mcfg.enc_len,
                                 mcfg.d_model)), dt)
    elif mcfg.input_mode == "embeddings":
        out["embeds"] = jnp.asarray(
            rng.standard_normal((dcfg.global_batch, dcfg.seq_len,
                                 mcfg.d_model)), dt)
    else:
        out["tokens"] = jnp.asarray(raw["tokens"])
    return out


class DataIterator:
    """Stateless-resumable iterator facade used by the train loop."""

    def __init__(self, mcfg: ModelConfig, dcfg: DataConfig, start_step: int = 0):
        self.mcfg, self.dcfg = mcfg, dcfg
        self.step = start_step

    def __next__(self):
        b = batch_for_model(self.mcfg, self.dcfg, self.step)
        self.step += 1
        return b

    def __iter__(self):
        return self
