"""TuningSession — the offline search orchestrator (paper §4.1 at fleet scale).

The paper runs SIP offline per kernel; production needs the search to run
uniformly over *many* kernels and deployment shapes.  A session iterates the
registry's declarative :class:`~repro.core.registry.Workload` suites,
derives a stable per-(kernel, workload) seed (tuning a subset, or
reordering, never changes another workload's inputs or trajectory), and
persists every result into ONE :class:`~repro.core.cache.ScheduleCache` that
deployment then activates via ``schedule_cache``.

With ``chains=1`` a session workload is bit-identical to calling
``SipKernel.tune`` directly with the same seed — the session adds
orchestration, not search behavior.

Crash safety: give the session a :class:`~repro.tuning.state.SearchState`
journal (or a path) and it records workload progress atomically next to the
cache.  A killed session re-run with ``resume=True`` skips completed
workloads, purges the in-flight workload's partial cache entries
(:meth:`ScheduleCache.drop`) and re-runs it from its deterministic seed, so
the resumed cache converges to exactly the uninterrupted result.  The
journal also persists each workload's quarantine (schedules whose evaluation
crashed or blew ``TuneConfig.eval_deadline_s``) so a resume never re-pays a
known-bad candidate's deadline.
"""

from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Any, Sequence

import numpy as np

from repro.core.cache import ScheduleCache
from repro.core.jit import TuneConfig
from repro.core.registry import (KernelRegistry, Workload, cache_for_path,
                                 registry, workload_seed)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.tuning.state import SearchState


class SimulatedCrash(RuntimeError):
    """Deterministic mid-session death for chaos tests and CI.

    Raised by ``die_after=N`` at the torn-state point of the N-th workload
    tuned this run: its cache entries are written but the journal still says
    ``in_progress`` — the worst case a real kill can leave behind, and
    exactly what the resume path's purge-and-rerun must recover from.
    ``launch/tune.py`` maps it to :data:`EXIT_CODE`.
    """

    EXIT_CODE = 3


@dataclasses.dataclass(frozen=True)
class WorkloadRun:
    """Outcome of tuning one (kernel, workload) pair."""

    kernel: str
    workload: str
    signature: str                 # SipKernel.sig_str of the example args
    seed: int                      # workload_seed(kernel, workload, base)
    results: tuple[Any, ...]       # AnnealResult per round
    best_energy: float

    @property
    def improvement(self) -> float:
        return max(r.improvement for r in self.results)


class TuningSession:
    """Orchestrates offline SIP search over registered kernels.

    ``cache`` is the single persistent store every tuned schedule lands in;
    ``config`` is the shared search configuration (its ``seed`` is the
    session base seed — each workload folds it into its own stable seed).

    ``state`` (a :class:`SearchState` or a path) enables crash-safe
    journaling; ``keep_going`` records a workload whose tuning raises in the
    journal's ``failed`` list and moves on instead of aborting the session;
    ``die_after`` injects a :class:`SimulatedCrash` for tests/CI.
    """

    def __init__(self, cache: ScheduleCache | str | None = None,
                 config: TuneConfig | None = None,
                 registry_: KernelRegistry | None = None, *,
                 state: SearchState | str | None = None,
                 keep_going: bool = False,
                 die_after: int | None = None):
        if isinstance(cache, str):
            cache = cache_for_path(cache)   # interned: serving scopes over
            #                                 the same path share this store
        self.cache = cache if cache is not None else ScheduleCache()
        self.config = (config if config is not None else TuneConfig()).validate()
        self.registry = registry_ if registry_ is not None else registry
        if isinstance(state, str):
            state = SearchState.load(state) or SearchState(path=state)
        self.state = state
        self.keep_going = keep_going
        self.die_after = die_after
        self.failures: list[dict[str, str]] = []
        self._tuned_this_run = 0
        # session-local instance memo: workloads of one kernel share an
        # instance (and its build caches) within the session, without
        # pinning per-session instances in the process-wide registry forever
        self._instances: dict[str, Any] = {}

    def _kernel(self, name: str):
        inst = self._instances.get(name)
        if inst is None:
            inst = self._instances[name] = \
                self.registry.spec(name).instantiate(cache=self.cache)
        return inst

    def _fingerprint(self, names: Sequence[str], suite: str) -> dict[str, Any]:
        # JSON round-trip so equality against the reloaded journal is exact
        return json.loads(json.dumps(
            {"suite": suite, "kernels": sorted(names),
             "config": dataclasses.asdict(self.config)}))

    def run(self, kernels: Sequence[str] | None = None,
            suite: str = "default", verbose: bool = False, *,
            resume: bool = False) -> list[WorkloadRun]:
        """Tune every workload of ``suite`` for ``kernels`` (default: every
        registered kernel).  Unknown kernel names raise before any tuning.

        With ``resume=True`` and a matching journal, completed workloads are
        skipped (and excluded from the returned list — only work performed
        by THIS call is returned) and the stale in-flight workload's partial
        cache entries are dropped before it re-runs.
        """
        names = list(kernels) if kernels else self.registry.names()
        plan: list[tuple[str, Workload]] = []
        for name in names:
            spec = self.registry.spec(name)      # raises on unknown kernel
            wls = spec.workloads_in(suite)
            if verbose and not wls:
                print(f"[session] {name}: no {suite!r} workloads, skipping")
            plan.extend((name, wl) for wl in wls)

        done: set[tuple[str, str]] = set()
        if self.state is not None:
            fp = self._fingerprint(names, suite)
            if resume and self.state.matches(fp):
                done = self.state.completed_keys()
                stale = self.state.in_progress
                if stale is not None:
                    dropped = self.cache.drop(stale["kernel"],
                                              stale["signature"])
                    obs_metrics.counter("ft.resume_purged").inc(dropped)
                    obs_trace.instant("ft.resume_purge", **stale,
                                      dropped=dropped)
                    if verbose:
                        print(f"[session] resume: purged {dropped} partial "
                              f"entries of {stale['kernel']} · "
                              f"{stale['workload']}")
                if verbose and done:
                    print(f"[session] resume: skipping {len(done)} "
                          f"completed workloads")
            else:
                if resume:
                    warnings.warn(
                        "TuningSession: journal fingerprint does not match "
                        "this run (different suite/kernels/config) — "
                        "starting fresh instead of resuming",
                        RuntimeWarning, stacklevel=2)
                self.state.completed = []
                self.state.failed = []
                self.state.in_progress = None
                self.state.quarantine = {}
            self.state.fingerprint = fp
            self.state.save()

        runs: list[WorkloadRun] = []
        for name, wl in plan:
            if (name, wl.name) in done:
                continue
            try:
                runs.append(self.run_workload(name, wl, verbose=verbose))
            except SimulatedCrash:
                raise
            except Exception as e:
                if not self.keep_going:
                    raise
                msg = f"{type(e).__name__}: {e}"
                self.failures.append({"kernel": name, "workload": wl.name,
                                      "error": msg})
                obs_metrics.counter("ft.workload_failed").inc()
                obs_trace.instant("ft.workload_failed", kernel=name,
                                  workload=wl.name, error=msg[:200])
                if self.state is not None:
                    self.state.mark_failed(name, wl.name, msg)
                if verbose:
                    print(f"[session] {name} · {wl.name} FAILED "
                          f"({msg}); continuing")
        return runs

    def run_workload(self, kernel: str, workload: Workload,
                     verbose: bool = False, *,
                     x0: Any | None = None) -> WorkloadRun:
        """Tune one (kernel, workload) pair, seeded independently of every
        other pair in the session.  ``x0`` (a :class:`Schedule`) warm-starts
        the search from a known-good neighbor instead of the space default —
        the autotune service's history seam; it must be compatible with the
        workload's knob space (``SipKernel.tune`` raises otherwise)."""
        seed = workload_seed(kernel, workload.name, self.config.seed)
        args = list(workload.make_args(np.random.default_rng(seed)))
        kern = self._kernel(kernel)
        sig = kern.sig_str(kern.static_of(*args))
        quarantine: set[str] | None = None
        if self.state is not None:
            quarantine = self.state.quarantine_for(kernel, workload.name)
            self.state.mark_in_progress(kernel, workload.name, sig)
        if verbose:
            print(f"[session] {kernel} · {workload.name} (seed={seed})")
        with obs_trace.span("tune.workload", kernel=kernel,
                            workload=workload.name, seed=seed) as sp:
            results = kern.tune(args,
                                dataclasses.replace(self.config, seed=seed),
                                verbose=verbose, quarantine=quarantine,
                                x0=x0)
            sp["best_energy"] = min(r.best_raw for r in results)
        obs_metrics.counter("tune.workloads").inc()
        best = min(r.best_raw for r in results)
        if self.state is not None and quarantine:
            self.state.save_quarantine(kernel, workload.name, quarantine)
        self._tuned_this_run += 1
        if self.die_after is not None and self._tuned_this_run >= self.die_after:
            # die at the torn-state point: cache entries durably written,
            # journal still in_progress (see SimulatedCrash docstring)
            raise SimulatedCrash(
                f"die_after={self.die_after}: simulated crash after tuning "
                f"{kernel} · {workload.name}")
        if self.state is not None:
            self.state.mark_completed(kernel, workload.name, signature=sig,
                                      seed=seed, best_energy=best)
        return WorkloadRun(kernel=kernel, workload=workload.name,
                           signature=sig, seed=seed, results=tuple(results),
                           best_energy=best)
