"""TuningSession — the offline search orchestrator (paper §4.1 at fleet scale).

The paper runs SIP offline per kernel; production needs the search to run
uniformly over *many* kernels and deployment shapes.  A session iterates the
registry's declarative :class:`~repro.core.registry.Workload` suites,
derives a stable per-(kernel, workload) seed (tuning a subset, or
reordering, never changes another workload's inputs or trajectory), and
persists every result into ONE :class:`~repro.core.cache.ScheduleCache` that
deployment then activates via ``schedule_cache``.

With ``chains=1`` a session workload is bit-identical to calling
``SipKernel.tune`` directly with the same seed — the session adds
orchestration, not search behavior.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

from repro.core.cache import ScheduleCache
from repro.core.jit import TuneConfig
from repro.core.registry import (KernelRegistry, Workload, cache_for_path,
                                 registry, workload_seed)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class WorkloadRun:
    """Outcome of tuning one (kernel, workload) pair."""

    kernel: str
    workload: str
    signature: str                 # SipKernel.sig_str of the example args
    seed: int                      # workload_seed(kernel, workload, base)
    results: tuple[Any, ...]       # AnnealResult per round
    best_energy: float

    @property
    def improvement(self) -> float:
        return max(r.improvement for r in self.results)


class TuningSession:
    """Orchestrates offline SIP search over registered kernels.

    ``cache`` is the single persistent store every tuned schedule lands in;
    ``config`` is the shared search configuration (its ``seed`` is the
    session base seed — each workload folds it into its own stable seed).
    """

    def __init__(self, cache: ScheduleCache | str | None = None,
                 config: TuneConfig | None = None,
                 registry_: KernelRegistry | None = None):
        if isinstance(cache, str):
            cache = cache_for_path(cache)   # interned: serving scopes over
            #                                 the same path share this store
        self.cache = cache if cache is not None else ScheduleCache()
        self.config = (config if config is not None else TuneConfig()).validate()
        self.registry = registry_ if registry_ is not None else registry
        # session-local instance memo: workloads of one kernel share an
        # instance (and its build caches) within the session, without
        # pinning per-session instances in the process-wide registry forever
        self._instances: dict[str, Any] = {}

    def _kernel(self, name: str):
        inst = self._instances.get(name)
        if inst is None:
            inst = self._instances[name] = \
                self.registry.spec(name).instantiate(cache=self.cache)
        return inst

    def run(self, kernels: Sequence[str] | None = None,
            suite: str = "default", verbose: bool = False) -> list[WorkloadRun]:
        """Tune every workload of ``suite`` for ``kernels`` (default: every
        registered kernel).  Unknown kernel names raise before any tuning."""
        names = list(kernels) if kernels else self.registry.names()
        plan: list[tuple[str, Workload]] = []
        for name in names:
            spec = self.registry.spec(name)      # raises on unknown kernel
            wls = spec.workloads_in(suite)
            if verbose and not wls:
                print(f"[session] {name}: no {suite!r} workloads, skipping")
            plan.extend((name, wl) for wl in wls)
        return [self.run_workload(name, wl, verbose=verbose)
                for name, wl in plan]

    def run_workload(self, kernel: str, workload: Workload,
                     verbose: bool = False) -> WorkloadRun:
        """Tune one (kernel, workload) pair, seeded independently of every
        other pair in the session."""
        seed = workload_seed(kernel, workload.name, self.config.seed)
        args = list(workload.make_args(np.random.default_rng(seed)))
        kern = self._kernel(kernel)
        if verbose:
            print(f"[session] {kernel} · {workload.name} (seed={seed})")
        with obs_trace.span("tune.workload", kernel=kernel,
                            workload=workload.name, seed=seed) as sp:
            results = kern.tune(args,
                                dataclasses.replace(self.config, seed=seed),
                                verbose=verbose)
            sp["best_energy"] = min(r.best_raw for r in results)
        obs_metrics.counter("tune.workloads").inc()
        return WorkloadRun(kernel=kernel, workload=workload.name,
                           signature=kern.sig_str(kern.static_of(*args)),
                           seed=seed, results=tuple(results),
                           best_energy=min(r.best_raw for r in results))
