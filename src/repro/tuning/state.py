"""Crash-safe search-state journal for :class:`~repro.tuning.TuningSession`.

A session is a sequence of independently-seeded (kernel, workload) searches;
the journal records, atomically and next to the :class:`ScheduleCache`, which
of them are ``completed``, which one is ``in_progress``, which ``failed``,
and the per-workload quarantine (signatures of candidate schedules whose
evaluation crashed or blew the deadline).  A killed session ``--resume``\\ s
by skipping completed workloads, purging the in-flight workload's partial
cache entries, and re-running it from its deterministic per-workload seed —
so the resumed cache converges to exactly the uninterrupted result.

The write protocol is: ``mark_in_progress`` *before* any tuning work for a
workload, ``mark_completed`` *after* its last cache flush.  Whatever point
the process dies at, the journal's view is pessimistic (a workload is only
``completed`` once all its entries are durably in the cache), which is what
makes the purge-and-rerun recovery correct.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any

STATE_VERSION = 1


def state_path_for(cache_path: str) -> str:
    """Default journal location: next to the schedule cache."""
    return cache_path + ".state.json"


@dataclasses.dataclass
class SearchState:
    """On-disk journal; every mutating method persists atomically."""

    path: str
    fingerprint: dict[str, Any] = dataclasses.field(default_factory=dict)
    completed: list[dict[str, Any]] = dataclasses.field(default_factory=list)
    failed: list[dict[str, Any]] = dataclasses.field(default_factory=list)
    in_progress: dict[str, Any] | None = None
    quarantine: dict[str, list[str]] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ io
    @classmethod
    def load(cls, path: str) -> "SearchState | None":
        """The journal at ``path``, or None when absent/unreadable (an
        unreadable journal means no resume credit — safe, just slower)."""
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                d = json.load(f)
            if d.get("version") != STATE_VERSION:
                return None
            return cls(path=path,
                       fingerprint=d.get("fingerprint", {}),
                       completed=list(d.get("completed", [])),
                       failed=list(d.get("failed", [])),
                       in_progress=d.get("in_progress"),
                       quarantine={k: list(v) for k, v in
                                   d.get("quarantine", {}).items()})
        except (json.JSONDecodeError, OSError, TypeError, ValueError):
            return None

    def save(self) -> None:
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".sipstate")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": STATE_VERSION,
                           "fingerprint": self.fingerprint,
                           "completed": self.completed,
                           "failed": self.failed,
                           "in_progress": self.in_progress,
                           "quarantine": self.quarantine}, f, indent=1,
                          sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # ------------------------------------------------------------- protocol
    @staticmethod
    def _key(kernel: str, workload: str) -> str:
        return f"{kernel}::{workload}"

    def matches(self, fingerprint: dict[str, Any]) -> bool:
        return self.fingerprint == fingerprint

    def completed_keys(self) -> set[tuple[str, str]]:
        return {(c["kernel"], c["workload"]) for c in self.completed}

    def mark_in_progress(self, kernel: str, workload: str,
                         signature: str) -> None:
        self.in_progress = {"kernel": kernel, "workload": workload,
                            "signature": signature}
        self.save()

    def stale_in_progress(self, kernel: str, workload: str) -> dict | None:
        """The crashed prior run's in-flight record, iff it is this
        workload (the resume must purge its partial cache entries)."""
        ip = self.in_progress
        if ip and ip["kernel"] == kernel and ip["workload"] == workload:
            return ip
        return None

    def mark_completed(self, kernel: str, workload: str, *,
                       signature: str, seed: int,
                       best_energy: float) -> None:
        self.completed.append({"kernel": kernel, "workload": workload,
                               "signature": signature, "seed": seed,
                               "best_energy": best_energy})
        self.in_progress = None
        self.save()

    def mark_failed(self, kernel: str, workload: str, error: str) -> None:
        self.failed.append({"kernel": kernel, "workload": workload,
                            "error": error[:500]})
        self.in_progress = None
        self.save()

    def quarantine_for(self, kernel: str, workload: str) -> set[str]:
        """Caller-owned live set; persist with :meth:`save_quarantine`."""
        return set(self.quarantine.get(self._key(kernel, workload), ()))

    def save_quarantine(self, kernel: str, workload: str,
                        sigs: set[str]) -> None:
        key = self._key(kernel, workload)
        if sigs:
            self.quarantine[key] = sorted(sigs)
        else:
            self.quarantine.pop(key, None)
        self.save()
