"""Multi-kernel, multi-workload tuning sessions over the kernel registry."""

from repro.tuning.session import SimulatedCrash, TuningSession, WorkloadRun
from repro.tuning.state import SearchState, state_path_for

__all__ = ["SearchState", "SimulatedCrash", "TuningSession", "WorkloadRun",
           "state_path_for"]
