"""Multi-kernel, multi-workload tuning sessions over the kernel registry."""

from repro.tuning.session import TuningSession, WorkloadRun

__all__ = ["TuningSession", "WorkloadRun"]
