"""Deployment correctness gate: probabilistic_test sweep over the registry.

The paper validates every SIP-optimized schedule with 10M random samples
before deployment (§4.2).  This driver is that gate at CI scale: for every
registered kernel workload in ``--suite``, the DEPLOYMENT-path kernel — the
registry-resolved shared instance, serving the tuned schedule when ``--cache``
holds one, the default schedule otherwise — runs against its declared oracle
under a reduced-sample :func:`repro.core.testing.probabilistic_test`.

    PYTHONPATH=src python -m repro.launch.verify --suite smoke --samples 8 \
        --cache /tmp/sip_smoke_cache.json

Exits non-zero on any mismatch, so a schedule that tunes "well" but computes
wrong values can never ship through CI (.github/workflows/ci.yml runs this
right after the smoke tune, against the store the tune persisted).
"""

from __future__ import annotations

import argparse
import contextlib

import numpy as np

from repro import kernels
from repro.core.registry import registry, schedule_cache, workload_seed
from repro.core.testing import InputSpec, probabilistic_test


def verify_workload(spec, workload, *, samples: int, seed: int,
                    schedule=None) -> dict:
    """Test one (kernel, workload) pair through the deployment path.

    With ``schedule`` (a :class:`~repro.core.schedule.Schedule`) the sweep
    runs a CANDIDATE instead: the kernel is built directly from that
    schedule, bypassing cache resolution — the seam ``repro.autotune.gate``
    uses so a schedule is probabilistically verified BEFORE promotion makes
    it the deployment path."""
    rng = np.random.default_rng(
        workload_seed(spec.name, workload.name, seed) ^ 0x5EED)
    example = workload.make_args(rng)
    input_specs = [InputSpec(tuple(np.asarray(a).shape), np.asarray(a).dtype)
                   for a in example]
    if schedule is not None:
        static = spec.signature_fn(*example)
        fn = spec.build(schedule, **static)
        which = "candidate"
    else:
        kern = registry.get(spec.name)  # honors the active schedule_cache
        static = kern.static_of(*example)
        tuned = kern.cache.best(spec.name, kern.sig_str(static)) is not None
        fn = kern
        which = "tuned" if tuned else "default"
    report = probabilistic_test(fn, spec.oracle, input_specs, samples, rng)
    return {"kernel": spec.name, "workload": workload.name,
            "schedule": which,
            "passed": report.passed, "samples": report.samples_run,
            "max_err": report.max_err}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cache", default=None,
                    help="tuned-schedule store to verify against (default: "
                         "default schedules only)")
    ap.add_argument("--suite", default="smoke",
                    help="workload suite to sweep (default: 'smoke')")
    ap.add_argument("--samples", type=int, default=8,
                    help="probabilistic-test samples per workload (the "
                         "paper's 10M gate, reduced for CI)")
    ap.add_argument("--kernel", action="append", default=[],
                    help="registered kernel name (repeatable; default: all)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    kernels.load_all()
    for name in args.kernel:
        if name not in registry:
            ap.error(f"unknown kernel {name!r}; registered: "
                     f"{', '.join(registry.names())}")

    scope = (schedule_cache(args.cache) if args.cache
             else contextlib.nullcontext())
    ran, failures = 0, []
    with scope:
        for spec in registry.specs():
            if args.kernel and spec.name not in args.kernel:
                continue
            for workload in spec.workloads_in(args.suite):
                res = verify_workload(spec, workload, samples=args.samples,
                                      seed=args.seed)
                ran += 1
                status = "PASS" if res["passed"] else "FAIL"
                print(f"[verify] {status} {res['kernel']}/{res['workload']} "
                      f"({res['schedule']} schedule, {res['samples']} samples,"
                      f" max_err={res['max_err']:.2e})")
                if not res["passed"]:
                    failures.append(res)
    if ran == 0:
        raise SystemExit(f"no {args.suite!r} workloads matched "
                         f"{args.kernel or 'any registered kernel'}")
    if failures:
        names = ", ".join(f"{f['kernel']}/{f['workload']}" for f in failures)
        print(f"[verify] {len(failures)}/{ran} workload(s) FAILED: {names}")
        return 1
    print(f"[verify] {ran} workload(s) passed the correctness gate")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
