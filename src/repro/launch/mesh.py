"""Production mesh definitions (TPU v5e pods).

Functions, not module-level constants — importing this module never touches
jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (CPU smoke tests / examples): (data, model)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def mesh_for(shape: tuple[int, ...], axes: tuple[str, ...]):
    """A mesh of exactly ``prod(shape)`` devices from this process's device
    list.  This is the elastic-reshape seam: ``FTManager.viable_mesh`` picks
    a (shape, axes) rung off the ladder after worker loss, and the supervisor
    rebuilds the mesh from the devices that remain — fewer than the full
    host/pod set, which ``jax.make_mesh`` supports via ``devices=``."""
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    if need > len(devs):
        raise ValueError(f"mesh {shape} needs {need} devices, host has "
                         f"{len(devs)}")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def chips(mesh) -> int:
    return mesh.devices.size
