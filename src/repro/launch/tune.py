"""Offline SIP search driver (paper §4.1's deployment workflow).

Tunes every registered kernel for a set of deployment shapes and persists
the best test-passing schedules to a cache file that training/serving then
load with zero runtime overhead:

    PYTHONPATH=src python -m repro.launch.tune --cache /tmp/sip_cache.json \
        --rounds 2 --kernel gemm --kernel attention
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import ScheduleCache
from repro.core.jit import TuneConfig


def tune_gemm(cache, cfg: TuneConfig, rng):
    from repro.kernels.gemm_fused import ops
    kern = ops.make(cache=cache)
    for m, n, k in ((64, 64, 128), (128, 128, 256)):
        x = rng.standard_normal((m, k)).astype(np.float32)
        w = rng.standard_normal((k, n)).astype(np.float32)
        kern.tune([x, w], cfg, verbose=True)


def tune_attention(cache, cfg: TuneConfig, rng):
    from repro.kernels.flash_attention import ops
    kern = ops.make(causal=True, cache=cache)
    for b, hq, hkv, s, d in ((1, 4, 2, 128, 32),):
        q = rng.standard_normal((b, hq, s, d)).astype(np.float32)
        k = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
        v = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
        kern.tune([q, k, v], cfg, verbose=True)


def tune_rmsnorm(cache, cfg: TuneConfig, rng):
    from repro.kernels.rmsnorm import ops
    kern = ops.make(cache=cache)
    x = rng.standard_normal((64, 128)).astype(np.float32)
    g = rng.standard_normal((128,)).astype(np.float32)
    kern.tune([x, g], cfg, verbose=True)


def tune_ssd(cache, cfg: TuneConfig, rng):
    from repro.kernels.ssd import pallas_ops
    kern = pallas_ops.make(cache=cache)
    g, q, h, p, n = 4, 16, 4, 8, 16
    xb = rng.standard_normal((g, q, h, p)).astype(np.float32)
    la = -np.abs(rng.standard_normal((g, q, h))).astype(np.float32) * 0.1
    B = rng.standard_normal((g, q, n)).astype(np.float32) * 0.3
    C = rng.standard_normal((g, q, n)).astype(np.float32) * 0.3
    kern.tune([xb, la, B, C], cfg, verbose=True)


KERNELS = {"gemm": tune_gemm, "attention": tune_attention,
           "rmsnorm": tune_rmsnorm, "ssd": tune_ssd}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache", default="/tmp/sip_cache.json")
    ap.add_argument("--kernel", action="append", default=[],
                    choices=list(KERNELS))
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--cooling", type=float, default=1.05)
    ap.add_argument("--final-samples", type=int, default=64)
    ap.add_argument("--guided", action="store_true",
                    help="use the beyond-paper guided mutation policy")
    ap.add_argument("--greed", type=float, default=0.5,
                    help="P(greedy proposal) when --guided (default 0.5)")
    ap.add_argument("--chains", type=int, default=1,
                    help="population chains per round on a temperature "
                         "ladder (1 == paper-faithful sequential search)")
    ap.add_argument("--exchange-every", type=int, default=16,
                    help="lockstep rounds between best-state exchanges "
                         "(0 disables migration)")
    ap.add_argument("--no-memoize", action="store_true",
                    help="disable the shared energy cache (re-evaluate "
                         "revisited schedules)")
    args = ap.parse_args()

    cache = ScheduleCache(args.cache)
    cfg = TuneConfig(rounds=args.rounds, cooling=args.cooling,
                     final_samples=args.final_samples,
                     step_samples=1,
                     guided=args.guided, greed=args.greed,
                     chains=args.chains, exchange_every=args.exchange_every,
                     memoize=not args.no_memoize)
    rng = np.random.default_rng(0)
    for name in (args.kernel or list(KERNELS)):
        print(f"[tune] {name}")
        KERNELS[name](cache, cfg, rng)
    print(f"[tune] schedules persisted to {args.cache}")


if __name__ == "__main__":
    main()
