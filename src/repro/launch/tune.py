"""Offline SIP search driver over the kernel registry (paper §4.1).

Fully generic: every kernel declares its own deployment workloads next to
its integration module, so this driver contains zero per-kernel code —
adding a kernel (or a deployment shape) never touches this file.

    PYTHONPATH=src python -m repro.launch.tune --list
    PYTHONPATH=src python -m repro.launch.tune --cache /tmp/sip_cache.json \
        --rounds 2 --kernel gemm_fused_leaky_relu --kernel flash_attention_causal
    PYTHONPATH=src python -m repro.launch.tune --smoke      # CI gate

Training/serving then activate the persisted store with
``repro.core.schedule_cache(path)`` and resolve tuned kernels by name.
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses

from repro import kernels, obs
from repro.core.jit import TuneConfig
from repro.core.registry import registry
from repro.tuning.session import SimulatedCrash, TuningSession
from repro.tuning.state import state_path_for


def _print_listing() -> None:
    for spec in registry.specs():
        wls = ", ".join(f"{w.name}({'/'.join(w.suites)})"
                        for w in spec.workloads) or "(no workloads)"
        print(f"{spec.name}  [{spec.module}]")
        print(f"    {wls}")


def _check_smoke_coverage() -> None:
    """Every kernel package must contribute at least one smoke workload —
    a kernel that cannot be smoke-tuned fails the build instead of silently
    dropping out of CI."""
    packages = {s.module.rsplit(".", 1)[0] for s in registry.specs()}
    for pkg in sorted(packages):
        specs = [s for s in registry.specs()
                 if s.module.rsplit(".", 1)[0] == pkg]
        if not any(s.workloads_in("smoke") for s in specs):
            raise SystemExit(f"kernel package {pkg!r} declares no 'smoke' "
                             f"workload; add one to its integration module")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--list", action="store_true",
                    help="list registered kernels + workload suites and exit")
    ap.add_argument("--cache", default="/tmp/sip_cache.json")
    ap.add_argument("--kernel", action="append", default=[],
                    help="registered kernel name (repeatable; default: all)")
    ap.add_argument("--suite", default="default",
                    help="workload suite to tune (default: 'default')")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: 1 fast round over every registered "
                         "kernel's tiny 'smoke' workload")
    ap.add_argument("--seed", type=int, default=0,
                    help="session base seed (per-workload seeds derive from "
                         "it, independent of kernel selection/order)")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--cooling", type=float, default=1.05)
    ap.add_argument("--final-samples", type=int, default=64)
    ap.add_argument("--guided", action="store_true",
                    help="use the beyond-paper guided mutation policy")
    ap.add_argument("--greed", type=float, default=0.5,
                    help="P(greedy proposal) when --guided (default 0.5)")
    ap.add_argument("--chains", type=int, default=1,
                    help="population chains per round on a temperature "
                         "ladder (1 == paper-faithful sequential search)")
    ap.add_argument("--exchange-every", type=int, default=16,
                    help="lockstep rounds between best-state exchanges "
                         "(0 disables migration)")
    ap.add_argument("--no-memoize", action="store_true",
                    help="disable the shared energy cache (re-evaluate "
                         "revisited schedules)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed session from its search-state "
                         "journal: skip completed workloads, purge + re-run "
                         "the one that was in flight")
    ap.add_argument("--state", default=None,
                    help="search-state journal path (default: "
                         "<cache>.state.json)")
    ap.add_argument("--eval-deadline", type=float, default=None,
                    metavar="S",
                    help="wall-clock cap per candidate evaluation; a wedged "
                         "or crashing schedule is quarantined and skipped, "
                         "never fatal")
    ap.add_argument("--keep-going", action="store_true",
                    help="record a workload whose tuning raises as failed "
                         "and continue with the rest of the session")
    ap.add_argument("--die-after", type=int, default=None, metavar="N",
                    help=f"chaos/CI: simulate a crash mid-journal after N "
                         f"workloads (exit code {SimulatedCrash.EXIT_CODE}); "
                         f"recover with --resume")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome-trace JSON of the tuning run "
                         "(per-workload/round spans + per-chain energy "
                         "tracks; see repro.launch.obsreport)")
    ap.add_argument("--metrics-json", default=None,
                    help="write a metrics-registry snapshot of the run")
    args = ap.parse_args(argv)

    kernels.load_all()
    if args.list:
        _print_listing()
        return 0

    suite = args.suite
    cfg = TuneConfig(rounds=args.rounds, cooling=args.cooling,
                     final_samples=args.final_samples, step_samples=1,
                     seed=args.seed, guided=args.guided, greed=args.greed,
                     chains=args.chains, exchange_every=args.exchange_every,
                     memoize=not args.no_memoize,
                     eval_deadline_s=args.eval_deadline)
    if args.smoke:
        suite = "smoke"
        # the CI gate pins the budget knobs (fast, fixed cost) but keeps
        # every other flag the user wired in
        cfg = dataclasses.replace(cfg, rounds=1, t_min=0.3, cooling=1.3,
                                  final_samples=4)
        _check_smoke_coverage()

    for name in args.kernel:
        if name not in registry:
            ap.error(f"unknown kernel {name!r}; registered: "
                     f"{', '.join(registry.names())}")

    # pass the path, not a ScheduleCache: the session interns it, so an
    # in-process schedule_cache(args.cache) scope shares the same store
    state = args.state if args.state is not None else state_path_for(args.cache)
    session = TuningSession(cache=args.cache, config=cfg, state=state,
                            keep_going=args.keep_going,
                            die_after=args.die_after)
    tracer = obs.Tracer() if args.trace else None
    with contextlib.ExitStack() as stack:
        if tracer is not None:
            stack.enter_context(obs.tracing(tracer))
        reg = stack.enter_context(obs.metrics_scope()) \
            if args.metrics_json else obs.active_registry()
        with obs.span("tune.session", suite=suite, seed=args.seed):
            try:
                runs = session.run(kernels=args.kernel or None, suite=suite,
                                   verbose=True, resume=args.resume)
            except SimulatedCrash as e:
                print(f"[tune] {e}")
                return SimulatedCrash.EXIT_CODE
    if tracer is not None:
        tracer.save(args.trace)
        print(f"[tune] trace written to {args.trace}")
    if args.metrics_json:
        reg.save_json(args.metrics_json)
        print(f"[tune] metrics snapshot written to {args.metrics_json}")
    if session.failures:
        for f in session.failures:
            print(f"[tune] FAILED {f['kernel']} · {f['workload']}: "
                  f"{f['error']}")
    if not runs and not args.resume:
        raise SystemExit(f"no {suite!r} workloads matched "
                         f"{args.kernel or 'any registered kernel'}")
    print(f"[tune] {len(runs)} workload(s) tuned; schedules persisted to "
          f"{args.cache}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
