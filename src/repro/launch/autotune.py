"""Standalone autotune daemon: tune a live deployment from outside it.

    # terminal 1: serve, streaming the live mix
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --use-pallas --sip-cache /tmp/live_cache.json \
        --record-workloads /tmp/live_mix.jsonl ...

    # terminal 2: the daemon tails the stream and tunes into the same store
    PYTHONPATH=src python -m repro.launch.autotune --arch qwen3-1.7b --smoke \
        --cache /tmp/live_cache.json --recorder /tmp/live_mix.jsonl \
        --interval 5 --budget 1

The daemon runs the same :class:`~repro.autotune.service.AutotuneService`
loop ``launch/serve.py --autotune`` embeds, but from a separate process: it
tails the serving process's ``--record-workloads`` JSONL (byte-offset
resume; a mid-write trailing line is left for the next poll), prioritizes by
traffic share x energy headroom, searches in a shadow store, and commits
gate-passing winners to ``--cache``.  The serving process observes the store
version move and hot-swaps on its next step — promotion needs no
coordination beyond the shared cache file.

``--cycles N`` bounds the run (CI smoke); the default (0) runs until
interrupted.  ``--arch``/geometry flags must mirror the serving process so
the adapter maps observed shapes to the kernels that deployment dispatches.
"""

from __future__ import annotations

import argparse
import json
import time

from repro import configs
from repro.autotune import (AutotuneConfig, AutotuneService, EventLog,
                            TuneHistory, jsonl_source, serve_targets)
from repro.core.registry import cache_for_path
from repro.serve.engine import ServeConfig
from repro.tuning.state import SearchState


def build_service(args, cfg) -> AutotuneService:
    scfg = ServeConfig(max_len=args.max_len, capacity=args.capacity,
                       paged=args.paged, page_size=args.page_size,
                       num_pages=args.num_pages or None)
    live = cache_for_path(args.cache)
    state_path = args.state or args.cache + ".autotune.state.json"
    state = SearchState.load(state_path) or SearchState(path=state_path)
    acfg = AutotuneConfig(interval_s=args.interval, budget=args.budget,
                          margin=args.margin, samples=args.samples,
                          half_life_s=args.half_life,
                          share_floor=args.share_floor,
                          max_rounds=args.max_rounds, seed=args.seed)
    return AutotuneService(
        live, source=jsonl_source(args.recorder),
        target_for=serve_targets(cfg, scfg), config=acfg,
        history=TuneHistory(args.history or args.cache + ".history.json"),
        state=state,
        log=EventLog(args.log or args.cache + ".autotune.jsonl"))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", required=True, choices=configs.arch_names(),
                    help="the SERVING process's arch (shapes must match)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--cache", required=True,
                    help="the deployment's live schedule store (shared with "
                         "the serving process)")
    ap.add_argument("--recorder", required=True,
                    help="the serving process's --record-workloads JSONL to "
                         "tail")
    ap.add_argument("--history", default=None,
                    help="cross-session tune history (default: "
                         "<cache>.history.json)")
    ap.add_argument("--log", default=None,
                    help="decision journal JSONL (default: "
                         "<cache>.autotune.jsonl)")
    ap.add_argument("--state", default=None,
                    help="quarantine/search journal (default: "
                         "<cache>.autotune.state.json)")
    ap.add_argument("--interval", type=float, default=10.0,
                    help="seconds between cycles")
    ap.add_argument("--budget", type=int, default=2,
                    help="workloads tuned per cycle")
    ap.add_argument("--cycles", type=int, default=0,
                    help="stop after N cycles (0 = run until interrupted)")
    ap.add_argument("--margin", type=float, default=0.01,
                    help="relative energy win required to promote")
    ap.add_argument("--samples", type=int, default=8,
                    help="correctness-sweep samples per candidate")
    ap.add_argument("--half-life", type=float, default=120.0,
                    help="traffic staleness half-life, seconds")
    ap.add_argument("--share-floor", type=float, default=0.01,
                    help="evict promoted keys decaying below this share")
    ap.add_argument("--max-rounds", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    # serving geometry (mirrors launch/serve.py; feeds the shape adapter)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=0)
    args = ap.parse_args(argv)

    from repro import kernels
    kernels.load_all()
    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    svc = build_service(args, cfg)
    print(f"[autotune] daemon over {args.cache} (tailing {args.recorder}, "
          f"interval={args.interval}s, budget={args.budget}/cycle)")
    try:
        if args.cycles > 0:
            for i in range(args.cycles):
                summary = svc.run_once()
                print(f"[autotune] {json.dumps(summary)}")
                if i + 1 < args.cycles:
                    time.sleep(args.interval)
        else:
            svc.start()
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        svc.stop()
        svc.log.close()
    print(f"[autotune] done: {json.dumps(svc.metrics())}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
