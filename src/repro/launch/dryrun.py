import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod / 2x16x16
multi-pod of placeholder host devices), constructs shape-only params/inputs
(ShapeDtypeStruct — nothing is allocated), jits the appropriate step with
explicit shardings, and must succeed through ``.lower().compile()``.  It then
records memory analysis, cost analysis (FLOPs / bytes), and the collective
traffic parsed from the optimized HLO into a JSON results file that
benchmarks/roofline.py and EXPERIMENTS.md consume.

Usage:
    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out dryrun_results.json
"""

import argparse
import functools
import json
import math
import re
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import costmodel
from repro.dist import partition
from repro.launch import mesh as mesh_lib
from repro.launch import steps
from repro.models import model as M
from repro.models import modules as nn
from repro.optim import adamw

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
# bytes-on-the-wire weights per op (result-shape based; all-reduce counts 2x
# for its reduce-scatter + all-gather phases)
COLLECTIVE_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0,
                     "reduce-scatter": 1.0, "all-to-all": 1.0,
                     "collective-permute": 1.0}
DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
                       r"\[([0-9,]*)\]")


def parse_collectives(hlo_text: str) -> dict[str, float]:
    """Sum result bytes of every collective op in optimized HLO, weighted per
    COLLECTIVE_WEIGHT.  Returns {op_name: bytes, ..., 'total': bytes}."""
    out = {op: 0.0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result-side ops look like: %name = TYPE ops-name(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        opname = m.group(2)
        base = opname.rstrip("0123456789.")
        base = base.replace("-start", "").replace("-done", "")
        if base not in COLLECTIVE_OPS:
            continue
        if opname.endswith("-done"):
            continue                      # counted at -start
        result_bytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            result_bytes += n * DTYPE_BYTES[dt]
        out[base] += COLLECTIVE_WEIGHT[base] * result_bytes
    out["total"] = sum(out[op] for op in COLLECTIVE_OPS)
    return out


def bytes_per_device(sds_tree, shardings) -> float:
    """Analytic per-device bytes of a (ShapeDtypeStruct, NamedSharding) tree."""
    total = 0.0
    for sds, sh in zip(jax.tree.leaves(sds_tree), jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))):
        shard_shape = sh.shard_shape(sds.shape)
        total += math.prod(shard_shape) * jnp.dtype(sds.dtype).itemsize
    return total


def count_params(shapes_tree, cfg) -> tuple[int, int]:
    """(total, active) parameter counts from the shape-only param tree."""
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes_tree)[0]:
        n = math.prod(leaf.shape)
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        is_expert = cfg.family == "moe" and "ffn" in keys and "router" not in keys
        active += int(n * cfg.top_k / cfg.n_experts) if is_expert else n
    return total, active


def model_flops(cfg, shape, total_params: int, active_params: int) -> float:
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    n = active_params
    per_token = 6 * n if shape.kind == "train" else 2 * n
    return float(per_token) * tokens


# ================================================================== lowering
def build_cell(cfg, shape, mesh):
    """Returns (jitted_fn, example_args_sds) for the cell's step kind."""
    key = jax.random.PRNGKey(0)
    ptree = M.init_lm_shapes(key, cfg)
    pshard = steps.param_shardings(ptree, mesh)
    pspecs = nn.unwrap(ptree)      # ShapeDtypeStruct tree

    if shape.kind == "train":
        opt_specs = jax.eval_shape(adamw.init_opt_state, pspecs)
        oshard = steps.opt_shardings(pshard, mesh)
        bspecs = steps.batch_sds(cfg, shape)
        bshard = steps.batch_shardings(bspecs, mesh)
        nmb = cfg.force_microbatches or steps.pick_microbatches(cfg, shape, mesh)
        fn = functools.partial(steps.train_step, cfg=cfg,
                               opt_cfg=adamw.OptConfig(),
                               num_microbatches=nmb)
        jfn = jax.jit(fn,
                      in_shardings=(pshard, oshard, bshard),
                      out_shardings=(pshard, oshard, None),
                      donate_argnums=(0, 1))
        return jfn, (pspecs, opt_specs, bspecs), {"num_microbatches": nmb}

    if shape.kind == "prefill":
        bspecs = steps.batch_sds(cfg, shape, with_labels=False)
        bshard = steps.batch_shardings(bspecs, mesh)
        cshard = steps.cache_shardings(cfg, mesh, shape.global_batch,
                                       shape.seq_len)
        fn = functools.partial(steps.prefill_step, cfg=cfg,
                               max_len=shape.seq_len)
        jfn = jax.jit(fn, in_shardings=(pshard, bshard),
                      out_shardings=(None, cshard))
        return jfn, (pspecs, bspecs), {}

    if shape.kind == "decode":
        cspecs = steps.cache_sds(cfg, shape.global_batch, shape.seq_len)
        cshard = steps.cache_shardings(cfg, mesh, shape.global_batch,
                                       shape.seq_len)
        tspecs = steps.decode_tokens_sds(shape.global_batch)
        tshard = partition.named_sharding(("batch",), mesh,
                                          shape=(shape.global_batch,))
        fn = functools.partial(steps.serve_step, cfg=cfg)
        jfn = jax.jit(fn, in_shardings=(pshard, cshard, tshard),
                      out_shardings=(None, cshard), donate_argnums=(1,))
        return jfn, (pspecs, cspecs, tspecs), {}

    raise ValueError(shape.kind)


def probe_cfg(cfg, units: int):
    """A ``units``-deep variant of ``cfg`` for unrolled cost probing, plus the
    full model's unit count (fractional for hybrid trailing layers)."""
    import dataclasses
    if cfg.family == "hybrid":
        return (dataclasses.replace(cfg, n_layers=units * cfg.hybrid_group,
                                    scan_layers=False),
                cfg.n_layers / cfg.hybrid_group)
    if cfg.family == "enc_dec":
        return (dataclasses.replace(cfg, enc_layers=units, dec_layers=units,
                                    n_layers=2 * units, scan_layers=False),
                cfg.enc_layers)
    return dataclasses.replace(cfg, n_layers=units, scan_layers=False), cfg.n_layers


def rules_for(cfg):
    rules = dict(partition.DEFAULT_RULES)
    if cfg.seq_shard:
        rules["seq"] = "model"        # SP: every seq constraint follows
    return rules


def measure_costs(cfg, shape, mesh) -> dict[str, float]:
    """Compile the cell and return {'flops','bytes','coll/<op>',...} per device."""
    with partition.mesh_rules(mesh, rules_for(cfg)):
        jfn, args, _ = build_cell(cfg, shape, mesh)
        compiled = jfn.lower(*args).compile()
    out: dict[str, float] = {}
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    out["flops"] = float(ca.get("flops", 0))
    out["bytes"] = float(ca.get("bytes accessed", 0))
    coll = parse_collectives(compiled.as_text())
    for k, v in coll.items():
        out[f"coll/{k}"] = v
    return out


def extrapolated_costs(cfg, shape, mesh) -> dict[str, Any]:
    """XLA counts loop bodies once, so the scanned artifact under-reports
    per-layer costs by ~n_layers.  Probe the cell UNROLLED at depths 1 and 2
    and extrapolate linearly — exact for homogeneous stacks:
        cost(L) = c1 + (L - 1) * (c2 - c1).
    """
    p1, full_units = probe_cfg(cfg, 1)
    p2, _ = probe_cfg(cfg, 2)
    c1 = measure_costs(p1, shape, mesh)
    c2 = measure_costs(p2, shape, mesh)
    out = {k: c1[k] + (full_units - 1) * (c2[k] - c1[k]) for k in c1}
    out["probe_flops_1"] = c1["flops"]
    out["probe_flops_2"] = c2["flops"]
    out["full_units"] = full_units
    return out


def _apply_overrides(cfg, overrides: dict[str, Any] | None):
    if not overrides:
        return cfg
    import dataclasses
    typed = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            typed[k] = v in (True, "true", "True", "1")
        elif isinstance(cur, int):
            typed[k] = int(v)
        elif isinstance(cur, float):
            typed[k] = float(v)
        else:
            typed[k] = v
    return dataclasses.replace(cfg, **typed)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             verbose: bool = True,
             overrides: dict[str, Any] | None = None) -> dict[str, Any]:
    cfg = _apply_overrides(configs.get(arch), overrides)
    shape = configs.SHAPES[shape_name]
    ok, reason = configs.applicable(cfg, shape)
    rec: dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "kind": shape.kind}
    if overrides:
        rec["overrides"] = dict(overrides)
    if not ok:
        rec["status"] = "skipped"
        rec["skip_reason"] = reason
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh_lib.chips(mesh)
    # --- 1. the REAL production artifact (scan-over-layers) must compile ----
    with partition.mesh_rules(mesh, rules_for(cfg)):
        t0 = time.time()
        jfn, args, extra = build_cell(cfg, shape, mesh)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes") if hasattr(mem, k)}
        rec["memory_per_device_bytes"] = (
            rec["memory_analysis"].get("argument_size_in_bytes", 0)
            + rec["memory_analysis"].get("temp_size_in_bytes", 0))
    except Exception as e:                      # CPU backend may not support
        rec["memory_analysis"] = f"unavailable: {e}"

    # --- 2. depth-probe cost extrapolation (see extrapolated_costs) ---------
    costs = extrapolated_costs(cfg, shape, mesh)
    rec["flops_per_device"] = costs["flops"]
    rec["hlo_bytes_per_device"] = costs["bytes"]
    coll = {k.split("/", 1)[1]: v for k, v in costs.items()
            if k.startswith("coll/")}
    rec["collective_bytes"] = coll
    rec["probe"] = {k: costs[k] for k in
                    ("probe_flops_1", "probe_flops_2", "full_units")}

    # analytic per-device residency (params + step inputs)
    ptree = M.init_lm_shapes(jax.random.PRNGKey(0), cfg)
    pshard = steps.param_shardings(ptree, mesh)
    rec["param_bytes_per_device"] = bytes_per_device(nn.unwrap(ptree), pshard)
    total_p, active_p = count_params(nn.unwrap(ptree), cfg)
    rec["params_total"] = total_p
    rec["params_active"] = active_p

    # roofline terms (per §Roofline: per-chip rates; HLO numbers are already
    # per device post-SPMD)
    terms = {
        "compute_s": max(rec["flops_per_device"], 0) / costmodel.PEAK_FLOPS_BF16,
        "memory_s": max(rec["hlo_bytes_per_device"], 0) / costmodel.HBM_BW,
        "collective_s": coll["total"] / chips / costmodel.ICI_BW_PER_LINK,
    }
    terms["dominant"] = costmodel.dominant_term(terms)
    rec["roofline"] = terms
    mf = model_flops(cfg, shape, total_p, active_p)
    rec["model_flops_total"] = mf
    hlo_total = max(rec["flops_per_device"], 0) * chips
    rec["useful_flops_ratio"] = (mf / hlo_total) if hlo_total > 0 else None
    rec["chips"] = chips
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    rec["status"] = "ok"
    rec.update(extra)
    if verbose:
        dom = terms["dominant"]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
              f"dominant={dom} {terms[dom] * 1e3:.2f}ms, "
              f"useful_flops={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'], 3)})")
    return rec


# ====================================================================== CLI
def load_results(path: str) -> dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(path: str, results: dict[str, Any]) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def cell_key(arch, shape, mesh_kind) -> str:
    return f"{arch}|{shape}|{mesh_kind}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override for §Perf hillclimbs, e.g. "
                         "--override remat_policy=dots (repeatable)")
    ap.add_argument("--tag", default="",
                    help="suffix for the results key (names the experiment)")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.override)

    if args.list:
        for name, _, shape, ok, reason in configs.cells():
            print(f"{name:24s} {shape.name:12s} "
                  f"{'RUN' if ok else 'SKIP: ' + reason}")
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        todo = [(n, s.name) for n, _, s, _, _ in configs.cells()]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        todo = [(args.arch, args.shape)]

    results = load_results(args.out)
    for arch, shape in todo:
        for mk in meshes:
            key = cell_key(arch, shape, mk)
            if args.tag:
                key += f"#{args.tag}"
            if not args.force and results.get(key, {}).get("status") in ("ok", "skipped"):
                print(f"[dryrun] {key}: cached, skipping")
                continue
            try:
                rec = run_cell(arch, shape, mk, overrides=overrides)
            except Exception as e:
                import traceback
                rec = {"arch": arch, "shape": shape, "mesh": mk,
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"[dryrun] {key}: ERROR {type(e).__name__}: {e}")
            results[key] = rec
            save_results(args.out, results)


if __name__ == "__main__":
    main()
