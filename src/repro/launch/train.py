"""Production training launcher — supervised, fault-tolerant.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--mesh host`` shards over whatever devices the host exposes; on a real
v5e deployment the same flags run under the (pod, data, model) production
mesh.  Every run goes through the :class:`~repro.ft.Supervisor`: the loop
checkpoints (async by default), heartbeats to the FT manager, and on worker
death / non-finite loss / elastic capacity loss the supervisor restores
from the newest verified checkpoint and re-enters with bounded backoff.

``--chaos`` drives the deterministic fault-injection harness, e.g.::

    --chaos 'crash@7,corrupt@5'        # kill at step 7, damage ckpt 5
    --chaos 'kill@10:w1:perm'          # worker 1 dies for good (elastic)
    --chaos 'nan@12:sticky'            # bad batch: nan until skipped
    --chaos 'random:123'               # seeded random plan

Exits nonzero if training does not reach ``--steps`` (restart budget
exhausted)."""

from __future__ import annotations

import argparse
import functools

from repro import configs
from repro.data.pipeline import DataConfig
from repro.ft import (ChaosEngine, FaultPlan, FTConfig, FTManager,
                      RestartBudgetExhausted, Supervisor, SupervisorConfig)
from repro.launch import mesh as mesh_lib
from repro.optim import adamw
from repro.train.loop import TrainConfig, train


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.arch_names())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--blocking-ckpt", action="store_true",
                    help="synchronous checkpoint saves (default: overlapped "
                         "async device-to-host + background write)")
    ap.add_argument("--mesh", default="none", choices=["none", "host",
                                                       "single", "multi"])
    # --- fault tolerance -------------------------------------------------
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="fault-injection plan: comma-separated "
                         "kind@step[:wW][:xF][:dD][:perm][:sticky][:mode] "
                         "with kind in {crash,kill,straggle,nan,corrupt}, "
                         "or random:SEED")
    ap.add_argument("--workers", type=int, default=1,
                    help="logical worker count reported to the FT manager")
    ap.add_argument("--max-restarts", type=int, default=8)
    ap.add_argument("--backoff-base", type=float, default=0.05, metavar="S")
    ap.add_argument("--backoff-max", type=float, default=5.0, metavar="S")
    ap.add_argument("--heartbeat-timeout", type=float, default=60.0,
                    metavar="S")
    args = ap.parse_args(argv)

    mcfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq,
                      vocab=mcfg.vocab)
    tcfg = TrainConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir,
                       async_ckpt=not args.blocking_ckpt,
                       num_microbatches=args.microbatches)
    ocfg = adamw.OptConfig(peak_lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                           decay_steps=args.steps)
    mesh = None
    if args.mesh == "host":
        mesh = mesh_lib.make_host_mesh()
    elif args.mesh in ("single", "multi"):
        mesh = mesh_lib.make_production_mesh(multi_pod=(args.mesh == "multi"))

    ft = FTManager(n_workers=args.workers,
                   cfg=FTConfig(heartbeat_timeout_s=args.heartbeat_timeout,
                                max_restarts=args.max_restarts))
    chaos = None
    if args.chaos:
        plan = FaultPlan.parse(args.chaos, n_workers=args.workers,
                               total_steps=args.steps)
        chaos = ChaosEngine(plan)
        print(f"[train] chaos plan: {[f.to_spec() for f in plan.faults]}")

    sup = Supervisor(
        functools.partial(train, mcfg, dcfg, tcfg, ocfg, ft=ft, chaos=chaos),
        ft=ft, chaos=chaos, mesh=mesh,
        mesh_factory=lambda target: mesh_lib.mesh_for(*target),
        cfg=SupervisorConfig(max_restarts=args.max_restarts,
                             backoff_base_s=args.backoff_base,
                             backoff_max_s=args.backoff_max))
    try:
        res = sup.run()
    except RestartBudgetExhausted as e:
        print(f"[train] FAILED: {e}")
        return 1
    s = res["supervisor"]
    print(f"[train] done: final loss {res['final_loss']:.4f} at step "
          f"{res['step']}; attempts={s['attempts']} "
          f"recoveries={[e['kind'] for e in s['events']] or 'none'} "
          f"skipped_data_steps={s['skip_data_steps'] or 'none'}")
    if res["step"] < args.steps:
        print(f"[train] FAILED: stopped at step {res['step']} < {args.steps}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
