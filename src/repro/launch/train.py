"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--mesh host`` shards over whatever devices the host exposes; on a real
v5e deployment the same flags run under the (pod, data, model) production
mesh.  The loop checkpoints, heartbeats to the FT manager, and resumes from
the newest verified checkpoint automatically."""

from __future__ import annotations

import argparse

from repro import configs
from repro.data.pipeline import DataConfig
from repro.ft.manager import FTManager
from repro.launch import mesh as mesh_lib
from repro.optim import adamw
from repro.train.loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.arch_names())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="none", choices=["none", "host",
                                                       "single", "multi"])
    args = ap.parse_args()

    mcfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq,
                      vocab=mcfg.vocab)
    tcfg = TrainConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir,
                       num_microbatches=args.microbatches)
    ocfg = adamw.OptConfig(peak_lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                           decay_steps=args.steps)
    mesh = None
    if args.mesh == "host":
        mesh = mesh_lib.make_host_mesh()
    elif args.mesh in ("single", "multi"):
        mesh = mesh_lib.make_production_mesh(multi_pod=(args.mesh == "multi"))

    ft = FTManager(n_workers=1)
    res = train(mcfg, dcfg, tcfg, ocfg, mesh=mesh, ft=ft)
    print(f"[train] done: final loss {res['final_loss']:.4f} over "
          f"{len(res['history'])} steps; FT events: {len(ft.events)}")


if __name__ == "__main__":
    main()
