"""Serving launcher: batched generation against any assigned arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 32

With ``--use-pallas --sip-cache PATH`` the model's kernel paths resolve
SIP-tuned schedules from the store ``repro.launch.tune`` persisted (via the
registry's contextvar-scoped ``schedule_cache``).
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses

import jax
import numpy as np

from repro import configs
from repro.core.registry import schedule_cache
from repro.models import model as M
from repro.models import modules as nn
from repro.serve.engine import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.arch_names())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--use-pallas", action="store_true",
                    help="route fwd-only paths through SIP-tuned kernels")
    ap.add_argument("--sip-cache", default=None,
                    help="tuned-schedule store to serve from (see "
                         "repro.launch.tune)")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if args.use_pallas:
        cfg = dataclasses.replace(cfg, use_pallas=True)
    params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
    eng = Engine(params, cfg,
                 ServeConfig(max_len=args.prompt_len + args.new_tokens,
                             temperature=args.temperature))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extra = None
    if cfg.family == "enc_dec":
        extra = {"enc_embeds": rng.standard_normal(
            (args.batch, cfg.enc_len, cfg.d_model)).astype(np.float32)}
    elif cfg.input_mode == "embeddings":
        # VLM: prompt is precomputed patch+text embeddings (frontend stub)
        extra = {"embeds": rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)}
    # kernel resolution happens at trace time (first generate), so the cache
    # scope must wrap generation, not engine construction
    scope = (schedule_cache(args.sip_cache) if args.sip_cache
             else contextlib.nullcontext())
    with scope:
        out = eng.generate(prompts, args.new_tokens, extra_inputs=extra)
    print(f"[serve] generated {out.shape} tokens; "
          f"prefill {eng.stats['prefill_s']:.2f}s, "
          f"decode {eng.stats['decode_s']:.2f}s "
          f"({eng.stats['tokens_out'] / max(eng.stats['decode_s'], 1e-9):.1f} tok/s)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
