"""Serving traffic driver: continuous batching under synthetic or traced load.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 32 --capacity 8 --arrival-rate 16 \
        --prompt-len-min 8 --prompt-len-max 48 --new-tokens 8 --new-tokens-max 24

Generates a mixed-prompt-length request stream (uniform lengths in
[--prompt-len-min, --prompt-len-max], Poisson arrivals at --arrival-rate
req/s; 0 = all at once), or replays ``--replay FILE`` — a JSON list of
``{"prompt_len": int, "new_tokens": int, "arrival": float}`` records — and
reports throughput plus latency/TTFT percentiles and the engine's
queue/occupancy/prefill-decode stats.

Observability (``repro.obs``): ``--trace PATH`` writes a Chrome-trace JSON
of the run (prefill/decode spans; open in ui.perfetto.dev, summarize or
validate with ``repro.launch.obsreport``), ``--metrics-json PATH`` dumps
the engine's metrics-registry snapshot (counters, occupancy/queue gauges,
TTFT / inter-token latency histograms), and ``--record-workloads PATH``
logs the live (shape, dtype, occupancy) mix to a replayable JSONL — the
``WorkloadRecorder`` seam offline tuning consumes.

With ``--use-pallas --sip-cache PATH`` the whole serve loop runs inside the
registry's ``schedule_cache`` scope, so the model's kernel paths resolve
SIP-tuned schedules from the store ``repro.launch.tune`` persisted.
``--static`` runs the same stream through the static-batch baseline engine
for comparison.

``--autotune`` (requires ``--sip-cache``) runs the always-on tuning service
(``repro.autotune``) on a background thread: every ``--autotune-interval``
seconds it drains the live mix, tunes up to ``--autotune-budget`` workloads
in a shadow store, gates candidates through the correctness sweep + energy
margin, and commits winners into the live cache — the engine hot-swaps them
on its next step, no restart.  Decisions journal to ``--autotune-log``
(summarize with ``repro.launch.obsreport --kind autotune``).

``--mesh N`` serves tensor-parallel over the first N devices on a 1-D
``("model",)`` mesh: parameters and KV/SSM cache shard on the head/mlp
axes, the slot (or page-id) axis stays replicated, and greedy outputs are
token-identical to the 1-device engine (see
tests/test_sharding_multidevice.py).  ``--tp-mode`` picks the manual
shard_map path vs GSPMD propagation; ``--compressed-collectives`` int8-
compresses the decode psum seams (approximate).

``--paged`` serves from the paged KV cache (``repro.serve.pages``): add
``--page-size``/``--num-pages`` to set the pool, ``--prefill-chunk N`` to
interleave long-prompt prefill with decode, ``--no-prefix-cache`` /
``--admission`` to tune sharing and overload policy.
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import time

import jax
import numpy as np

from repro import configs, obs
from repro.core.registry import schedule_cache
from repro.models import model as M
from repro.models import modules as nn
from repro.serve.engine import (ContinuousEngine, Engine, ServeConfig,
                                static_batches)


@dataclasses.dataclass
class TrafficSpec:
    prompt_len: int
    new_tokens: int
    arrival: float      # seconds after driver start


def make_traffic(args, rng: np.random.Generator) -> list[TrafficSpec]:
    if args.replay:
        with open(args.replay) as f:
            records = json.load(f)
        return [TrafficSpec(int(r["prompt_len"]), int(r["new_tokens"]),
                            float(r.get("arrival", 0.0))) for r in records]
    arrivals = np.zeros(args.requests)
    if args.arrival_rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                             args.requests))
    return [TrafficSpec(
        int(rng.integers(args.prompt_len_min, args.prompt_len_max + 1)),
        int(rng.integers(args.new_tokens,
                         max(args.new_tokens_max, args.new_tokens) + 1)),
        float(a)) for a in arrivals]


def _pct(xs: list[float]) -> dict[str, float]:
    if not xs:
        return {}
    return {p: round(float(np.percentile(xs, q)) * 1e3, 1)
            for p, q in (("p50_ms", 50), ("p95_ms", 95), ("p99_ms", 99))}


def drive_continuous(eng: ContinuousEngine, traffic: list[TrafficSpec],
                     prompts: list[np.ndarray], extras) -> dict:
    order = sorted(range(len(traffic)), key=lambda i: traffic[i].arrival)
    handles = []
    t0 = time.perf_counter()
    i = 0
    while i < len(order) or not eng.pool.idle:
        now = time.perf_counter() - t0
        while i < len(order) and traffic[order[i]].arrival <= now:
            j = order[i]
            handles.append(eng.submit(prompts[j], traffic[j].new_tokens,
                                      extra=extras[j] if extras else None))
            i += 1
        if eng.pool.idle:
            # nothing in flight: sleep until the next arrival is due
            time.sleep(max(traffic[order[i]].arrival - now, 0.0))
            continue
        eng.step()
    wall = time.perf_counter() - t0
    lat = [r.finished_at - r.submitted_at for r in handles]
    ttft = [r.admitted_at - r.submitted_at for r in handles]
    toks = sum(len(r.tokens) for r in handles)
    # top-level tokens_per_s is WALL-clock (includes arrival idle time) and
    # directly comparable to drive_static's; the engine's busy-time rates
    # live under "engine"
    return {"wall_s": round(wall, 3), "tokens": toks,
            "tokens_per_s": round(toks / wall, 1),
            "latency": _pct(lat), "ttft": _pct(ttft),
            "engine": {k: round(v, 3) for k, v in eng.metrics().items()}}


def drive_static(eng: Engine, traffic: list[TrafficSpec],
                 prompts: list[np.ndarray], extras, capacity: int) -> dict:
    """Baseline: batches of ``capacity`` in arrival order, prompts padded to
    the batch max, every batch decoding to its longest request."""
    order = sorted(range(len(traffic)), key=lambda i: traffic[i].arrival)
    aprompts = [prompts[j] for j in order]
    abudgets = [traffic[j].new_tokens for j in order]
    t0 = time.perf_counter()
    toks = 0
    for padded, new, idxs in static_batches(aprompts, abudgets, capacity):
        ei = None
        if extras:
            ei = {k: _stack_extra(k, [extras[order[j]][k] for j in idxs],
                                  padded.shape[1])
                  for k in extras[0]}
        eng.generate(padded, new, extra_inputs=ei)
        toks += sum(abudgets[j] for j in idxs)              # useful tokens
    wall = time.perf_counter() - t0
    return {"wall_s": round(wall, 3), "tokens": toks,
            "tokens_per_s": round(toks / wall, 1)}


def _stack_extra(key: str, values: list[np.ndarray], plen: int) -> np.ndarray:
    """Batch per-request extra inputs; prompt-aligned extras (VLM embeds)
    are left-padded to the batch prompt length like the tokens."""
    if key != "embeds":
        return np.stack(values)
    out = np.zeros((len(values), plen) + values[0].shape[1:],
                   values[0].dtype)
    for r, v in enumerate(values):
        out[r, plen - v.shape[0]:] = v
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", required=True, choices=configs.arch_names())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=8,
                    help="decode-batch slots")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrivals, requests/s (0 = all at start)")
    ap.add_argument("--replay", default=None,
                    help="JSON request trace to replay (overrides synthetic "
                         "traffic)")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome-trace JSON of the run (Perfetto-"
                         "loadable; see repro.launch.obsreport)")
    ap.add_argument("--metrics-json", default=None,
                    help="write the engine's metrics-registry snapshot")
    ap.add_argument("--record-workloads", default=None,
                    help="record the live workload mix to a replayable "
                         "JSONL (repro.obs.WorkloadRecorder)")
    ap.add_argument("--prompt-len-min", type=int, default=8)
    ap.add_argument("--prompt-len-max", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--new-tokens-max", type=int, default=0,
                    help="uniform in [--new-tokens, this] when > 0")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--static", action="store_true",
                    help="run the static-batch baseline engine instead")
    ap.add_argument("--paged", action="store_true",
                    help="serve from a paged KV cache (repro.serve.pages) "
                         "instead of per-slot contiguous segments")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV cache page (with --paged)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page budget incl. the trash page (0 = contiguous-"
                         "equivalent memory: capacity*ceil(max_len/ps)+1)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill size (0 = whole-prompt prefills); "
                         "with --paged only")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable content-hashed prefix sharing (with "
                         "--paged)")
    ap.add_argument("--admission", choices=("queue", "reject"),
                    default="queue",
                    help="paged admission policy when pages/slots are "
                         "unavailable at submit time")
    ap.add_argument("--mesh", type=int, default=0,
                    help="serve tensor-parallel over the first N devices "
                         "(1-D 'model' mesh; shards heads/kv-heads/mlp, "
                         "replicates the slot/page axis).  Multi-device on "
                         "CPU needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--tp-mode", choices=("auto", "shard_map", "gspmd"),
                    default="auto",
                    help="tensor-parallel path with --mesh: manual shard_map "
                         "collectives vs GSPMD constraint propagation "
                         "(auto = shard_map when the config is TP-eligible)")
    ap.add_argument("--compressed-collectives", action="store_true",
                    help="int8-compress the decode-step psum seams (with "
                         "--mesh; shard_map path only).  Approximate: "
                         "trades exact token parity for collective bytes")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route fwd-only paths through SIP-tuned kernels")
    ap.add_argument("--sip-cache", default=None,
                    help="tuned-schedule store to serve from (see "
                         "repro.launch.tune)")
    ap.add_argument("--autotune", action="store_true",
                    help="run the always-on autotune service alongside the "
                         "engine: tune the live mix, gate, hot-swap winners "
                         "into --sip-cache (see repro.autotune)")
    ap.add_argument("--autotune-interval", type=float, default=10.0,
                    help="seconds between autotune cycles")
    ap.add_argument("--autotune-budget", type=int, default=2,
                    help="workloads tuned per autotune cycle")
    ap.add_argument("--autotune-log", default=None,
                    help="autotune decision journal JSONL (default: "
                         "<sip-cache>.autotune.jsonl)")
    args = ap.parse_args()
    if args.autotune and not args.sip_cache:
        ap.error("--autotune requires --sip-cache (a live store to promote "
                 "into)")
    if args.autotune and args.static:
        ap.error("--autotune requires the continuous engine (drop --static)")
    if args.static and args.mesh:
        ap.error("--mesh requires the continuous engine (drop --static)")
    if args.compressed_collectives and not args.mesh:
        ap.error("--compressed-collectives requires --mesh")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import mesh_for
        mesh = mesh_for((args.mesh,), ("model",))

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if args.use_pallas:
        cfg = dataclasses.replace(cfg, use_pallas=True)
    params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(args.seed)
    traffic = make_traffic(args, rng)
    # global maxima, not max(plen_i + new_i): a static batch left-pads to its
    # longest prompt AND decodes to its largest budget, so the cache must
    # cover their combination
    max_len = (max(t.prompt_len for t in traffic)
               + max(t.new_tokens for t in traffic))
    scfg = ServeConfig(max_len=max_len, temperature=args.temperature,
                       capacity=args.capacity, seed=args.seed,
                       paged=args.paged, page_size=args.page_size,
                       num_pages=args.num_pages or None,
                       prefill_chunk=args.prefill_chunk or None,
                       prefix_cache=not args.no_prefix_cache,
                       admission=args.admission, tp_mode=args.tp_mode,
                       compressed_collectives=args.compressed_collectives)
    prompts = [rng.integers(0, cfg.vocab, t.prompt_len).astype(np.int32)
               for t in traffic]
    extras = None
    if cfg.family == "enc_dec":
        ctx = rng.standard_normal(
            (cfg.enc_len, cfg.d_model)).astype(np.float32)
        extras = [{"enc_embeds": ctx} for _ in traffic]
    elif cfg.input_mode == "embeddings":
        # VLM: the prompt is precomputed patch+text embeddings (frontend stub)
        extras = [{"embeds": rng.standard_normal(
            (t.prompt_len, cfg.d_model)).astype(np.float32)}
            for t in traffic]

    # kernel resolution happens at trace time, so the cache scope must wrap
    # the serve loop (late-binding registry handles honor it from then on)
    tracer = obs.Tracer() if args.trace else None
    # streaming mode: records hit the JSONL as they happen, so an external
    # autotune daemon can tail the file while this process serves
    recorder = (obs.WorkloadRecorder(args.record_workloads)
                if args.record_workloads
                else obs.WorkloadRecorder() if args.autotune else None)
    reg = obs.MetricsRegistry()
    service = None
    if args.autotune:
        from repro.autotune import (AutotuneConfig, AutotuneService,
                                    EventLog, TuneHistory, recorder_source,
                                    serve_targets)
        from repro.core.registry import cache_for_path
        from repro.tuning.state import SearchState
        state_path = args.sip_cache + ".autotune.state.json"
        service = AutotuneService(
            cache_for_path(args.sip_cache),
            source=recorder_source(recorder),
            target_for=serve_targets(cfg, scfg),
            config=AutotuneConfig(interval_s=args.autotune_interval,
                                  budget=args.autotune_budget),
            history=TuneHistory(args.sip_cache + ".history.json"),
            state=(SearchState.load(state_path)
                   or SearchState(path=state_path)),
            log=EventLog(args.autotune_log
                         or args.sip_cache + ".autotune.jsonl"),
            obs=reg)
    with contextlib.ExitStack() as stack:
        if args.sip_cache:
            stack.enter_context(schedule_cache(args.sip_cache))
        if tracer is not None:
            stack.enter_context(obs.tracing(tracer))
        if args.static:
            eng = Engine(params, cfg, scfg)
            report = drive_static(eng, traffic, prompts, extras,
                                  args.capacity)
            print(f"[serve:static] {json.dumps(report)}")
        else:
            ceng = ContinuousEngine(params, cfg, scfg,
                                    example_extra=extras[0] if extras
                                    else None, obs=reg, recorder=recorder,
                                    mesh=mesh)
            if mesh is not None:
                print(f"[serve] mesh={tuple(mesh.shape.values())} "
                      f"tp_path={ceng.tp_path} ({ceng.tp_reason})")
            if service is not None:
                service.start()
            try:
                report = drive_continuous(ceng, traffic, prompts, extras)
            finally:
                if service is not None:
                    service.stop()
                    service.log.close()
            print(f"[serve:continuous] {json.dumps(report)}")
            if service is not None:
                print(f"[serve] autotune: {json.dumps(service.metrics())}")
    if tracer is not None:
        tracer.save(args.trace)
        print(f"[serve] trace written to {args.trace}")
    if args.metrics_json:
        reg.save_json(args.metrics_json)
        print(f"[serve] metrics snapshot written to {args.metrics_json}")
    if recorder is not None:
        recorder.close()
        if args.record_workloads:
            print(f"[serve] workload mix ({len(recorder)} records) written "
                  f"to {args.record_workloads}")


if __name__ == "__main__":
    main()
