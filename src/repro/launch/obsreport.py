"""Render or validate a repro.obs trace — the human end of the telemetry.

    PYTHONPATH=src python -m repro.launch.obsreport run_trace.json
    PYTHONPATH=src python -m repro.launch.obsreport run_trace.json --validate
    PYTHONPATH=src python -m repro.launch.obsreport run_trace.json \
        --metrics-json run_metrics.json
    PYTHONPATH=src python -m repro.launch.obsreport live.jsonl --kind workloads

Default mode summarizes a Chrome-trace/JSONL file produced by
``launch/tune.py --trace`` or ``launch/serve.py --trace``: top spans by
total time, counter-track extrema (the per-chain energy-vs-step trajectory
of a search run), and — with ``--metrics-json`` — histogram percentiles and
counters from the matching metrics snapshot.  ``--validate`` schema-checks
the file instead (event shape + span nesting, see
``repro.obs.trace.validate_events``) and exits non-zero on any violation;
``--kind workloads`` treats the file as a ``WorkloadRecorder`` JSONL and
summarizes (or validates) the recorded serving mix; ``--kind autotune``
treats it as an autotune decision journal (``repro.autotune.log``) and
reports promotions (with energy deltas vs the displaced incumbent),
quarantines, warm-start hits, and evictions — or schema-checks it with
``--validate``.
"""

from __future__ import annotations

import argparse
import json

from repro.autotune import log as autotune_log
from repro.obs.recorder import WorkloadRecorder
from repro.obs.trace import load_trace, validate_events

_WORKLOAD_KINDS = {"prefill", "decode", "submit"}


def _fmt_ms(us: float) -> str:
    return f"{us / 1e3:10.3f}"


def summarize_spans(events: list[dict], top: int = 15) -> list[str]:
    agg: dict[str, list[float]] = {}
    for ev in events:
        if ev.get("ph") == "X":
            agg.setdefault(ev["name"], []).append(float(ev.get("dur", 0.0)))
    if not agg:
        return ["  (no spans)"]
    lines = [f"  {'span':<28}{'count':>7}{'total ms':>12}{'mean ms':>12}"
             f"{'max ms':>12}"]
    ranked = sorted(agg.items(), key=lambda kv: -sum(kv[1]))[:top]
    for name, durs in ranked:
        lines.append(f"  {name:<28}{len(durs):>7}{_fmt_ms(sum(durs)):>12}"
                     f"{_fmt_ms(sum(durs) / len(durs)):>12}"
                     f"{_fmt_ms(max(durs)):>12}")
    dropped = len(agg) - len(ranked)
    if dropped > 0:
        lines.append(f"  ... {dropped} more span name(s) below the top {top}")
    return lines


def summarize_counters(events: list[dict]) -> list[str]:
    """Counter tracks as (first, min, last) — for an energy track this is
    the energy-vs-step story of the search: where it started, the best it
    found, where it ended."""
    tracks: dict[tuple[str, str], list[float]] = {}
    for ev in events:
        if ev.get("ph") != "C":
            continue
        for key, v in (ev.get("args") or {}).items():
            if isinstance(v, (int, float)):
                tracks.setdefault((ev["name"], key), []).append(float(v))
    if not tracks:
        return ["  (no counter tracks)"]
    lines = [f"  {'track':<40}{'samples':>8}{'first':>10}{'min':>10}"
             f"{'last':>10}"]
    for (name, key), vals in sorted(tracks.items()):
        lines.append(f"  {name + ':' + key:<40}{len(vals):>8}"
                     f"{vals[0]:>10.4g}{min(vals):>10.4g}{vals[-1]:>10.4g}")
    return lines


def summarize_metrics(path: str) -> list[str]:
    with open(path) as f:
        snap = json.load(f)
    lines = []
    for name, m in sorted(snap.items()):
        if m.get("type") == "histogram":
            lines.append(
                f"  {name:<28} n={m['count']:<7} mean={m.get('mean', 0):.4g} "
                f"p50={m.get('p50', 0):.4g} p95={m.get('p95', 0):.4g} "
                f"p99={m.get('p99', 0):.4g} max={m.get('max', 0):.4g}")
        else:
            lines.append(f"  {name:<28} {m.get('type', '?'):<10} "
                         f"{m.get('value', 0):.6g}")
    return lines or ["  (empty snapshot)"]


def validate_workloads(path: str) -> list[str]:
    errors = []
    try:
        with open(path) as f:
            lines = [line for line in f if line.strip()]
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    for i, line in enumerate(lines):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"line {i}: invalid JSON ({e})")
            continue
        if rec.get("kind") not in _WORKLOAD_KINDS:
            errors.append(f"line {i}: bad kind {rec.get('kind')!r}")
        for field, ty in (("t", (int, float)), ("prompt_len", int),
                          ("batch", int), ("dtype", str),
                          ("occupancy", int), ("queue_depth", int)):
            if not isinstance(rec.get(field), ty):
                errors.append(f"line {i}: bad {field!r}: {rec.get(field)!r}")
    return errors


def summarize_autotune(events: list[dict]) -> list[str]:
    """Activity report for an autotune decision journal: event-kind counts,
    every promotion with its energy delta vs the incumbent it displaced,
    quarantines, warm-start hits, evictions."""
    kinds: dict[str, int] = {}
    for ev in events:
        kinds[str(ev.get("kind", "?"))] = kinds.get(str(ev.get("kind",
                                                              "?")), 0) + 1
    lines = ["  " + "  ".join(f"{k}={n}" for k, n in sorted(kinds.items()))
             if kinds else "  (no events)"]
    promos = [ev for ev in events if ev.get("kind") == "promoted"]
    if promos:
        lines.append(f"  {'kernel':<26}{'workload':<30}{'energy':>11}"
                     f"{'vs incumbent':>14}")
        per_kernel: dict[str, list[float]] = {}
        for ev in promos:
            inc = ev.get("incumbent_energy")
            if isinstance(inc, (int, float)) and inc > 0:
                d = (float(ev.get("energy", 0.0)) / inc - 1.0) * 100
                per_kernel.setdefault(str(ev.get("kernel", "?")),
                                      []).append(d)
                delta = f"{d:+.1f}%"
            else:
                delta = "(untuned)"
            lines.append(f"  {str(ev.get('kernel', '')):<26}"
                         f"{str(ev.get('workload', '')):<30}"
                         f"{float(ev.get('energy', 0.0)):>11.4g}{delta:>14}")
        for kernel, deltas in sorted(per_kernel.items()):
            lines.append(f"  {kernel}: mean energy delta "
                         f"{sum(deltas) / len(deltas):+.1f}% over "
                         f"{len(deltas)} re-promotion(s)")
    for ev in events:
        if ev.get("kind") == "quarantined":
            lines.append(f"  QUARANTINED {ev.get('kernel')}"
                         f"/{ev.get('workload')}: {ev.get('reason')} "
                         f"(max_err={ev.get('max_err', 0)})")
    warm = sum(1 for ev in events if ev.get("kind") == "warm_start")
    evictions = sum(1 for ev in events if ev.get("kind") == "evicted")
    lines.append(f"  warm-start hits: {warm}   evictions: {evictions}")
    return lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="trace file (.json Chrome trace or JSONL) "
                                 "or WorkloadRecorder JSONL")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check instead of summarizing; non-zero "
                         "exit on any violation")
    ap.add_argument("--kind", choices=("trace", "workloads", "autotune"),
                    default="trace")
    ap.add_argument("--metrics-json", default=None,
                    help="metrics snapshot to summarize alongside the trace")
    ap.add_argument("--top", type=int, default=15,
                    help="span names to show (by total time)")
    args = ap.parse_args(argv)

    if args.validate:
        if args.kind == "workloads":
            errors = validate_workloads(args.path)
        elif args.kind == "autotune":
            try:
                errors = autotune_log.validate_events(
                    autotune_log.load_events(args.path))
            except (OSError, ValueError) as e:
                errors = [f"{args.path}: unreadable journal ({e})"]
        else:
            try:
                errors = validate_events(load_trace(args.path))
            except (OSError, ValueError, json.JSONDecodeError) as e:
                errors = [f"{args.path}: unreadable trace ({e})"]
        for err in errors[:50]:
            print(f"[obsreport] INVALID: {err}")
        if len(errors) > 50:
            print(f"[obsreport] ... {len(errors) - 50} more errors")
        print(f"[obsreport] {args.path}: "
              f"{'INVALID (%d error(s))' % len(errors) if errors else 'OK'}")
        return 1 if errors else 0

    if args.kind == "workloads":
        rec = WorkloadRecorder.load(args.path)
        print(f"[obsreport] workload mix from {args.path}")
        print(json.dumps(rec.summary(), indent=1))
        return 0

    if args.kind == "autotune":
        events = autotune_log.load_events(args.path)
        print(f"[obsreport] autotune journal {args.path}: "
              f"{len(events)} events")
        for line in summarize_autotune(events):
            print(line)
        return 0

    events = load_trace(args.path)
    print(f"[obsreport] {args.path}: {len(events)} events")
    print("top spans:")
    for line in summarize_spans(events, args.top):
        print(line)
    print("counter tracks (energy-vs-step etc.):")
    for line in summarize_counters(events):
        print(line)
    if args.metrics_json:
        print(f"metrics snapshot ({args.metrics_json}):")
        for line in summarize_metrics(args.metrics_json):
            print(line)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
