"""Jittable production steps (train / prefill / serve) + ShapeDtypeStruct
input specs for every (arch x shape) dry-run cell.

``train_step`` does loss + grad (with optional microbatch accumulation) +
AdamW; ``prefill_step`` runs the prompt and materializes decode caches;
``serve_step`` decodes one token against the caches.  All are pure functions
of (params, state, batch) suitable for ``jax.jit`` with explicit shardings.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.dist import partition
from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.optim import adamw


# ================================================================ train step
def train_step(params, opt_state, batch, *, cfg: ModelConfig,
               opt_cfg: adamw.OptConfig, num_microbatches: int = 1):
    """One optimizer step.  ``batch`` leading dim is the global batch;
    with ``num_microbatches > 1`` gradients are accumulated over microbatch
    slices under lax.scan (bounds activation memory)."""

    def loss(p, b):
        return M.loss_fn(p, b, cfg)

    if num_microbatches <= 1:
        (_, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
    else:
        def micro(b):
            return jax.tree.map(
                lambda x: x.reshape((num_microbatches,
                                     x.shape[0] // num_microbatches) + x.shape[1:]),
                b)

        mb = micro(batch)

        def body(carry, b):
            acc, macc = carry
            (_, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, b)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            macc = jax.tree.map(lambda a, m: a + m, macc, metrics)
            return (acc, macc), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, metrics), _ = jax.lax.scan(
            body, (zero, _zero_metrics()), mb)
        grads = jax.tree.map(lambda g: g / num_microbatches, grads)
        metrics = jax.tree.map(lambda m: m / num_microbatches, metrics)

    new_params, new_opt, opt_metrics = adamw.adamw_update(
        grads, opt_state, params, opt_cfg)
    metrics = {**metrics, **opt_metrics}
    return new_params, new_opt, metrics


def _zero_metrics():
    return {"loss": jnp.float32(0), "aux/load_balance": jnp.float32(0),
            "aux/router_z": jnp.float32(0)}


# ============================================================== serve steps
def prefill_step(params, batch, *, cfg: ModelConfig, max_len: int):
    return M.prefill(params, batch, cfg, max_len=max_len)


def serve_step(params, caches, tokens, *, cfg: ModelConfig):
    return M.decode_step(params, caches, tokens, cfg)


# ======================================================== shape-only builders
def batch_sds(cfg: ModelConfig, shape: ShapeSpec, *, with_labels: bool = True):
    """ShapeDtypeStructs for a train/prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    out: dict[str, Any] = {}
    if cfg.family == "enc_dec":
        out["enc_embeds"] = jax.ShapeDtypeStruct((b, cfg.enc_len, cfg.d_model), dt)
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif cfg.input_mode == "embeddings":
        out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def cache_sds(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStructs for decode caches — mirrors M.prefill's output
    structure exactly (asserted by tests/test_steps.py)."""
    dt = jnp.dtype(cfg.dtype)
    kvl = M._kv_cache_len(cfg, max_len)

    def kv(layers, length):
        return {
            "k": jax.ShapeDtypeStruct((layers, batch, length, cfg.n_kv_heads,
                                       cfg.hd), dt),
            "v": jax.ShapeDtypeStruct((layers, batch, length, cfg.n_kv_heads,
                                       cfg.hd), dt),
            "len": jax.ShapeDtypeStruct((layers,), jnp.int32),
        }

    def ssm_states(lead):
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        return {
            "conv": jax.ShapeDtypeStruct(lead + (batch, cfg.conv_width - 1,
                                                 conv_ch), dt),
            "ssd": jax.ShapeDtypeStruct(lead + (batch, cfg.ssm_heads,
                                                cfg.ssm_state,
                                                cfg.ssm_headdim), jnp.float32),
        }

    if cfg.family in ("dense", "moe", "vlm"):
        return kv(cfg.n_layers, kvl)
    if cfg.family == "ssm":
        return ssm_states((cfg.n_layers,))
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.hybrid_group
        trailing = cfg.n_layers % cfg.hybrid_group
        out = {"mamba": ssm_states((n_groups, cfg.hybrid_group)),
               "attn": kv(n_groups, kvl)}
        if trailing:
            out["trailing"] = ssm_states((trailing,))
        return out
    if cfg.family == "enc_dec":
        cross = (jax.ShapeDtypeStruct((cfg.dec_layers, batch, cfg.enc_len,
                                       cfg.n_kv_heads, cfg.hd), dt),) * 2
        return {"self": kv(cfg.dec_layers, kvl), "cross": cross}
    raise ValueError(cfg.family)


def decode_tokens_sds(batch: int):
    return jax.ShapeDtypeStruct((batch,), jnp.int32)


# ------------------------------------------------------------- shardings
BATCH_AXES = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
              "embeds": ("batch", "seq", None),
              "enc_embeds": ("batch", "seq", None)}


def batch_shardings(batch_tree, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, sds: partition.named_sharding(
            BATCH_AXES[path[0].key], mesh, shape=sds.shape),
        batch_tree)


def cache_axes(cfg: ModelConfig):
    """Logical axes for each cache leaf (same tree structure as cache_sds).
    The table itself lives with the cache layouts in ``models.model``
    (``cache_logical_axes``) so sharded serving shares one source of
    truth; this alias keeps the historical launch-side entry point."""
    return M.cache_logical_axes(cfg)


def cache_shardings(cfg: ModelConfig, mesh, batch: int, max_len: int):
    axes = cache_axes(cfg)
    sds = cache_sds(cfg, batch, max_len)
    return partition.tree_shardings(axes, mesh, sds_tree=sds)


def param_shardings(param_tree_with_axes, mesh):
    """Param (axes) tree -> NamedSharding tree."""
    axes = nn.axes_of(param_tree_with_axes)
    return partition.tree_shardings(axes, mesh,
                                    sds_tree=nn.unwrap(param_tree_with_axes))


def opt_shardings(pshard, mesh):
    return {"mu": pshard, "nu": pshard,
            "step": partition.named_sharding((), mesh)}


# ------------------------------------------------------------ microbatching
def pick_microbatches(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    """Default microbatch count: keep per-device live tokens bounded."""
    data_ways = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            data_ways *= mesh.shape[ax]
    per_dev_tokens = shape.global_batch * shape.seq_len / max(data_ways, 1)
    target = 64 * 1024                      # tokens per device per microbatch
    n = max(1, int(per_dev_tokens // target))
    while shape.global_batch % n:
        n -= 1
    return n
