"""Architecture + shape registry: the assigned 10 archs x 4 shapes = 40 cells.

``cells()`` enumerates every (arch, shape) pair with its applicability ruling
(long_500k requires sub-quadratic sequence handling — run for ssm/hybrid/SWA,
skip for pure full-attention archs; see DESIGN.md §4)."""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, smoke_variant

ARCH_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "llama4-scout-17b-16e": "llama4_scout_17b_16e",
    "llava-next-34b": "llava_next_34b",
    "mamba2-2.7b": "mamba2_2_7b",
    "zamba2-7b": "zamba2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen3-4b": "qwen3_4b",
    "internlm2-20b": "internlm2_20b",
    "qwen3-1.7b": "qwen3_1_7b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")
    return mod.CONFIG.validate()


def get_smoke(name: str, **overrides) -> ModelConfig:
    return smoke_variant(get(name), **overrides)


def arch_names() -> list[str]:
    return list(ARCH_MODULES)


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 512k dense-KV decode is "
                       "out of scope per assignment (needs sub-quadratic "
                       "attention); see DESIGN.md §4")
    return True, ""


def cells():
    """Yield (arch_name, cfg, shape, runnable, skip_reason) for all 40 cells."""
    for name in ARCH_MODULES:
        cfg = get(name)
        for shape in SHAPES.values():
            ok, reason = applicable(cfg, shape)
            yield name, cfg, shape, ok, reason
