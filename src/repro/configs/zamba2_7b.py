"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64; Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].

Structure here: 13 scanned groups of 6 mamba blocks, each followed by the
ONE shared attention+MLP block (params reused across groups — the Zamba
trick), plus 3 trailing mamba blocks (81 = 13*6 + 3)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=256, conv_width=4,
    hybrid_group=6, hybrid_attn_every=1,
)
