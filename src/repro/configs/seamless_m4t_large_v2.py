"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206; encoder-decoder, multimodal [arXiv:2308.11596; hf].

The speech frontend (conformer feature extractor) is a STUB: input_specs
provide precomputed frame embeddings (B, T_enc, d_model).  Both the 24-layer
encoder and the 24-layer decoder (self+cross attention) are modeled.
T_enc is capped at 4096 frames (DESIGN.md §4)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="enc_dec",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206,
    enc_layers=24, dec_layers=24, enc_len=4096,
    input_mode="embeddings", mlp_type="gelu",
)
