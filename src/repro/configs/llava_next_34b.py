"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling [hf:llava-hf/llava-v1.6; unverified].

The vision frontend (anyres patch tiling + projector) is a STUB: input_specs
provide precomputed patch+text embeddings (B, S, d_model); the transformer
BACKBONE is modeled exactly."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000,
    input_mode="embeddings", mlp_type="swiglu",
)
