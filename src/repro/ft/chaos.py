"""Deterministic fault injection for the training/tuning resilience layer.

A :class:`FaultPlan` is a seeded, declarative list of faults — worker
crashes, heartbeat kills, stragglers, checkpoint corruption, non-finite
losses — and a :class:`ChaosEngine` arms one plan against a running loop.
Everything is a pure function of (plan, step): the same plan against the
same run injects the same faults at the same points, so chaos runs are
replayable in tests and comparable against an uninterrupted baseline
(the differential gate in ``tests/test_ft_chaos.py``).

Drivable from the CLI::

    python -m repro.launch.train ... --chaos "crash@9,corrupt@12,nan@15"
    python -m repro.launch.train ... --chaos random:7     # seeded plan

Fault grammar (comma list of ``kind@step[:opt...]``):

* ``crash@S``            — raise :class:`WorkerKilled` entering step S (once)
* ``kill@S[:wW][:perm]`` — stop worker W's heartbeats from step S;
  transient kills resume on the next attempt, ``perm`` never comes back
* ``straggle@S[:wW][:xF][:dD]`` — inflate worker W's reported step latency
  by F for D steps (default: rest of the attempt)
* ``nan@S[:sticky]``     — non-finite loss at step S; ``sticky`` re-fires
  every time step S's original batch is used (a genuinely bad batch — only
  the supervisor's skip-window makes progress possible)
* ``corrupt@S[:truncate|bitflip|manifest]`` — damage the first checkpoint
  written at/after step S, mid-write from the loop's point of view
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.ft.errors import WorkerKilled
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

KINDS = ("crash", "kill", "straggle", "nan", "corrupt")
CORRUPT_MODES = ("truncate", "bitflip", "manifest")


@dataclasses.dataclass(frozen=True)
class Fault:
    kind: str
    step: int
    worker: int = 0
    factor: float = 8.0        # straggle: reported-latency multiplier
    duration: int = 0          # straggle: steps it lasts (0 = rest of attempt)
    sticky: bool = False       # nan: re-fires whenever step's batch is used
    permanent: bool = False    # kill: worker never rejoins
    mode: str = "truncate"     # corrupt: truncate | bitflip | manifest

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if self.kind == "corrupt" and self.mode not in CORRUPT_MODES:
            raise ValueError(f"unknown corrupt mode {self.mode!r} "
                             f"(expected one of {CORRUPT_MODES})")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")

    def to_spec(self) -> str:
        opts = []
        if self.kind in ("kill", "straggle") and self.worker:
            opts.append(f"w{self.worker}")
        if self.kind == "straggle":
            if self.factor != 8.0:
                opts.append(f"x{self.factor:g}")
            if self.duration:
                opts.append(f"d{self.duration}")
        if self.kind == "kill" and self.permanent:
            opts.append("perm")
        if self.kind == "nan" and self.sticky:
            opts.append("sticky")
        if self.kind == "corrupt" and self.mode != "truncate":
            opts.append(self.mode)
        return "@".join([self.kind, str(self.step)]) + \
            "".join(":" + o for o in opts)


def _parse_fault(item: str) -> Fault:
    head, _, rest = item.strip().partition("@")
    if not rest:
        raise ValueError(f"fault {item!r} is missing '@step'")
    parts = rest.split(":")
    try:
        step = int(parts[0])
    except ValueError:
        raise ValueError(f"fault {item!r}: step {parts[0]!r} is not an int")
    kw: dict = {}
    for opt in parts[1:]:
        if opt == "perm":
            kw["permanent"] = True
        elif opt == "sticky":
            kw["sticky"] = True
        elif opt in CORRUPT_MODES:
            kw["mode"] = opt
        elif opt.startswith("w"):
            kw["worker"] = int(opt[1:])
        elif opt.startswith("x"):
            kw["factor"] = float(opt[1:])
        elif opt.startswith("d"):
            kw["duration"] = int(opt[1:])
        else:
            raise ValueError(f"fault {item!r}: unknown option {opt!r}")
    return Fault(kind=head, step=step, **kw)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered, immutable set of faults.  Construct via :meth:`parse`
    (explicit CLI spec) or :meth:`random` (seeded generation)."""

    faults: tuple[Fault, ...] = ()

    @classmethod
    def parse(cls, spec: str, *, n_workers: int = 1,
              total_steps: int | None = None) -> "FaultPlan":
        """Parse a comma-separated fault spec; ``random:SEED`` delegates to
        :meth:`random` (which needs ``total_steps``)."""
        spec = spec.strip()
        if spec.startswith("random:"):
            if total_steps is None:
                raise ValueError("random chaos plans need total_steps")
            return cls.random(int(spec.split(":", 1)[1]),
                              total_steps=total_steps, n_workers=n_workers)
        faults = tuple(_parse_fault(p) for p in spec.split(",") if p.strip())
        if not faults:
            raise ValueError(f"empty fault spec {spec!r}")
        return cls(faults)

    @classmethod
    def random(cls, seed: int, *, total_steps: int,
               n_workers: int = 1, n_faults: int = 3) -> "FaultPlan":
        """A seeded plan: ``n_faults`` faults at distinct mid-run steps.
        Deterministic — the same (seed, total_steps, n_workers) always
        yields the same plan."""
        rng = np.random.default_rng(seed)
        lo, hi = max(1, total_steps // 8), max(2, total_steps - 2)
        steps = sorted(rng.choice(np.arange(lo, hi), size=min(
            n_faults, hi - lo), replace=False).tolist())
        kinds = rng.choice(["crash", "kill", "straggle", "nan", "corrupt"],
                           size=len(steps)).tolist()
        faults = []
        for step, kind in zip(steps, kinds):
            kw: dict = {}
            if kind in ("kill", "straggle"):
                kw["worker"] = int(rng.integers(0, n_workers))
            if kind == "corrupt":
                kw["mode"] = str(rng.choice(CORRUPT_MODES))
            faults.append(Fault(kind=kind, step=int(step), **kw))
        return cls(tuple(faults))

    def to_spec(self) -> str:
        return ",".join(f.to_spec() for f in self.faults)

    def __iter__(self):
        return iter(self.faults)

    def __len__(self) -> int:
        return len(self.faults)


class ChaosEngine:
    """Arms a :class:`FaultPlan` against a live loop.

    The engine is held by the *supervisor* (it outlives train attempts) so
    fire-once faults stay fired across restarts — a crash injected at step
    N must not re-kill the relaunched attempt replaying step N, while a
    ``sticky`` nan keyed to a data step re-fires until the supervisor skips
    that batch.  Every injection lands in ``events`` and in ``ft.chaos.*``
    counters/trace instants.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.events: list[dict] = []
        self._fired: set[int] = set()          # indices of one-shot faults
        self._suppressed: dict[int, bool] = {}  # worker -> permanent?
        self._m = obs_metrics.active_registry().counter("ft.chaos.injected")

    # ------------------------------------------------------------- lifecycle
    def on_attempt_start(self) -> None:
        """A new train attempt begins: transiently-killed workers rejoin."""
        self._suppressed = {w: True for w, perm in self._suppressed.items()
                            if perm}

    def _record(self, fault: Fault, step: int, **extra) -> None:
        self._m.inc()
        ev = {"kind": fault.kind, "fault_step": fault.step, "step": step,
              **extra}
        self.events.append(ev)
        obs_trace.instant("ft.chaos", **ev)

    # ------------------------------------------------------------ injection
    def on_step_start(self, step: int) -> None:
        """Arm step-keyed faults: raises :class:`WorkerKilled` once for a
        ``crash`` fault, starts heartbeat suppression for ``kill`` faults."""
        for i, f in enumerate(self.plan):
            if f.kind == "crash" and f.step == step and i not in self._fired:
                self._fired.add(i)
                self._record(f, step)
                raise WorkerKilled(f"chaos: worker crash at step {step}",
                                   step=step)
        for i, f in enumerate(self.plan):
            if f.kind == "kill" and f.step == step and i not in self._fired:
                self._fired.add(i)
                self._suppressed[f.worker] = f.permanent
                self._record(f, step, worker=f.worker, permanent=f.permanent)

    def heartbeat_suppressed(self, worker: int) -> bool:
        return worker in self._suppressed

    def latency_factor(self, worker: int, step: int) -> float:
        """Multiplier applied to the latency ``worker`` reports at ``step``."""
        factor = 1.0
        for f in self.plan:
            if f.kind != "straggle" or f.worker != worker:
                continue
            end = f.step + f.duration if f.duration else float("inf")
            if f.step <= step < end:
                factor *= f.factor
        return factor

    def filter_loss(self, step: int, loss: float, *,
                    substituted: bool = False) -> float:
        """Return the (possibly poisoned) loss for ``step``.

        ``substituted=True`` means the loop replaced this step's batch (the
        supervisor's skip-window) — a sticky nan models data-dependent
        corruption, so it does not fire against the substitute batch."""
        for i, f in enumerate(self.plan):
            if f.kind != "nan" or f.step != step:
                continue
            if f.sticky:
                if not substituted:
                    self._record(f, step, sticky=True)
                    return float("nan")
            elif i not in self._fired:
                self._fired.add(i)
                self._record(f, step)
                return float("nan")
        return loss

    def wants_corrupt(self, saved_step: int) -> bool:
        return any(f.kind == "corrupt" and f.step <= saved_step
                   and i not in self._fired
                   for i, f in enumerate(self.plan))

    def corrupt_checkpoint(self, directory: str, saved_step: int) -> None:
        """Damage the on-disk checkpoint for ``saved_step`` (call after the
        write has finished — the loop joins the async writer first)."""
        for i, f in enumerate(self.plan):
            if f.kind != "corrupt" or f.step > saved_step \
                    or i in self._fired:
                continue
            self._fired.add(i)
            path = os.path.join(directory, f"step_{saved_step:08d}")
            corrupt_checkpoint_dir(path, f.mode)
            self._record(f, saved_step, mode=f.mode, path=path)


def corrupt_checkpoint_dir(path: str, mode: str = "truncate") -> None:
    """Damage one ``step_*`` checkpoint directory in a detectable way.

    Shared by the chaos engine and the checkpoint corruption tests so both
    exercise the exact same failure shapes ``CheckpointManager.verify``
    must catch."""
    arrays = os.path.join(path, "arrays.npz")
    manifest = os.path.join(path, "manifest.json")
    if mode == "truncate":
        size = os.path.getsize(arrays)
        with open(arrays, "r+b") as fh:
            fh.truncate(max(1, size // 2))
    elif mode == "bitflip":
        with open(arrays, "r+b") as fh:
            fh.seek(os.path.getsize(arrays) // 2)
            b = fh.read(1)
            fh.seek(-1, os.SEEK_CUR)
            fh.write(bytes([b[0] ^ 0xFF]))
    elif mode == "manifest":
        with open(manifest) as fh:
            m = json.load(fh)
        for k in m.get("hashes", {}):
            m["hashes"][k] = "0" * 64
        with open(manifest, "w") as fh:
            json.dump(m, fh)
    else:
        raise ValueError(f"unknown corrupt mode {mode!r}")
