"""repro.ft — fault tolerance: detection, injection, and supervised recovery.

* :mod:`repro.ft.manager` — transport-agnostic coordinator: heartbeats,
  straggler detection, restart/elastic-reshape policy.
* :mod:`repro.ft.chaos` — deterministic fault injection (seeded
  :class:`FaultPlan` + :class:`ChaosEngine`), drivable from tests and
  ``launch/train.py --chaos``.
* :mod:`repro.ft.supervisor` — the loop that consumes
  ``FTManager.decide()``: restart-from-checkpoint with bounded backoff,
  elastic re-meshing, and non-finite-loss rollback with a data skip-window.
* :mod:`repro.ft.errors` — the control-flow exceptions the train loop
  raises and the supervisor catches.
"""

from repro.ft.chaos import ChaosEngine, Fault, FaultPlan
from repro.ft.errors import (NonFiniteLossError, ReshapeRequired,
                             RestartBudgetExhausted, RestartRequired,
                             TrainFailure, WorkerKilled)
from repro.ft.manager import Action, FTConfig, FTManager
from repro.ft.supervisor import Supervisor, SupervisorConfig

__all__ = [
    "Action", "ChaosEngine", "Fault", "FaultPlan", "FTConfig", "FTManager",
    "NonFiniteLossError", "ReshapeRequired", "RestartBudgetExhausted",
    "RestartRequired", "Supervisor", "SupervisorConfig", "TrainFailure",
    "WorkerKilled",
]
