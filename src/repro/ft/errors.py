"""Fault-tolerance control-flow exceptions.

The training loop signals failures by raising; the supervisor
(:mod:`repro.ft.supervisor`) is the only intended catcher.  Keeping them in
their own module breaks the import cycle between ``train/loop.py`` (raises)
and ``ft/supervisor.py`` (catches and re-enters the loop).
"""

from __future__ import annotations

from typing import Any


class TrainFailure(RuntimeError):
    """Base class for failures the supervisor knows how to recover from."""

    def __init__(self, msg: str, *, step: int | None = None,
                 info: dict[str, Any] | None = None):
        super().__init__(msg)
        self.step = step
        self.info = info or {}


class WorkerKilled(TrainFailure):
    """A worker process died mid-step (chaos ``crash`` fault, or a real
    uncaught crash surfaced by the launch fabric)."""


class RestartRequired(TrainFailure):
    """``FTManager.decide()`` returned RESTART_FROM_CKPT: relaunch on the
    same mesh from the newest verified checkpoint."""


class ReshapeRequired(TrainFailure):
    """``FTManager.decide()`` returned ELASTIC_RESHAPE: capacity was lost
    permanently; ``target`` is the (shape, axes) ladder mesh to rebuild."""

    def __init__(self, msg: str, *, target: tuple, step: int | None = None,
                 info: dict[str, Any] | None = None):
        super().__init__(msg, step=step, info=info)
        self.target = target


class NonFiniteLossError(TrainFailure):
    """The loss went NaN/inf at ``step``.  The supervisor rolls back to the
    last verified checkpoint and skips a window of data steps around the
    offending batch instead of crashing (or, worse, training on garbage)."""

    def __init__(self, step: int, loss: float):
        super().__init__(f"non-finite loss {loss!r} at step {step}", step=step)
        self.loss = loss


class RestartBudgetExhausted(RuntimeError):
    """The supervisor gave up: more failures than ``max_restarts`` allows."""
