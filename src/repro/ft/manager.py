"""Fault-tolerance manager: heartbeats, straggler detection, restart policy,
and elastic re-meshing decisions.

At 1000+-node scale the failure model is: workers heartbeat step latencies to
a coordinator; the coordinator (this class) detects dead nodes (missed
heartbeats), stragglers (latency z-score), and decides between
  * CONTINUE          — healthy
  * RESTART_FROM_CKPT — a worker died; relaunch on the same mesh
  * ELASTIC_RESHAPE   — capacity permanently lost; pick the largest viable
                        mesh from survivors and restore (checkpoint/ckpt.py's
                        mesh-independent restore makes this a pure relaunch)
The coordinator is deliberately transport-agnostic (heartbeats are fed in by
whatever fabric exists — GRPC, GCS, SLURM); tests drive it with synthetic
timelines, and launch/train.py wires it to the local loop.
"""

from __future__ import annotations

import dataclasses
import enum
import math
import time
from typing import Any


class Action(enum.Enum):
    CONTINUE = "continue"
    RESTART_FROM_CKPT = "restart"
    ELASTIC_RESHAPE = "elastic"


@dataclasses.dataclass
class FTConfig:
    heartbeat_timeout_s: float = 60.0
    straggler_zscore: float = 3.0
    straggler_min_samples: int = 16
    max_restarts: int = 100
    chips_per_worker: int = 8          # v5e: 8 chips per host
    # meshes we may elastically fall back to, largest first: (shape, axes)
    mesh_ladder: tuple = (
        ((2, 16, 16), ("pod", "data", "model")),
        ((16, 16), ("data", "model")),
        ((8, 16), ("data", "model")),
        ((4, 16), ("data", "model")),
    )


@dataclasses.dataclass
class WorkerState:
    last_seen: float = 0.0
    latencies: list = dataclasses.field(default_factory=list)
    alive: bool = True


class FTManager:
    def __init__(self, n_workers: int, cfg: FTConfig | None = None,
                 clock=time.monotonic):
        # cfg=None -> a fresh FTConfig per manager: a shared default instance
        # would alias ladder/threshold mutations across managers (the same
        # mutable-default bug class as TuneConfig, fixed in PR 2)
        self.cfg = cfg if cfg is not None else FTConfig()
        self.clock = clock
        self.workers = {i: WorkerState(last_seen=clock())
                        for i in range(n_workers)}
        self.restarts = 0
        self.events: list[dict[str, Any]] = []

    # ------------------------------------------------------------ heartbeats
    def refresh(self, now: float | None = None) -> None:
        """Reset every live worker's liveness deadline.  The supervisor
        calls this when an attempt (re)starts: time spent in backoff or
        checkpoint restore must not read as missed heartbeats."""
        now = self.clock() if now is None else now
        for w in self.workers.values():
            if w.alive:
                w.last_seen = now

    def heartbeat(self, worker: int, step_latency_s: float | None = None):
        w = self.workers[worker]
        w.last_seen = self.clock()
        w.alive = True
        if step_latency_s is not None:
            w.latencies.append(step_latency_s)
            if len(w.latencies) > 256:
                del w.latencies[:128]

    def dead_workers(self) -> list[int]:
        now = self.clock()
        return [i for i, w in self.workers.items()
                if w.alive and now - w.last_seen > self.cfg.heartbeat_timeout_s]

    # ------------------------------------------------------------ stragglers
    def stragglers(self) -> list[int]:
        """Workers whose recent latency is an outlier vs the fleet median.

        Median-ratio rather than z-score: with few workers a single big
        outlier inflates the stddev enough to hide itself; the median is
        robust to it.  A worker is a straggler when its recent mean exceeds
        ``straggler_zscore`` x the fleet median (the config knob is reused
        as the ratio)."""
        means = {i: sum(w.latencies[-16:]) / len(w.latencies[-16:])
                 for i, w in self.workers.items()
                 if w.alive and len(w.latencies) >= self.cfg.straggler_min_samples}
        if len(means) < 4:
            return []
        vals = sorted(means.values())
        med = vals[len(vals) // 2]
        if med <= 0:
            return []
        return [i for i, v in means.items()
                if v / med > self.cfg.straggler_zscore]

    # --------------------------------------------------------------- policy
    def decide(self) -> tuple[Action, dict[str, Any]]:
        dead = self.dead_workers()
        if dead:
            for i in dead:
                self.workers[i].alive = False
            self.restarts += 1
            alive = sum(w.alive for w in self.workers.values())
            info = {"dead": dead, "alive": alive, "restarts": self.restarts}
            self.events.append({"t": self.clock(), "action": "failure", **info})
            if self.restarts > self.cfg.max_restarts:
                raise RuntimeError("restart budget exhausted")
            # permanent capacity loss -> reshape; transient -> plain restart
            target = self.viable_mesh(alive)
            if target is not None and target != self.cfg.mesh_ladder[0]:
                info["mesh"] = target
                return Action.ELASTIC_RESHAPE, info
            return Action.RESTART_FROM_CKPT, info
        stragglers = self.stragglers()
        if stragglers:
            self.events.append({"t": self.clock(), "action": "straggler",
                                "workers": stragglers})
            return Action.CONTINUE, {"stragglers": stragglers,
                                     "mitigation": "reroute-or-replace"}
        return Action.CONTINUE, {}

    def viable_mesh(self, alive_workers: int):
        """Largest ladder mesh that fits the surviving worker count
        (``cfg.chips_per_worker`` chips per host; 8 on v5e)."""
        chips = alive_workers * self.cfg.chips_per_worker
        for shape, axes in self.cfg.mesh_ladder:
            need = math.prod(shape)
            if need <= chips:
                return (shape, axes)
        return None
