"""Supervisor loop — the consumer of ``FTManager.decide()``.

The train loop is a plain function that RAISES on failure (worker death,
FT-manager verdicts, non-finite loss); this module is the outer shell that
catches, repairs, and re-enters it:

* :class:`~repro.ft.errors.WorkerKilled` / ``RestartRequired`` —
  re-enter ``train()`` on the same mesh.  The loop restores from the newest
  *verified* checkpoint itself, so a restart is a pure relaunch; attempts
  are spaced by bounded exponential backoff.
* :class:`~repro.ft.errors.ReshapeRequired` — capacity was lost for good:
  rebuild the mesh from the failure's ladder target (``mesh_factory``) and
  relaunch; the checkpoint restore re-shards every leaf onto the new mesh's
  ``NamedSharding``s (mesh-independent checkpoints make this free).
* :class:`~repro.ft.errors.NonFiniteLossError` — roll back to the last
  checkpoint and widen the data skip-window over the offending step so the
  bad batch is replaced with a disjoint substitute instead of re-exploding.

Every recovery lands in ``ft.*`` counters and trace instants, and in the
returned result's ``supervisor`` summary.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.ft.chaos import ChaosEngine
from repro.ft.errors import (NonFiniteLossError, ReshapeRequired,
                             RestartBudgetExhausted, RestartRequired,
                             TrainFailure, WorkerKilled)
from repro.ft.manager import FTManager
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class SupervisorConfig:
    max_restarts: int = 8               # attempts beyond the first
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    backoff_max_s: float = 5.0
    nan_skip_window: int = 1            # data steps skipped per nan rollback


class Supervisor:
    """Runs ``train_fn`` to completion across failures.

    ``train_fn(mesh=..., skip_data_steps=...)`` is the (partially applied)
    training entry point — usually :func:`repro.train.loop.train` with
    everything but the supervisor-owned arguments bound.  ``mesh_factory``
    maps an :class:`~repro.ft.errors.ReshapeRequired` ladder target
    ``(shape, axes)`` to a live mesh; without one, elastic events fall back
    to ``mesh=None`` (single-device relaunch — still correct, just smaller).
    """

    def __init__(self, train_fn: Callable[..., dict[str, Any]], *,
                 ft: FTManager | None = None,
                 chaos: ChaosEngine | None = None,
                 mesh: Any = None,
                 mesh_factory: Callable[[tuple], Any] | None = None,
                 cfg: SupervisorConfig | None = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.train_fn = train_fn
        self.ft = ft
        self.chaos = chaos
        self.mesh = mesh
        self.mesh_factory = mesh_factory
        self.cfg = cfg if cfg is not None else SupervisorConfig()
        self.sleep = sleep
        self.events: list[dict[str, Any]] = []
        self.skip_data_steps: set[int] = set()

    # ---------------------------------------------------------------- events
    def _record(self, kind: str, attempt: int, **info) -> None:
        reg = obs_metrics.active_registry()
        reg.counter(f"ft.{kind}").inc()
        ev = {"kind": kind, "attempt": attempt, **info}
        self.events.append(ev)
        obs_trace.instant(f"ft.{kind}", **{k: v for k, v in ev.items()
                                           if not isinstance(v, (list, dict))})

    def _backoff(self, restarts: int) -> float:
        c = self.cfg
        return min(c.backoff_base_s * c.backoff_factor ** (restarts - 1),
                   c.backoff_max_s)

    # ------------------------------------------------------------------ run
    def run(self) -> dict[str, Any]:
        """Train to completion; returns the final train result annotated
        with a ``supervisor`` summary.  Raises
        :class:`RestartBudgetExhausted` after ``max_restarts`` failures."""
        mesh = self.mesh
        restarts = 0
        while True:
            if self.ft is not None:
                self.ft.refresh()       # a backoff pause is not a death
            if self.chaos is not None:
                self.chaos.on_attempt_start()
            try:
                with obs_trace.span("ft.attempt", attempt=restarts,
                                    skip=len(self.skip_data_steps)):
                    res = self.train_fn(
                        mesh=mesh,
                        skip_data_steps=frozenset(self.skip_data_steps))
                res["supervisor"] = {
                    "attempts": restarts + 1,
                    "events": list(self.events),
                    "skip_data_steps": sorted(self.skip_data_steps),
                    "final_mesh": _mesh_summary(mesh),
                }
                return res
            except NonFiniteLossError as e:
                lo = e.step
                self.skip_data_steps.update(
                    range(lo, lo + self.cfg.nan_skip_window))
                self._record("nonfinite_rollback", restarts, step=e.step,
                             skip_window=self.cfg.nan_skip_window)
            except ReshapeRequired as e:
                if self.mesh_factory is not None:
                    mesh = self.mesh_factory(e.target)
                else:
                    mesh = None
                self._record("elastic_reshape", restarts, step=e.step,
                             target=list(e.target[0]), **_safe_info(e))
            except (WorkerKilled, RestartRequired) as e:
                self._record("restart", restarts, step=e.step,
                             cause=type(e).__name__, **_safe_info(e))
            restarts += 1
            if restarts > self.cfg.max_restarts:
                raise RestartBudgetExhausted(
                    f"supervisor gave up after {restarts - 1} restarts "
                    f"(events: {[e['kind'] for e in self.events]})")
            delay = self._backoff(restarts)
            obs_metrics.active_registry().histogram(
                "ft.backoff_s").record(delay)
            self.sleep(delay)


def _mesh_summary(mesh: Any) -> Any:
    """(shape, axes) for a jax Mesh; whatever the caller passed otherwise
    (tests drive the supervisor with stand-in mesh objects)."""
    if mesh is None:
        return None
    if hasattr(mesh, "shape") and hasattr(mesh, "axis_names"):
        return (tuple(mesh.shape.values()), tuple(mesh.axis_names))
    return mesh


def _safe_info(e: TrainFailure) -> dict[str, Any]:
    """Failure info fields that are safe to splat into an event record."""
    return {k: v for k, v in e.info.items()
            if isinstance(v, (str, int, float, bool))}
