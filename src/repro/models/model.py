"""Top-level models: decoder LMs (dense/MoE/VLM), Mamba-2, Zamba-2 hybrid,
and the encoder-decoder (audio) — with train, prefill and decode entry points.

Layer stacks run under ``jax.lax.scan`` over stacked per-layer params with
optional remat — O(1) HLO size in depth (what makes the 80-compile dry-run
feasible) and the production-standard choice at 1000+-node scale.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.partition import shard
from repro.models import attention as attn_mod
from repro.models import blocks
from repro.models import modules as nn
from repro.models.config import ModelConfig

Params = Any


# ===================================================================== init
def init_lm(key, cfg: ModelConfig) -> Params:
    cfg.validate()
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    p: dict[str, Any] = {}
    if cfg.family != "enc_dec":
        p["embed"] = nn.param(ks[0], (cfg.vocab, d), ("vocab", "embed"),
                              scale=1.0)
        p["ln_f"] = nn.rmsnorm_init(ks[1], d)
        p["lm_head"] = nn.param(ks[2], (d, cfg.vocab), ("embed", "vocab"),
                                scale=d ** -0.5)
    if cfg.family in ("dense", "moe", "vlm"):
        p["blocks"] = nn.stack_layers(
            lambda k: blocks.init_decoder_block(k, cfg), ks[3], cfg.n_layers)
    elif cfg.family == "ssm":
        p["blocks"] = nn.stack_layers(
            lambda k: blocks.init_mamba_block(k, cfg), ks[3], cfg.n_layers)
    elif cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.hybrid_group
        trailing = cfg.n_layers % cfg.hybrid_group
        p["groups"] = nn.stack_layers(
            lambda k: blocks.init_hybrid_group(k, cfg), ks[3], n_groups)
        p["shared_attn"] = blocks.init_decoder_block(ks[4], cfg)
        if trailing:
            p["trailing"] = nn.stack_layers(
                lambda k: blocks.init_mamba_block(k, cfg), ks[5], trailing)
    elif cfg.family == "enc_dec":
        p["enc_blocks"] = nn.stack_layers(
            lambda k: blocks.init_decoder_block(k, cfg), ks[3], cfg.enc_layers)
        p["enc_ln"] = nn.rmsnorm_init(ks[4], d)
        p["dec_embed"] = nn.param(ks[5], (cfg.vocab, d), ("vocab", "embed"),
                                  scale=1.0)
        p["dec_blocks"] = nn.stack_layers(
            lambda k: blocks.init_decoder_block(k, cfg, cross=True), ks[6],
            cfg.dec_layers)
        p["dec_ln"] = nn.rmsnorm_init(ks[7], d)
        p["lm_head"] = nn.param(ks[8], (d, cfg.vocab), ("embed", "vocab"),
                                scale=d ** -0.5)
    else:
        raise ValueError(cfg.family)
    if cfg.param_dtype != "float32":
        pd = jnp.dtype(cfg.param_dtype)
        p = jax.tree.map(
            lambda prm: nn.Param(prm.value.astype(pd), prm.axes)
            if jnp.issubdtype(prm.value.dtype, jnp.floating) else prm,
            p, is_leaf=nn.is_param)
    return p


def init_lm_shapes(key, cfg: ModelConfig):
    """Shape-only init (no allocation) — dry-run entry point."""
    return jax.eval_shape(functools.partial(init_lm, cfg=cfg), key)


def param_logical_axes(cfg: ModelConfig):
    """Logical partition axes for every param leaf, recovered without
    allocating (``nn.Param`` carries its axes through ``eval_shape``).  Lets
    sharded serving derive param shardings from a plain (unwrapped) param
    tree — the tree structure matches ``nn.unwrap(init_lm(...))``."""
    return nn.axes_of(init_lm_shapes(jax.random.PRNGKey(0), cfg))


# =============================================================== scan utils
def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        # save matmul outputs, recompute the cheap elementwise ops only —
        # trades activation memory for a large cut in recompute FLOPs/bytes
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _scan(fn, carry, xs, cfg: ModelConfig):
    if cfg.scan_layers:
        return jax.lax.scan(_maybe_remat(fn, cfg), carry, xs)
    f = _maybe_remat(fn, cfg)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = f(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    stacked = (jax.tree.map(lambda *a: jnp.stack(a), *ys)
               if ys and ys[0] is not None else None)
    return carry, stacked


def _sum_aux(aux):
    return {k: jnp.sum(v) for k, v in aux.items()} if aux else {}


# ============================================================== forward (train)
def embed_inputs(p, inputs: dict[str, jnp.ndarray], cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    if cfg.input_mode == "embeddings" and "embeds" in inputs:
        x = inputs["embeds"].astype(dt)
    else:
        x = p["embed"].astype(dt)[inputs["tokens"]]
    return shard(x, "batch", "act_seq" if cfg.seq_shard else "seq", None)


def forward(p: Params, inputs: dict[str, jnp.ndarray], cfg: ModelConfig):
    """Training/eval forward -> (logits, aux).  Decoder families."""
    if cfg.family == "enc_dec":
        return _forward_enc_dec(p, inputs, cfg)
    x = embed_inputs(p, inputs, cfg)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, lp):
            h, aux, _ = blocks.decoder_block(lp, h, cfg, causal=True)
            return h, aux
        x, aux = _scan(body, x, p["blocks"], cfg)
        aux = _sum_aux(aux)
    elif cfg.family == "ssm":
        def body(h, lp):
            h, _ = blocks.mamba_block(lp, h, cfg)
            return h, blocks.ZERO_AUX()
        x, aux = _scan(body, x, p["blocks"], cfg)
        aux = _sum_aux(aux)
    elif cfg.family == "hybrid":
        x, aux = _hybrid_forward(p, x, cfg)
    else:
        raise ValueError(cfg.family)

    x = nn.rmsnorm_apply(p["ln_f"], x)
    logits = x @ p["lm_head"].astype(x.dtype)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux


def _hybrid_forward(p, x, cfg: ModelConfig):
    n_groups = cfg.n_layers // cfg.hybrid_group
    flags = _hybrid_flags(cfg, n_groups)

    def body(h, xs):
        gp, flag = xs
        h, _, _ = blocks.hybrid_group(gp, p["shared_attn"], h, cfg, flag)
        return h, blocks.ZERO_AUX()

    x, aux = _scan(body, x, (p["groups"], flags), cfg)
    if "trailing" in p:
        def tbody(h, lp):
            h, _ = blocks.mamba_block(lp, h, cfg)
            return h, None
        x, _ = _scan(tbody, x, p["trailing"], cfg)
    return x, _sum_aux(aux)


def _hybrid_flags(cfg: ModelConfig, n_groups: int):
    every = cfg.hybrid_attn_every
    return (jnp.arange(n_groups) % every) == (every - 1)


def _forward_enc_dec(p, inputs, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    enc = shard(inputs["enc_embeds"].astype(dt), "batch", "seq", None)

    def enc_body(h, lp):
        h, aux, _ = blocks.decoder_block(lp, h, cfg, causal=False)
        return h, aux
    enc, enc_aux = _scan(enc_body, enc, p["enc_blocks"], cfg)
    enc = nn.rmsnorm_apply(p["enc_ln"], enc)

    x = p["dec_embed"].astype(dt)[inputs["tokens"]]
    x = shard(x, "batch", "seq", None)

    def dec_body(h, lp):
        kv = attn_mod.encode_kv(lp["xattn"], enc, cfg)
        h, aux, _ = blocks.decoder_block(lp, h, cfg, causal=True, cross_kv=kv)
        return h, aux
    x, dec_aux = _scan(dec_body, x, p["dec_blocks"], cfg)
    x = nn.rmsnorm_apply(p["dec_ln"], x)
    logits = x @ p["lm_head"].astype(x.dtype)
    logits = shard(logits, "batch", "seq", "vocab")
    aux = {k: _sum_aux(enc_aux).get(k, 0.0) + _sum_aux(dec_aux).get(k, 0.0)
           for k in ("load_balance", "router_z")}
    return logits, aux


# ===================================================================== loss
def loss_fn(p: Params, batch: dict[str, jnp.ndarray], cfg: ModelConfig):
    logits, aux = forward(p, batch, cfg)
    labels = batch["labels"]
    mask = batch.get("mask")
    lf = logits.astype(jnp.float32)
    if cfg.logits_microbatch > 1:
        # chunk the softmax over the sequence dim to bound live logits memory
        nchunks = cfg.logits_microbatch
        s = labels.shape[1]
        assert s % nchunks == 0
        cs = s // nchunks
        def chunk_loss(i):
            sl = jax.lax.dynamic_slice_in_dim(lf, i * cs, cs, axis=1)
            ll = jax.lax.dynamic_slice_in_dim(labels, i * cs, cs, axis=1)
            return _xent(sl, ll)
        per = jax.lax.map(chunk_loss, jnp.arange(nchunks))
        token_loss = jnp.moveaxis(per, 0, 1).reshape(labels.shape)
    else:
        token_loss = _xent(lf, labels)
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(token_loss * mask) / denom
    else:
        loss = jnp.mean(token_loss)
    total = loss + sum(aux.values()) if aux else loss
    metrics = {"loss": loss, **{f"aux/{k}": v for k, v in aux.items()}}
    return total, metrics


def _xent(logits_f32, labels):
    lse = jax.nn.logsumexp(logits_f32, axis=-1)
    gold = jnp.take_along_axis(logits_f32, labels[..., None], axis=-1)[..., 0]
    return lse - gold


# ============================================================ prefill / decode
def prefill(p: Params, inputs: dict[str, jnp.ndarray], cfg: ModelConfig,
            max_len: int):
    """Forward over the prompt, building decode caches sized ``max_len``.
    Returns (last_token_logits, caches)."""
    if cfg.family == "enc_dec":
        return _prefill_enc_dec(p, inputs, cfg, max_len)
    x = embed_inputs(p, inputs, cfg)
    s = x.shape[1]

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, lp):
            h, _, cache = blocks.decoder_block(lp, h, cfg, causal=True,
                                               return_cache=True)
            return h, cache
        x, caches = _scan(body, x, p["blocks"], cfg)
        caches = _pad_kv_caches(caches, cfg, max_len)
    elif cfg.family == "ssm":
        def body(h, lp):
            h, st = blocks.mamba_block(lp, h, cfg, return_state=True)
            return h, st
        x, caches = _scan(body, x, p["blocks"], cfg)
    elif cfg.family == "hybrid":
        x, caches = _hybrid_prefill(p, x, cfg, max_len)
    else:
        raise ValueError(cfg.family)

    x = nn.rmsnorm_apply(p["ln_f"], x[:, -1:])
    logits = (x @ p["lm_head"].astype(x.dtype))[:, 0]
    return logits, caches


def _kv_cache_len(cfg: ModelConfig, max_len: int) -> int:
    """Rolling window cache for SWA models (production ring buffer)."""
    return min(max_len, cfg.window) if cfg.window else max_len


def _pad_kv_caches(caches, cfg: ModelConfig, max_len: int):
    m = _kv_cache_len(cfg, max_len)

    def pad(kv):   # (L, B, S, H, D) -> (L, B, m, H, D)
        l, b, s, h, hd = kv.shape
        if s == m:
            return jnp.roll(kv, s % m, axis=2) if s % m else kv
        if s > m:   # keep the last window, rolled so slot(p) = p % m
            return jnp.roll(kv[:, :, s - m:], s % m, axis=2)
        buf = jnp.zeros((l, b, m, h, hd), kv.dtype)
        return jax.lax.dynamic_update_slice(buf, kv, (0, 0, 0, 0, 0))

    return {"k": pad(caches["k"]), "v": pad(caches["v"]),
            "len": caches["len"]}


def _hybrid_prefill(p, x, cfg: ModelConfig, max_len: int):
    n_groups = cfg.n_layers // cfg.hybrid_group
    flags = _hybrid_flags(cfg, n_groups)

    def body(h, xs):
        gp, flag = xs
        h, states, cache = blocks.hybrid_group(gp, p["shared_attn"], h, cfg,
                                               flag, return_state=True)
        return h, (states, cache)
    x, (states, attn_caches) = _scan(body, x, (p["groups"], flags), cfg)
    attn_caches = _pad_kv_caches(attn_caches, cfg, max_len)
    caches = {"mamba": states, "attn": attn_caches}
    if "trailing" in p:
        def tbody(h, lp):
            h, st = blocks.mamba_block(lp, h, cfg, return_state=True)
            return h, st
        x, tstates = _scan(tbody, x, p["trailing"], cfg)
        caches["trailing"] = tstates
    return x, caches


def _prefill_enc_dec(p, inputs, cfg: ModelConfig, max_len: int):
    dt = jnp.dtype(cfg.dtype)
    enc = inputs["enc_embeds"].astype(dt)

    def enc_body(h, lp):
        h, _, _ = blocks.decoder_block(lp, h, cfg, causal=False)
        return h, None
    enc, _ = _scan(enc_body, enc, p["enc_blocks"], cfg)
    enc = nn.rmsnorm_apply(p["enc_ln"], enc)

    x = p["dec_embed"].astype(dt)[inputs["tokens"]]

    def dec_body(h, lp):
        kv = attn_mod.encode_kv(lp["xattn"], enc, cfg)
        h, _, cache = blocks.decoder_block(lp, h, cfg, causal=True,
                                           return_cache=True, cross_kv=kv)
        return h, (cache, kv)
    x, (self_caches, cross_kvs) = _scan(dec_body, x, p["dec_blocks"], cfg)
    x = nn.rmsnorm_apply(p["dec_ln"], x[:, -1:])
    logits = (x @ p["lm_head"].astype(x.dtype))[:, 0]
    return logits, {"self": _pad_kv_caches(self_caches, cfg, max_len),
                    "cross": cross_kvs}


def decode_step(p: Params, caches, tokens: jnp.ndarray, cfg: ModelConfig, *,
                pt: jnp.ndarray | None = None,
                active: jnp.ndarray | None = None):
    """One decode step.  tokens: (B,) int32 -> (logits (B, vocab), caches).

    ``pt`` (B, n_pages) routes attention-family cache traffic through a paged
    store (see :func:`alloc_paged_caches`); ``active`` (B,) masks rows that
    must neither write real pages nor advance (idle slots, slots mid
    chunked-prefill) — their scatters land in the trash page."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "enc_dec":
        return _decode_enc_dec(p, caches, tokens, cfg)
    if cfg.family in ("dense", "moe", "vlm"):
        logits, caches = decode_tokens(p, caches, tokens[:, None], cfg,
                                       pt=pt, active=active)
        return logits[:, 0], caches
    if pt is not None:
        raise ValueError(f"paged decode supports attention families "
                         f"(dense/moe/vlm), not {cfg.family!r}")
    x = p["embed"].astype(dt)[tokens][:, None, :]       # (B, 1, d)
    x = shard(x, "batch", "seq", None)

    if cfg.family == "ssm":
        def body(h, xs):
            lp, st = xs
            h, st = blocks.mamba_block(lp, h, cfg, state=st)
            return h, st
        x, caches = _scan(body, x, (p["blocks"], caches), cfg)
    elif cfg.family == "hybrid":
        x, caches = _hybrid_decode(p, x, caches, cfg)
    else:
        raise ValueError(cfg.family)

    x = nn.rmsnorm_apply(p["ln_f"], x)
    logits = (x @ p["lm_head"].astype(x.dtype))[:, 0]
    return logits, caches


def decode_tokens(p: Params, caches, tokens: jnp.ndarray, cfg: ModelConfig, *,
                  pt: jnp.ndarray | None = None,
                  active: jnp.ndarray | None = None,
                  n_valid: jnp.ndarray | None = None,
                  embeds: jnp.ndarray | None = None):
    """Cache-advancing forward over ``tokens`` (B, S) for the attention
    families -> (logits (B, S, vocab), caches).

    The S == 1 case is the lockstep decode step; S > 1 is a *chunked
    prefill* step (a prompt chunk pushed through the decode path, so long
    prompts interleave with decode instead of stalling the batch).  The
    paged-cache routing keys are injected into each layer's cache dict and
    consumed (and stripped) by ``attention.py``'s paged branch:

    * ``pt`` (B, n_pages) int32 — per-slot page tables over the page store;
    * ``active`` (B,) bool — rows that may write real pages and advance;
    * ``n_valid`` scalar — how many of the S positions are real (a padded
      final chunk advances ``len`` by n_valid and its logits are read at
      position n_valid - 1).

    ``embeds`` (B, S, d) replaces the token embedding lookup for
    embedding-prompt (VLM) chunks.
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"decode_tokens supports attention families "
                         f"(dense/moe/vlm), not {cfg.family!r}")
    dt = jnp.dtype(cfg.dtype)
    x = embeds.astype(dt) if embeds is not None \
        else p["embed"].astype(dt)[tokens]              # (B, S, d)
    x = shard(x, "batch", "seq", None)

    def body(h, xs):
        lp, cache = xs
        if pt is not None:
            cache = dict(cache, pt=pt)
            if active is not None:
                cache["active"] = active
            if n_valid is not None:
                cache["n_valid"] = n_valid
        h, _, cache = blocks.decoder_block(
            lp, h, cfg, causal=True, pos_offset=cache["len"], cache=cache)
        return h, cache

    x, caches = _scan(body, x, (p["blocks"], caches), cfg)
    x = nn.rmsnorm_apply(p["ln_f"], x)
    logits = x @ p["lm_head"].astype(x.dtype)
    return logits, caches


def _hybrid_decode(p, x, caches, cfg: ModelConfig):
    n_groups = cfg.n_layers // cfg.hybrid_group
    flags = _hybrid_flags(cfg, n_groups)

    def body(h, xs):
        (gp, flag), (states, cache) = xs
        h, states, cache = blocks.hybrid_group(
            gp, p["shared_attn"], h, cfg, flag, states=states,
            attn_cache=cache, pos_offset=cache["len"])
        return h, (states, cache)
    x, (mstates, acaches) = _scan(
        body, x, ((p["groups"], flags), (caches["mamba"], caches["attn"])), cfg)
    new = {"mamba": mstates, "attn": acaches}
    if "trailing" in p:
        def tbody(h, xs):
            lp, st = xs
            h, st = blocks.mamba_block(lp, h, cfg, state=st)
            return h, st
        x, tstates = _scan(tbody, x, (p["trailing"], caches["trailing"]), cfg)
        new["trailing"] = tstates
    return x, new


# ====================================================== cache logical axes
def cache_logical_axes(cfg: ModelConfig):
    """Logical partition axes for each decode-cache leaf (the same tree
    structure ``prefill`` returns, for every family).  This is the canonical
    table both training (``launch.steps.cache_shardings``) and sharded
    serving consume — under a 1-D ``("model",)`` serving mesh only the
    head-like axes (kv_heads / ssm_heads / conv_ch) resolve to a mesh axis,
    which is exactly the seam paged and per-slot stores shard on."""
    kv = {"k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
          "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
          "len": ("layers",)}
    ssm = {"conv": (None, "batch", None, "conv_ch"),
           "ssd": (None, "batch", "ssm_heads", "ssm_state", None)}
    ssm_g = {"conv": (None, None, "batch", None, "conv_ch"),
             "ssd": (None, None, "batch", "ssm_heads", "ssm_state", None)}
    if cfg.family in ("dense", "moe", "vlm"):
        return kv
    if cfg.family == "ssm":
        return ssm
    if cfg.family == "hybrid":
        out = {"mamba": ssm_g, "attn": kv}
        if cfg.n_layers % cfg.hybrid_group:
            out["trailing"] = ssm
        return out
    if cfg.family == "enc_dec":
        x = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        return {"self": kv, "cross": (x, x)}
    raise ValueError(cfg.family)


def _is_logical_leaf(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def serve_cache_axes(cfg: ModelConfig, slot_axes):
    """Adapt :func:`cache_logical_axes` to the serving cache layouts.

    ``slot_axes`` is the per-leaf marker tree ``alloc_slot_caches`` /
    ``alloc_paged_caches`` return: leaves marked :data:`SLOT_AXIS_SHARED`
    gained a TRAILING slot axis (per-layer ``len`` scalars became
    ``(L, capacity)``), so their logical tuple gains a trailing ``None``;
    paged stores keep the canonical 5-axis tuple (the page-id dim sits where
    ``batch`` was and resolves to the same mesh axes — replicated on a
    model-only serving mesh).  The result feeds ``dist.partition``
    (``tree_shardings`` / ``resolve_spec``) directly.
    """
    logical = cache_logical_axes(cfg)
    return jax.tree.map(
        lambda la, ax: tuple(la) + (None,) if ax == SLOT_AXIS_SHARED
        else tuple(la),
        logical, slot_axes, is_leaf=_is_logical_leaf)


# ============================================== per-slot caches (cont. batching)
# The continuous-batching engine (repro.serve.engine.ContinuousEngine) keeps a
# fixed-capacity decode batch whose slots hold independent requests.  Each
# request is prefilled alone (batch 1) and its cache segment is spliced into
# its slot; cache-position leaves ("len") become per-slot vectors so decode
# masks/rope run at each slot's own offset (attention.py handles the (B,)
# form).  The batch axis of every cache leaf is discovered STRUCTURALLY — by
# diffing ``jax.eval_shape`` of prefill at two batch sizes — so the helpers
# work for every family (KV caches, SSM states, hybrid, enc-dec) without a
# per-family layout table.

#: sentinel axis for cache leaves whose shape does not depend on batch (the
#: per-layer "len" scalars); they gain a trailing slot axis instead
SLOT_AXIS_SHARED = -1


def _cache_shapes(p: Params, cfg: ModelConfig, max_len: int, batch: int,
                  example_inputs: dict[str, jnp.ndarray]):
    """Shape-only prefill -> decode-cache ShapeDtypeStructs at ``batch``."""
    inputs = {k: jax.ShapeDtypeStruct((batch,) + tuple(v.shape[1:]),
                                      jnp.asarray(v).dtype)
              for k, v in example_inputs.items()}
    _, caches = jax.eval_shape(
        functools.partial(prefill, cfg=cfg, max_len=max_len), p, inputs)
    return caches


def slot_cache_axes(p: Params, cfg: ModelConfig, max_len: int,
                    example_inputs: dict[str, jnp.ndarray]):
    """Per-leaf batch axis of the decode-cache pytree.

    Exactly one axis of each batch-dependent leaf changes when the prefill
    batch changes (batch enters every leaf at most once); leaves that do not
    change (cache-position scalars) map to :data:`SLOT_AXIS_SHARED`.
    """
    a = _cache_shapes(p, cfg, max_len, 2, example_inputs)
    b = _cache_shapes(p, cfg, max_len, 3, example_inputs)

    def axis(sa, sb) -> int:
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        if not diff:
            return SLOT_AXIS_SHARED
        assert len(diff) == 1, f"ambiguous batch axis for {sa.shape}"
        return diff[0]

    return jax.tree.map(axis, a, b)


def alloc_slot_caches(p: Params, cfg: ModelConfig, capacity: int,
                      max_len: int, example_inputs: dict[str, jnp.ndarray]):
    """Zero-initialized decode caches for ``capacity`` slots.

    Shared (batch-independent) leaves become per-slot vectors via a trailing
    slot axis — scanning over the layer axis then yields a (B,) ``len`` per
    layer, which the attention/rope per-slot paths consume directly.
    Returns ``(caches, axes)``; ``axes`` is what insert/evict need.
    """
    shapes = _cache_shapes(p, cfg, max_len, 1, example_inputs)
    axes = slot_cache_axes(p, cfg, max_len, example_inputs)

    def alloc(leaf, ax):
        if ax == SLOT_AXIS_SHARED:
            return jnp.zeros(leaf.shape + (capacity,), leaf.dtype)
        shp = list(leaf.shape)
        shp[ax] = capacity
        return jnp.zeros(shp, leaf.dtype)

    return jax.tree.map(alloc, shapes, axes), axes


def insert_slots(caches, group_caches, slots, axes):
    """Splice a batch-G prefill cache into slots ``slots`` ((G,) int32) of a
    batched cache — one scatter per leaf, so admitting a whole same-length
    group costs one dispatch instead of G cache-sized copies.

    ``slots`` may hold any (non-contiguous) slot ids; the group must share
    one prompt length, so shared leaves (per-layer lengths) are one scalar
    per layer broadcast across the group's slots.
    """
    g = slots.shape[0]

    def ins(batch_leaf, grp, ax):
        grp = jnp.asarray(grp).astype(batch_leaf.dtype)
        if ax == SLOT_AXIS_SHARED:
            tiled = jnp.broadcast_to(grp[..., None], grp.shape + (g,))
            return batch_leaf.at[..., slots].set(tiled)
        moved = jnp.moveaxis(batch_leaf, ax, 0)
        moved = moved.at[slots].set(jnp.moveaxis(grp, ax, 0))
        return jnp.moveaxis(moved, 0, ax)

    return jax.tree.map(ins, caches, group_caches, axes)


def insert_slot(caches, single_caches, slot, axes):
    """Batch-1 convenience wrapper over :func:`insert_slots`."""
    return insert_slots(caches, single_caches,
                        jnp.reshape(jnp.asarray(slot, jnp.int32), (1,)), axes)


def evict_slot(caches, slot, axes):
    """Invalidate slot ``slot``: zero its cache-position leaves so attention
    sees an empty prefix.  State leaves are left in place — the next
    ``insert_slot`` into this slot overwrites them wholesale, and per-slot
    masking/state flow keeps a stale slot from influencing any other."""
    def ev(leaf, ax):
        if ax != SLOT_AXIS_SHARED:
            return leaf
        zero = jnp.zeros(leaf.shape[:-1] + (1,), leaf.dtype)
        return jax.lax.dynamic_update_slice_in_dim(leaf, zero, slot,
                                                   axis=leaf.ndim - 1)

    return jax.tree.map(ev, caches, axes)


# ================================================== paged caches (serve/pages)
# Paged serving memory: instead of per-slot contiguous max_len segments, the
# KV leaves become flat page stores (L, P, page_size, H, D) indexed through
# per-slot page tables ((B, n_pages) int32 rows the engine owns host-side and
# passes into every decode/chunk step).  Which leaves page is discovered
# STRUCTURALLY, like the batch axes above: a leaf pages iff its shape depends
# on max_len (KV caches do; SSM conv/ssm states and cross-attention context
# do not — those families keep dense per-slot segments and the engine gates
# paging to attention families).

#: sentinel axis for leaves stored as (L, num_pages, page_size, ...) pages
PAGED_AXIS = -2


def paged_cache_axes(p: Params, cfg: ModelConfig, max_len: int,
                     page_size: int,
                     example_inputs: dict[str, jnp.ndarray]):
    """Per-leaf paging/batch markers for the decode-cache pytree:
    :data:`PAGED_AXIS` for max_len-dependent (pageable) leaves, otherwise the
    leaf's batch axis exactly as :func:`slot_cache_axes` reports it."""
    baxes = slot_cache_axes(p, cfg, max_len, example_inputs)
    a = _cache_shapes(p, cfg, max_len, 1, example_inputs)
    b = _cache_shapes(p, cfg, max_len + page_size, 1, example_inputs)

    def mark(sa, sb, bax):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        if not diff:
            return bax
        assert len(diff) == 1, f"ambiguous seq axis for {sa.shape}"
        # the paged layout assumes the canonical stacked-KV leaf
        # (layers, batch, seq, heads, head_dim)
        assert (bax, diff[0]) == (1, 2) and len(sa.shape) == 5, \
            f"unpageable cache leaf {sa.shape} (batch={bax}, seq={diff[0]})"
        return PAGED_AXIS

    return jax.tree.map(mark, a, b, baxes)


def alloc_paged_caches(p: Params, cfg: ModelConfig, capacity: int,
                       max_len: int, page_size: int, num_pages: int,
                       example_inputs: dict[str, jnp.ndarray]):
    """Zero-initialized paged decode caches.

    Pageable leaves become (L, num_pages, page_size, H, D) stores shared by
    every slot (page ids are layer-invariant: a slot's page holds that page's
    positions in every layer).  Non-pageable leaves allocate exactly like
    :func:`alloc_slot_caches` — shared leaves (per-layer ``len``) gain a
    trailing slot axis.  Returns ``(caches, axes)``.
    """
    if cfg.window is not None:
        raise ValueError("paged caches are incompatible with sliding-window "
                         "ring buffers (cfg.window)")
    axes = paged_cache_axes(p, cfg, max_len, page_size, example_inputs)
    # evaluated at max_len == page_size, a pageable leaf's shape IS one
    # page's shape with the batch axis in page-id position
    shapes = _cache_shapes(p, cfg, page_size, 1, example_inputs)

    def alloc(leaf, ax):
        if ax == PAGED_AXIS:
            shp = list(leaf.shape)
            shp[1] = num_pages
            return jnp.zeros(shp, leaf.dtype)
        if ax == SLOT_AXIS_SHARED:
            return jnp.zeros(leaf.shape + (capacity,), leaf.dtype)
        shp = list(leaf.shape)
        shp[ax] = capacity
        return jnp.zeros(shp, leaf.dtype)

    return jax.tree.map(alloc, shapes, axes), axes


def insert_pages(caches, group_caches, slots, pages, axes):
    """Splice a batch-G prefill cache (built at max_len rounded up to a page
    multiple, so its seq extent is ``n_pg * page_size``) into the page store:
    one scatter per pageable leaf at the groups' page ids ``pages``
    ((G, n_pg) int32), plus the usual per-slot scatter for everything else.
    """
    g = slots.shape[0]
    flat_pages = jnp.reshape(pages, (-1,))

    def ins(leaf, grp, ax):
        grp = jnp.asarray(grp).astype(leaf.dtype)
        if ax == PAGED_AXIS:
            l, _, r, h, hd = grp.shape            # (L, G, n_pg*ps, H, D)
            ps = leaf.shape[2]
            content = grp.reshape(l, g * (r // ps), ps, h, hd)
            return leaf.at[:, flat_pages].set(content)
        if ax == SLOT_AXIS_SHARED:
            tiled = jnp.broadcast_to(grp[..., None], grp.shape + (g,))
            return leaf.at[..., slots].set(tiled)
        moved = jnp.moveaxis(leaf, ax, 0)
        moved = moved.at[slots].set(jnp.moveaxis(grp, ax, 0))
        return jnp.moveaxis(moved, 0, ax)

    return jax.tree.map(ins, caches, group_caches, axes)


def set_slot_lens(caches, slot, value, axes):
    """Set slot ``slot``'s cache-position leaves to ``value`` (prefix-cache
    hits start a slot at the shared-prefix length without any KV traffic)."""
    def st(leaf, ax):
        if ax != SLOT_AXIS_SHARED:
            return leaf
        return leaf.at[..., slot].set(jnp.asarray(value, leaf.dtype))

    return jax.tree.map(st, caches, axes)


def slot_view(caches, slot, axes):
    """A batch-1 view of one slot: per-slot leaves sliced at ``slot`` (a
    traced scalar is fine), page stores passed through whole — chunked
    prefill runs a single slot without dragging the full batch through the
    compute."""
    def ex(leaf, ax):
        if ax == PAGED_AXIS:
            return leaf
        if ax == SLOT_AXIS_SHARED:
            return jax.lax.dynamic_slice_in_dim(leaf, slot, 1,
                                                axis=leaf.ndim - 1)
        return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)

    return jax.tree.map(ex, caches, axes)


def merge_slot(caches, view, slot, axes):
    """Write a :func:`slot_view` back: page stores replace wholesale (their
    writes already landed at absolute page ids), per-slot leaves scatter at
    ``slot``."""
    def mg(leaf, sub, ax):
        if ax == PAGED_AXIS:
            return sub
        if ax == SLOT_AXIS_SHARED:
            return jax.lax.dynamic_update_slice_in_dim(
                leaf, sub.astype(leaf.dtype), slot, axis=leaf.ndim - 1)
        return jax.lax.dynamic_update_slice_in_dim(
            leaf, sub.astype(leaf.dtype), slot, axis=ax)

    return jax.tree.map(mg, caches, view, axes)


def prefill_chunk(p: Params, caches, tokens: jnp.ndarray,
                  pt_row: jnp.ndarray, slot, n_valid, cfg: ModelConfig,
                  axes, embeds: jnp.ndarray | None = None):
    """One chunked-prefill step for one slot over the paged cache.

    ``tokens`` (1, chunk) is the next prompt chunk (zero-padded past
    ``n_valid`` on the final chunk — the fixed chunk shape is what bounds
    prefill recompilation to the number of chunk sizes, not prompt lengths);
    ``pt_row`` (1, n_pages) is the slot's page table.  Returns the logits at
    the last valid position ((1, vocab) — only meaningful on the final
    chunk) and the updated caches.
    """
    view = slot_view(caches, slot, axes)
    logits, view = decode_tokens(p, view, tokens, cfg, pt=pt_row,
                                 n_valid=n_valid, embeds=embeds)
    last = jax.lax.dynamic_slice_in_dim(logits, n_valid - 1, 1, axis=1)[:, 0]
    return last, merge_slot(caches, view, slot, axes)


def _decode_enc_dec(p, caches, tokens, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    x = p["dec_embed"].astype(dt)[tokens][:, None, :]

    def body(h, xs):
        lp, cache, kv = xs
        h, _, cache = blocks.decoder_block(
            lp, h, cfg, causal=True, pos_offset=cache["len"], cache=cache,
            cross_kv=kv)
        return h, cache
    x, self_caches = _scan(
        body, x, (p["dec_blocks"], caches["self"], caches["cross"]), cfg)
    x = nn.rmsnorm_apply(p["dec_ln"], x)
    logits = (x @ p["lm_head"].astype(x.dtype))[:, 0]
    return logits, {"self": self_caches, "cross": caches["cross"]}
