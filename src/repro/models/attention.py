"""GQA attention with RoPE, qk-norm, sliding window, cross-attn and KV cache.

Training / prefill use the differentiable jnp path (or the SIP-tuned Pallas
kernel when ``cfg.use_pallas`` and the path is forward-only); decode operates
on a preallocated right-padded KV cache with one-token updates.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.partition import shard
from repro.dist.tp import tp_allreduce
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.paged_attention import ops as pg_ops
from repro.models import modules as nn
from repro.models.config import ModelConfig

NEG_INF = -1e30


# ------------------------------------------------------------------ params
def phys_heads(cfg: ModelConfig) -> int:
    return max(cfg.padded_heads, cfg.n_heads) if cfg.padded_heads else cfg.n_heads


def kv_head_map(cfg: ModelConfig) -> jnp.ndarray | None:
    """Physical q-head -> kv-head index, preserving the ORIGINAL GQA grouping
    for the real heads; padded heads map to kv 0 (their wo rows are zero, so
    they contribute nothing).  None when no padding (reshape GQA is used)."""
    ph = phys_heads(cfg)
    if ph == cfg.n_heads:
        return None
    group = cfg.n_heads // cfg.n_kv_heads
    idx = [i // group for i in range(cfg.n_heads)] + [0] * (ph - cfg.n_heads)
    return jnp.asarray(idx, jnp.int32)


def _wo_eff(p, cfg: ModelConfig, dt) -> jnp.ndarray:
    """wo with padded-head rows hard-masked at USE.  The mask (not just the
    zero init) makes padded-head gradients exactly zero for both wq (via the
    zero output path) and wo (via the multiplicative mask), so padding stays
    inert under training — tests/test_perf_levers.py."""
    wo = p["wo"].astype(dt)
    ph = wo.shape[0]
    if ph != cfg.n_heads:
        mask = (jnp.arange(ph) < cfg.n_heads).astype(dt)
        wo = wo * mask[:, None, None]
    return wo


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    hd, h, hkv, d = cfg.hd, phys_heads(cfg), cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 6)
    wq = jax.random.normal(ks[0], (d, h, hd)) * d ** -0.5
    wo = jax.random.normal(ks[3], (h, hd, d)) * (cfg.n_heads * hd) ** -0.5
    if h != cfg.n_heads:                       # zero the padded head slices
        wq = wq.at[:, cfg.n_heads:, :].set(0.0)
        wo = wo.at[cfg.n_heads:, :, :].set(0.0)
    p = {
        "wq": nn.Param(wq, ("embed", "heads", "head_dim")),
        "wk": nn.param(ks[1], (d, hkv, hd), ("embed", "kv_heads", "head_dim"),
                       scale=d ** -0.5),
        "wv": nn.param(ks[2], (d, hkv, hd), ("embed", "kv_heads", "head_dim"),
                       scale=d ** -0.5),
        "wo": nn.Param(wo, ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = nn.param(ks[4], (hd,), ("head_dim",), init="ones")
        p["k_norm"] = nn.param(ks[5], (hd,), ("head_dim",), init="ones")
    del cross
    return p


# ------------------------------------------------------------------- rope
def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D) with D even; pos: (S,) absolute positions shared by the
    batch, or (B, S) per-sequence positions (continuous-batching decode, where
    every slot sits at its own offset)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None] * freqs   # (S, half) | (B, S, half)
    if pos.ndim == 2:
        cos = jnp.cos(ang)[:, :, None, :]
        sin = jnp.sin(ang)[:, :, None, :]
    else:
        cos = jnp.cos(ang)[None, :, None, :]
        sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


def _qkv(p, x: jnp.ndarray, cfg: ModelConfig, pos: jnp.ndarray,
         rotary: bool = True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = nn.rmsnorm_apply(p["q_norm"], q)
        k = nn.rmsnorm_apply(p["k_norm"], k)
    if rotary:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, *, causal: bool, window: int | None,
          kv_len: jnp.ndarray | None = None,
          kv_idx: jnp.ndarray | None = None) -> jnp.ndarray:
    """q: (B,Sq,H,D); k,v: (B,Skv,Hkv,D) -> (B,Sq,H,D).  jnp path (GQA).

    ``kv_len``: optional scalar — only cache positions < kv_len are valid
    (decode with a preallocated cache) — or a (B,) vector for per-slot decode
    (continuous batching: every slot has its own valid prefix).  ``kv_idx``:
    explicit q-head -> kv head map (padded-heads mode); kv is gathered to full
    head count so the heads dim shards over 'model'."""
    if kv_idx is not None:
        k = shard(k[:, :, kv_idx, :], "batch", "seq", "heads", "head_dim")
        v = shard(v[:, :, kv_idx, :], "batch", "seq", "heads", "head_dim")
    b, sq, h, dh = q.shape
    _, skv, hkv, _ = k.shape
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    if kv_len is not None and jnp.ndim(kv_len) > 0:
        # per-slot: mask is (B, sq, skv).  A fully-masked row (an empty slot,
        # kv_len == 0) stays finite — NEG_INF is a large negative, not -inf,
        # so softmax degrades to uniform and the garbage output is confined
        # to that slot (the engine discards it).
        rows = jnp.arange(sq)[None, :, None] + (kv_len[:, None, None] - sq)
        cols = jnp.arange(skv)[None, None, :]
        mask = jnp.broadcast_to(cols < kv_len[:, None, None], (b, sq, skv))
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    else:
        rows = jnp.arange(sq)[:, None] + (skv - sq)
        if kv_len is not None:
            rows = jnp.arange(sq)[:, None] + (kv_len - sq)
        cols = jnp.arange(skv)[None, :]
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        if kv_len is not None:
            mask &= cols < kv_len
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dh).astype(q.dtype)


def attention(p, x: jnp.ndarray, cfg: ModelConfig, *,
              causal: bool = True,
              pos_offset: int | jnp.ndarray = 0,
              cache: dict[str, Any] | None = None,
              return_cache: bool = False):
    """Self-attention.  Modes:
      train/prefill: cache=None (optionally return_cache -> fresh cache)
      decode: cache={'k','v','len'} preallocated; x is (B, 1, d)

    ``pos_offset`` / ``cache['len']`` may be (B,) vectors — per-slot decode
    for the continuous-batching engine, where each batch slot holds a request
    at its own sequence position.
    """
    b, s, d = x.shape
    if jnp.ndim(pos_offset) > 0:
        pos = jnp.arange(s)[None, :] + pos_offset[:, None]   # (B, S) per slot
    else:
        pos = jnp.arange(s) + pos_offset
    q, k, v = _qkv(p, x, cfg, pos)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    kv_idx = kv_head_map(cfg)

    new_cache = None
    if cache is not None and "pt" in cache:     # paged decode / chunk prefill
        o, new_cache = _paged_decode(cache, q, k, v, cfg, causal=causal,
                                     kv_idx=kv_idx)
    elif cache is not None:                     # decode: append to cache
        idx = cache["len"]
        size = cache["k"].shape[1]
        # SWA ring buffer: slot(p) = p % size once the cache is window-sized
        rolling = cfg.window is not None and size <= cfg.window
        w_idx = idx % size if rolling else idx
        if jnp.ndim(idx) > 0:
            # per-slot decode (s == 1): scatter each slot's token at its own
            # cache position
            rows_b = jnp.arange(b)
            ck = cache["k"].at[rows_b, w_idx].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows_b, w_idx].set(
                v[:, 0].astype(cache["v"].dtype))
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, w_idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, w_idx, 0, 0))
        new_cache = {"k": ck, "v": cv, "len": idx + s}
        # rolling: slot indices are not positions — causal/window masks do not
        # apply; every slot < min(len, size) is in-window by construction.
        o = _sdpa(q, ck, cv,
                  causal=causal and not rolling,
                  window=None if rolling else cfg.window,
                  kv_len=idx + s, kv_idx=kv_idx)
    else:
        if cfg.use_pallas and kv_idx is None:
            o = _pallas_sdpa(q, k, v, causal=causal, window=cfg.window)
        else:
            o = _sdpa(q, k, v, causal=causal, window=cfg.window,
                      kv_idx=kv_idx)
        if return_cache:
            new_cache = {"k": k, "v": v, "len": jnp.int32(s)}
    o = shard(o, "batch", "seq", "heads", "head_dim")
    # manual-TP seam: heads are the sharded contraction dim, so the wo
    # product is a partial sum per shard — reduced here (identity outside a
    # tp_context, so single-device and GSPMD paths are untouched)
    out = tp_allreduce(jnp.einsum("bshk,hkd->bsd", o, _wo_eff(p, cfg, x.dtype)))
    out = shard(out, "batch", "seq", "embed_act")
    if return_cache or cache is not None:
        return out, new_cache
    return out


def _paged_decode(cache: dict[str, Any], q, k, v, cfg: ModelConfig, *,
                  causal: bool, kv_idx):
    """Page-table-indirect cache write + read (continuous batching over a
    paged KV store).

    ``cache`` holds the flat page stores ``k``/``v`` (P, ps, Hkv, D), the
    per-slot lengths ``len`` (B,), and routing keys the model layer injects
    per step: ``pt`` (B, n_pages) int32 page tables, optional ``active``
    (B,) bool (rows mid-chunked-prefill or idle write to the trash page and
    do not advance), optional ``n_valid`` scalar (chunked prefill: how many
    of the s positions are real tokens — padding still writes, but beyond
    ``len + n_valid`` positions are never read because the engine reserves
    worst-case pages per slot and reads are bounded by ``len``).

    Writes scatter each token at (pt[b, pos // ps], pos % ps); reads gather
    the slot's pages back into a contiguous (B, n*ps, Hkv, D) view (the
    SIP-tuned ``paged_gather`` kernel under ``cfg.use_pallas``) and reuse
    the per-slot masked SDPA unchanged.  Sliding-window archs keep the
    dense ring buffer (the engine gates paging to window=None families).
    """
    store_k, store_v, idx = cache["k"], cache["v"], cache["len"]
    pt = cache["pt"]
    active = cache.get("active")
    n_valid = cache.get("n_valid")
    b, s = q.shape[0], q.shape[1]
    ps = store_k.shape[1]
    n_pages = pt.shape[1]

    pos = idx[:, None] + jnp.arange(s)[None, :]            # (B, S) absolute
    # positions past the table's end go to the trash page: chunked-prefill
    # padding can overrun a full table (pos // ps == n_pages) and clamping
    # would scatter duplicate offsets onto the LAST real page, overwriting
    # live KV — the clamp below only keeps the gather index legal
    page_slot = pos // ps
    page_ids = jnp.take_along_axis(
        pt, jnp.minimum(page_slot, n_pages - 1), axis=1)   # (B, S)
    page_ids = jnp.where(page_slot < n_pages, page_ids, 0)
    if active is not None:
        page_ids = jnp.where(active[:, None], page_ids, 0)  # trash page
    offs = pos % ps
    ck = store_k.at[page_ids, offs].set(k.astype(store_k.dtype))
    cv = store_v.at[page_ids, offs].set(v.astype(store_v.dtype))

    gk = _gather_pages(ck, pt, cfg)                        # (B, n*ps, Hkv, D)
    gv = _gather_pages(cv, pt, cfg)
    o = _sdpa(q, gk, gv, causal=causal, window=None,
              kv_len=idx + s, kv_idx=kv_idx)

    adv = s if n_valid is None else n_valid
    if active is not None:
        adv = jnp.where(active, adv, 0)
    return o, {"k": ck, "v": cv, "len": idx + adv}


def _gather_pages(store, pt, cfg: ModelConfig):
    """(P, ps, H, D) store + (B, n) page table -> contiguous (B, n*ps, H, D)
    per-slot KV view; the SIP-registered kernel when ``cfg.use_pallas``."""
    if cfg.use_pallas:
        pages = pg_ops.paged_gather(store, pt)
    else:
        pages = jnp.take(store, pt, axis=0)
    b, n, ps, h, d = pages.shape
    return pages.reshape(b, n * ps, h, d)


def cross_attention(p, x: jnp.ndarray, ctx_kv: tuple[jnp.ndarray, jnp.ndarray],
                    cfg: ModelConfig) -> jnp.ndarray:
    """Decoder cross-attn over precomputed encoder K/V (no rotary, no mask)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cfg.qk_norm:
        q = nn.rmsnorm_apply(p["q_norm"], q)
    k, v = ctx_kv
    o = _sdpa(q, k, v, causal=False, window=None, kv_idx=kv_head_map(cfg))
    return tp_allreduce(jnp.einsum("bshk,hkd->bsd", o, _wo_eff(p, cfg, dt)))


def encode_kv(p, ctx: jnp.ndarray, cfg: ModelConfig):
    """Project encoder output once into cross-attention K/V."""
    dt = ctx.dtype
    k = jnp.einsum("bsd,dhk->bshk", ctx, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", ctx, p["wv"].astype(dt))
    if cfg.qk_norm:
        k = nn.rmsnorm_apply(p["k_norm"], k)
    return k, v


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> dict[str, Any]:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.int32(0)}


def _pallas_sdpa(q, k, v, *, causal, window):
    """SIP-tuned Pallas kernel path (forward-only).  Layout: kernels expect
    (B, H, S, D).  The kernel is the ONE registry-cached instance for this
    variant (bound to the active schedule_cache), so repeated calls reuse
    its schedule/build caches instead of recompiling from scratch."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    kern = fa_ops.kernel(causal=causal, window=window)
    o = kern(qt, kt, vt)
    return jnp.swapaxes(o, 1, 2)
