"""Model configuration — one dataclass covers all 10 assigned families."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | enc_dec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"       # swiglu | gelu | geglu
    qk_norm: bool = False
    window: int | None = None      # sliding-window attention (tokens)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    moe_groups: int = 0            # >0: group-local dispatch — tokens are
                                   # routed/sorted/scattered WITHIN each of
                                   # moe_groups batch groups (sharded over
                                   # pod×data) so dispatch needs no global
                                   # collective and the expert einsum is
                                   # already EP-aligned (§Perf lever)
    # --- SSM (Mamba-2) -------------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_width: int = 4
    # --- hybrid (Zamba-2): groups of mamba layers + one shared attn block ----
    hybrid_group: int = 0          # mamba layers per scan group
    hybrid_attn_every: int = 0     # apply shared attn block every N groups
    # --- encoder-decoder ------------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0
    enc_len: int = 0               # encoder context length (frontend frames)
    # --- inputs ----------------------------------------------------------------
    input_mode: str = "tokens"     # tokens | embeddings  (vlm/audio stubs)
    # --- execution ---------------------------------------------------------------
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "full"     # full | dots | none  (§Perf lever)
    padded_heads: int = 0          # pad q-heads to this count with zeroed
                                   # wq/wo so the heads dim shards over the
                                   # 16-way model axis (§Perf lever; exact:
                                   # zero wo rows contribute nothing)
    force_microbatches: int = 0    # override grad-accum count (§Perf lever)
    seq_shard: bool = False        # sequence parallelism: shard the residual
                                   # stream's seq dim over 'model' (§Perf
                                   # lever for long-seq prefill; GSPMD
                                   # gathers K/V inside attention)
    scan_layers: bool = True
    use_pallas: bool = False       # SIP-tuned Pallas kernels on fwd-only paths
    logits_microbatch: int = 0     # chunk the loss over seq (0 = off)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence handling (SSM / hybrid / SWA)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def validate(self) -> "ModelConfig":
        if self.family in ("dense", "moe", "vlm", "enc_dec"):
            assert self.n_heads > 0 and self.n_heads % self.n_kv_heads == 0
        if self.family == "moe":
            assert self.n_experts > 0 and 0 < self.top_k <= self.n_experts
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0 and self.d_inner % self.ssm_headdim == 0
        if self.family == "hybrid":
            # trailing (n_layers % hybrid_group) mamba layers run after the
            # scanned groups — see models/model.py
            assert self.hybrid_group > 0 and self.hybrid_attn_every > 0
            assert self.n_layers >= self.hybrid_group
        if self.family == "enc_dec":
            assert self.enc_layers > 0 and self.dec_layers > 0
        return self


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else cfg.hybrid_group * 2),
        d_model=128,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=32 if cfg.n_heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        ssm_state=min(cfg.ssm_state, 16),
        ssm_headdim=32 if cfg.ssm_state else 64,
        ssm_chunk=16,
        enc_layers=min(cfg.enc_layers, 2),
        dec_layers=min(cfg.dec_layers, 2),
        enc_len=min(cfg.enc_len, 64) if cfg.enc_len else 0,
        window=min(cfg.window, 32) if cfg.window else None,
        hybrid_group=cfg.hybrid_group and min(cfg.hybrid_group, 2),
        hybrid_attn_every=cfg.hybrid_attn_every and min(cfg.hybrid_attn_every, 2),
        dtype="float32",
        param_dtype="float32",
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small).validate()
