"""Mamba-2 mixer block (SSD core), with O(1)-state decode.

Follows the Mamba-2 reference structure: a fused input projection producing
(z, x, B, C, dt), a short causal depthwise conv over (x, B, C), the chunked
SSD scan (kernels/ssd), a gated RMSNorm, and the output projection.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.partition import shard
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import pallas_ops as ssd_pallas
from repro.models import modules as nn
from repro.models.config import ModelConfig


def init_mamba(key, cfg: ModelConfig):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    proj_out = 2 * di + 2 * n + h          # z, x, B, C, dt
    ks = jax.random.split(key, 8)
    return {
        "in_proj": nn.param(ks[0], (d, proj_out), ("embed", "ssm_inner"),
                            scale=d ** -0.5),
        "conv_w": nn.param(ks[1], (cfg.conv_width, conv_ch),
                           (None, "conv_ch"), scale=cfg.conv_width ** -0.5),
        "conv_b": nn.param(ks[2], (conv_ch,), ("conv_ch",), init="zeros"),
        "A_log": nn.param(ks[3], (h,), ("ssm_heads",), init="zeros"),
        "D": nn.param(ks[4], (h,), ("ssm_heads",), init="ones"),
        "dt_bias": nn.param(ks[5], (h,), ("ssm_heads",), init="zeros"),
        "norm": nn.param(ks[6], (di,), ("ssm_inner",), init="ones"),
        "out_proj": nn.param(ks[7], (di, d), ("ssm_inner", "embed"),
                             scale=di ** -0.5),
    }


def _split(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _causal_conv(w: jnp.ndarray, b: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d.  x: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return jax.nn.silu(out + b[None, None, :])


def mamba(p, x: jnp.ndarray, cfg: ModelConfig, *,
          state: dict[str, Any] | None = None,
          return_state: bool = False):
    """x: (B, S, d).  ``state`` = {'conv': (B, W-1, C), 'ssd': (B,H,N,P)}
    enables continuation (decode uses S=1 via :func:`mamba_step`)."""
    bt, s, _ = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    dt_ = x.dtype

    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xbc, dtp = _split(cfg, zxbcdt)
    xbc = shard(xbc, "batch", "seq", "conv_ch")

    if state is not None:
        xbc_in = jnp.concatenate([state["conv"].astype(dt_), xbc], axis=1)
        conv_out = _causal_conv(p["conv_w"].astype(dt_), p["conv_b"].astype(dt_),
                                xbc_in)[:, cfg.conv_width - 1:]
    else:
        conv_out = _causal_conv(p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), xbc)
    xs = conv_out[..., :di].reshape(bt, s, h, pdim)
    B = conv_out[..., di:di + n]
    C = conv_out[..., di + n:]

    dt_act = jax.nn.softplus(dtp.astype(jnp.float32) +
                             p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    init_ssd = state["ssd"] if state is not None else None
    chunk = cfg.ssm_chunk if s % cfg.ssm_chunk == 0 else _best_chunk(s)
    # prefill: the SIP-tuned Pallas intra-chunk kernel (resolved via the
    # registry, honoring an active schedule_cache).  Forward-only, like the
    # attention pallas path — pallas_call is not differentiable, so training
    # must keep cfg.use_pallas False (only serve.py sets it).  Decode
    # continuation stays on jnp (S=1 steps don't amortize a kernel launch).
    ssd_fn = (ssd_pallas.ssd_chunked_pallas
              if cfg.use_pallas and state is None else ssd_ops.ssd_chunked)
    y, ssd_state = ssd_fn(xs, dt_act, A, B, C, p["D"],
                          chunk=chunk, init_state=init_ssd,
                          return_state=True)
    y = y.reshape(bt, s, di).astype(dt_)
    y = nn.rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(dt_)
    out = shard(out, "batch", "seq", None)
    if return_state or state is not None:
        hist = xbc if state is None else xbc_in
        deficit = (cfg.conv_width - 1) - hist.shape[1]
        if deficit > 0:
            hist = jnp.pad(hist, ((0, 0), (deficit, 0), (0, 0)))
        new_state = {"conv": hist[:, -(cfg.conv_width - 1):], "ssd": ssd_state}
        return out, new_state
    return out


def mamba_step(p, x_t: jnp.ndarray, cfg: ModelConfig,
               state: dict[str, Any]):
    """One decode token.  x_t: (B, d)."""
    out, new_state = mamba(p, x_t[:, None, :], cfg, state=state)
    return out[:, 0], new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        "ssd": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                          cfg.ssm_headdim), jnp.float32),
    }


def _best_chunk(s: int) -> int:
    for c in (64, 32, 16, 8, 4, 2, 1):
        if s % c == 0:
            return c
    return 1
