"""Minimal functional module system with logical-axis sharding metadata.

Parameters are nested dicts of :class:`Param` leaves; each Param carries the
*logical* axis names of its dimensions (MaxText-style).  Logical names are
resolved to mesh axes by the rules in :mod:`repro.dist.partition`, so the
same model code runs unsharded on one CPU device and fully sharded on the
(pod, data, model) production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    value: Any
    axes: tuple[str | None, ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def is_param(x: Any) -> bool:
    return isinstance(x, Param)


def param(key, shape, axes, *, scale: float | None = None,
          init: str = "normal", dtype=jnp.float32) -> Param:
    assert len(axes) == len(shape), (axes, shape)
    if init == "normal":
        s = scale if scale is not None else (shape[0] ** -0.5 if shape else 1.0)
        v = jax.random.normal(key, shape, dtype) * jnp.asarray(s, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    elif init == "zeros":
        v = jnp.zeros(shape, dtype)
    else:
        raise ValueError(init)
    return Param(v, tuple(axes))


def unwrap(tree) -> Any:
    """Param tree -> raw value tree (what train/serve code consumes)."""
    return jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)


def axes_of(tree) -> Any:
    """Param tree -> logical-axes tree (same structure, tuples at leaves)."""
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)


def stack_layers(init_fn: Callable[[jax.Array], Any], key: jax.Array,
                 n_layers: int) -> Any:
    """vmap ``init_fn`` over per-layer keys and prepend the 'layers' logical
    axis to every Param (the lax.scan stacking dimension)."""
    keys = jax.random.split(key, n_layers)
    stacked = jax.vmap(init_fn)(keys)
    return jax.tree.map(lambda p: Param(p.value, ("layers",) + p.axes),
                        stacked, is_leaf=is_param)


# ---------------------------------------------------------------- primitives
def dense_init(key, in_dim: int, out_dim: int, *, in_axis: str | None,
               out_axis: str | None, scale: float | None = None) -> Param:
    return param(key, (in_dim, out_dim), (in_axis, out_axis),
                 scale=scale if scale is not None else in_dim ** -0.5)


def dense(p: jnp.ndarray, x: jnp.ndarray, dtype=None) -> jnp.ndarray:
    w = p.astype(dtype) if dtype is not None else p
    return x @ w


def rmsnorm_init(key, dim: int, axis: str | None = "embed") -> Param:
    del key
    return param(jax.random.PRNGKey(0), (dim,), (axis,), init="ones")


def rmsnorm_apply(gamma: jnp.ndarray, x: jnp.ndarray,
                  eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)
    return y.astype(x.dtype)
