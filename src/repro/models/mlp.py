"""Dense MLP variants: SwiGLU / GeGLU / GELU."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.partition import shard
from repro.dist.tp import tp_allreduce
from repro.models import modules as nn
from repro.models.config import ModelConfig


def init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_down": nn.param(ks[2], (f, d), ("mlp", "embed"), scale=f ** -0.5)}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = nn.param(ks[0], (d, f), ("embed", "mlp"), scale=d ** -0.5)
        p["w_up"] = nn.param(ks[1], (d, f), ("embed", "mlp"), scale=d ** -0.5)
    elif cfg.mlp_type == "gelu":
        p["w_up"] = nn.param(ks[1], (d, f), ("embed", "mlp"), scale=d ** -0.5)
    else:
        raise ValueError(cfg.mlp_type)
    return p


def mlp(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = x.dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(dt), approximate=True)
    h = shard(h, "batch", "seq", "mlp")
    # manual-TP seam: the hidden (mlp) dim shards, so the down projection
    # is a partial sum per shard (identity outside a tp_context)
    return tp_allreduce(h @ p["w_down"].astype(dt))
