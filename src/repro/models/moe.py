"""Top-k MoE with sort-based capacity dispatch (expert-parallel over 'model').

Dispatch is the production-standard permute/bucket scheme (MaxText/MegaBlocks
lineage, without a custom grouped-GEMM kernel):

  1. router logits -> top-k experts per token (softmax-renormalized gates);
  2. token copies sorted by expert id; position-within-expert computed from
     the sorted segment starts; copies beyond expert capacity are dropped;
  3. scatter into a dense (E, C, d) buffer; per-expert FFN as one batched
     einsum with experts sharded over the 'model' axis (EP);
  4. gather back, unsort, gate-weight, sum the k copies.

Two dispatch scopes:

  * global (``cfg.moe_groups == 0``): one argsort/scatter over all tokens.
    Simple, but under GSPMD the scatter into the expert buffer partial-sums
    across data shards — it all-reduces the whole (E, C, d) buffer every
    layer (measured in EXPERIMENTS.md §Perf: the dominant collective for
    dbrx/llama4).
  * group-local (``cfg.moe_groups = G``): tokens are grouped along the batch
    dim (groups sharded over pod x data) and routed within their group, so
    sort/scatter are shard-local and the expert einsum
    ``gecd,edf->gecf`` is already aligned on (G->data, E->model) — no
    dispatch collective at all.  This is the EP-friendly layout GShard-style
    systems use.

Aux losses: switch-style load balancing + router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.partition import shard
from repro.dist.tp import tp_allreduce
from repro.models import modules as nn
from repro.models.config import ModelConfig


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": nn.param(ks[0], (d, e), ("embed", "experts"), scale=d ** -0.5),
        "w_gate": nn.param(ks[1], (e, d, f), ("experts", "embed", "mlp"),
                           scale=d ** -0.5),
        "w_up": nn.param(ks[2], (e, d, f), ("experts", "embed", "mlp"),
                         scale=d ** -0.5),
        "w_down": nn.param(ks[3], (e, f, d), ("experts", "mlp", "embed"),
                           scale=f ** -0.5),
    }


def _route(p, xt: jnp.ndarray, cfg: ModelConfig):
    """xt: (T, d) -> (gates (T,k), expert_idx (T,k), aux)."""
    e, k = cfg.n_experts, cfg.top_k
    logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = {
        "load_balance": cfg.router_aux_weight * e * jnp.sum(density * mean_probs),
        "router_z": cfg.router_z_weight *
                    jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return gate_vals, expert_idx, aux


def _dispatch_ffn(p, xt: jnp.ndarray, gate_vals, expert_idx,
                  cfg: ModelConfig, cap: int) -> jnp.ndarray:
    """Sort-based capacity dispatch + per-expert FFN over (T, d) tokens."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    flat_expert = expert_idx.reshape(-1)                        # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), k)

    sort_idx = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[sort_idx]
    sorted_token = flat_token[sort_idx]
    seg_start = jnp.searchsorted(sorted_expert, jnp.arange(e))
    pos_in_exp = jnp.arange(t * k) - seg_start[sorted_expert]
    keep = pos_in_exp < cap
    dest = jnp.where(keep, sorted_expert * cap + pos_in_exp, e * cap)

    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[dest].set(xt[sorted_token])
    buf = buf[:-1].reshape(e, cap, d)

    dt = xt.dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt))) * \
            jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt)),
                        approximate=True)
    # manual-TP seam: under serving TP the experts replicate and the FFN
    # hidden dim f shards, so the down projection is a partial sum per
    # shard (identity outside a tp_context; GSPMD EP is unaffected)
    out_buf = tp_allreduce(jnp.einsum("ecf,efd->ecd", h,
                                      p["w_down"].astype(dt)))

    gathered = out_buf.reshape(e * cap, d)[jnp.minimum(dest, e * cap - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    contrib = gathered * flat_gate[sort_idx][:, None].astype(dt)
    return jnp.zeros((t, d), dt).at[sorted_token].add(contrib)


def moe(p, x: jnp.ndarray, cfg: ModelConfig, *, dropless: bool = False):
    """x: (B, S, d) -> (y, aux_losses dict).

    ``dropless=True`` (decode path) sizes every expert for the worst case
    (capacity = n_tokens per dispatch scope) so no token is ever dropped."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = cfg.moe_groups
    if g and t % g == 0 and t // g >= 1 and not dropless:
        tg = t // g
        xg = x.reshape(g, tg, d)
        xg = shard(xg, "batch", None, None)       # groups over pod x data
        gate_vals, expert_idx, aux = jax.vmap(
            lambda xt: _route(p, xt, cfg))(xg)
        aux = {kk: jnp.mean(v) for kk, v in aux.items()}
        cap = min(max(int(tg * k / e * cfg.capacity_factor), 1), tg)
        y = jax.vmap(lambda xt, gv, ei:
                     _dispatch_ffn(p, xt, gv, ei, cfg, cap))(
            xg, gate_vals, expert_idx)
        y = shard(y, "batch", None, None)
        return y.reshape(b, s, d), aux

    xt = x.reshape(t, d)
    gate_vals, expert_idx, aux = _route(p, xt, cfg)
    # top-k experts are distinct, so capacity t is always dropless
    cap = t if dropless else min(max(int(t * k / e * cfg.capacity_factor), 1), t)
    y = _dispatch_ffn(p, xt, gate_vals, expert_idx, cfg, cap)
    return y.reshape(b, s, d), aux
