"""Block compositions: dense/MoE decoder blocks, Mamba blocks, Zamba-style
hybrid groups, and encoder blocks.  All block applies are scan-compatible
(uniform aux structure) and support train / prefill / decode modes."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import modules as nn
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig

ZERO_AUX = lambda: {"load_balance": jnp.float32(0.0), "router_z": jnp.float32(0.0)}


# ------------------------------------------------------------ decoder block
def init_decoder_block(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 6)
    p = {
        "ln1": nn.rmsnorm_init(ks[0], cfg.d_model),
        "attn": attn_mod.init_attention(ks[1], cfg),
        "ln2": nn.rmsnorm_init(ks[2], cfg.d_model),
    }
    if cfg.family == "moe":
        p["ffn"] = moe_mod.init_moe(ks[3], cfg)
    else:
        p["ffn"] = mlp_mod.init_mlp(ks[3], cfg)
    if cross:
        p["ln_x"] = nn.rmsnorm_init(ks[4], cfg.d_model)
        p["xattn"] = attn_mod.init_attention(ks[5], cfg, cross=True)
    return p


def _ffn(p, x, cfg: ModelConfig, dropless: bool = False):
    if cfg.family == "moe":
        return moe_mod.moe(p["ffn"], x, cfg, dropless=dropless)
    return mlp_mod.mlp(p["ffn"], x, cfg), ZERO_AUX()


def decoder_block(p, x, cfg: ModelConfig, *, causal: bool = True,
                  pos_offset: int | jnp.ndarray = 0,
                  cache: dict[str, Any] | None = None,
                  return_cache: bool = False,
                  cross_kv: tuple | None = None):
    h = nn.rmsnorm_apply(p["ln1"], x)
    if cache is not None or return_cache:
        a, new_cache = attn_mod.attention(p["attn"], h, cfg, causal=causal,
                                          pos_offset=pos_offset, cache=cache,
                                          return_cache=return_cache)
    else:
        a = attn_mod.attention(p["attn"], h, cfg, causal=causal,
                               pos_offset=pos_offset)
        new_cache = None
    x = x + a
    if cross_kv is not None:
        hx = nn.rmsnorm_apply(p["ln_x"], x)
        x = x + attn_mod.cross_attention(p["xattn"], hx, cross_kv, cfg)
    h2 = nn.rmsnorm_apply(p["ln2"], x)
    y, aux = _ffn(p, h2, cfg, dropless=cache is not None)
    return x + y, aux, new_cache


# ------------------------------------------------------------- mamba block
def init_mamba_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {"ln": nn.rmsnorm_init(ks[0], cfg.d_model),
            "mixer": ssm_mod.init_mamba(ks[1], cfg)}


def mamba_block(p, x, cfg: ModelConfig, *, state=None, return_state=False):
    h = nn.rmsnorm_apply(p["ln"], x)
    if state is not None or return_state:
        y, new_state = ssm_mod.mamba(p["mixer"], h, cfg, state=state,
                                     return_state=True)
        return x + y, new_state
    return x + ssm_mod.mamba(p["mixer"], h, cfg), None


# ------------------------------------------------------- hybrid (Zamba-2)
def init_hybrid_group(key, cfg: ModelConfig):
    """One scan group: ``hybrid_group`` mamba blocks.  The shared attention
    block's params live OUTSIDE the scan (one copy reused by all groups)."""
    return nn.stack_layers(lambda k: init_mamba_block(k, cfg), key,
                           cfg.hybrid_group)


def hybrid_group(gp, shared, x, cfg: ModelConfig, apply_attn: jnp.ndarray, *,
                 states=None, attn_cache=None, return_state=False,
                 pos_offset=0):
    """gp: stacked mamba-block params (g, ...); shared: shared attn block
    params; apply_attn: traced bool — whether this group runs the shared
    attention block (Zamba-2 applies it periodically)."""
    new_states = []
    for i in range(cfg.hybrid_group):
        pi = jax.tree.map(lambda a: a[i], gp)
        st = None if states is None else jax.tree.map(lambda a: a[i], states)
        x, ns = mamba_block(pi, x, cfg, state=st,
                            return_state=return_state or states is not None)
        if ns is not None:
            new_states.append(ns)

    want_cache = attn_cache is not None or return_state

    def with_attn(args):
        x, cache = args
        out, _, new_cache = decoder_block(shared, x, cfg, causal=True,
                                          pos_offset=pos_offset, cache=cache,
                                          return_cache=return_state)
        if new_cache is None:
            new_cache = cache
        return out, new_cache

    def without(args):
        x, cache = args
        return x, cache

    if want_cache:
        if attn_cache is None:   # prefill: must materialize cache either way
            x2, new_cache = with_attn((x, None))
            x = jnp.where(apply_attn, x2, x)
        else:
            x, new_cache = jax.lax.cond(apply_attn, with_attn, without,
                                        (x, attn_cache))
    else:
        x = jax.lax.cond(apply_attn,
                         lambda v: decoder_block(shared, v, cfg, causal=True,
                                                 pos_offset=pos_offset)[0],
                         lambda v: v, x)
        new_cache = None
    stacked_states = (jax.tree.map(lambda *a: jnp.stack(a), *new_states)
                      if new_states else None)
    return x, stacked_states, new_cache
