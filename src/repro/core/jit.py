"""``@sip_jit`` — one-line integration (paper §4.1, Listing 2).

The paper decorates a Triton kernel; the cubin is intercepted, searched
offline, and the best test-passing cubin is loaded at deployment with zero
runtime overhead.  Here the decorated object is a *schedule-parameterized
kernel factory* (each kernel's ``ops.py``), and the cached artifact is a
:class:`~repro.core.schedule.Schedule` instead of a patched binary — the
factory deterministically rebuilds the optimized kernel from it.

    gemm = sip_jit(name="gemm_fused", build=build, program_for=make_program,
                   space_for=space, oracle=ref, signature_fn=sig)(...)
    gemm.tune(example_args, TuneConfig(...))   # offline
    y = gemm(x, w)                             # deployment: cached schedule
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, MutableSet, Sequence

import numpy as np

from repro.core import annealing, energy as energy_mod, population, testing
from repro.core.cache import LRUCache, ScheduleCache
from repro.core.ir import Program
from repro.core.mutation import MutationPolicy
from repro.core.schedule import Schedule, SearchSpace
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class TuneConfig:
    rounds: int = 2               # §4.1: multiple offline rounds, greedy rank
    t_max: float = 1.0
    t_min: float = 0.02
    cooling: float = 1.05         # L in Alg. 1
    seed: int = 0
    energy: str = "costmodel"     # "costmodel" (TPU-analytic) | "wallclock"
    knob_prob: float = 0.0        # 0 == paper-faithful (order-only mutations)
    step_samples: int = 2         # probabilistic tests per search step (§4.2)
    final_samples: int = 64       # tests on the final best before caching
    rtol: float = 2e-2
    atol: float = 2e-2
    guided: bool = False          # beyond-paper cost-model-guided proposals
    greed: float = 0.5            # P(greedy action) when guided
    # --- population / throughput knobs (beyond-paper, core.population) ----
    chains: int = 1               # 1 == paper-faithful sequential chain
    exchange_every: int = 16      # lockstep rounds between best-state exchanges
    ladder: float = 1.5           # T_max ratio between temperature rungs
    memoize: bool = True          # share a CachedEnergy across chains+rounds
    build_cache: int = 32         # bounded LRU of built kernels per tune()
    # --- fault tolerance (crash-safe search) ------------------------------
    eval_deadline_s: float | None = None  # wall-clock cap per candidate
    #                                       evaluation; a wedged/crashing
    #                                       schedule is quarantined, not fatal

    def validate(self) -> "TuneConfig":
        """Reject configurations the search would only fail on much later
        (or, worse, silently misbehave on).  Called by ``SipKernel.tune``
        and ``TuningSession`` before any work starts."""
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.step_samples < 0:
            raise ValueError(f"step_samples must be >= 0, got "
                             f"{self.step_samples}")
        if self.chains < 1:
            raise ValueError(f"chains must be >= 1, got {self.chains}")
        if self.t_min >= self.t_max:
            raise ValueError(f"need t_min < t_max, got t_min={self.t_min} "
                             f">= t_max={self.t_max}")
        if self.ladder <= 0:
            raise ValueError(f"ladder must be > 0, got {self.ladder}")
        if self.energy not in ("costmodel", "wallclock"):
            raise ValueError(f"unknown energy {self.energy!r} "
                             f"(expected 'costmodel' or 'wallclock')")
        if self.eval_deadline_s is not None and self.eval_deadline_s <= 0:
            raise ValueError(f"eval_deadline_s must be > 0, got "
                             f"{self.eval_deadline_s}")
        return self


def check_space_compat(schedule: Schedule, space: SearchSpace, *,
                       kernel: str = "?") -> Schedule:
    """Raise unless ``schedule``'s knobs are a legal point of ``space``.

    The guard behind warm starting: a schedule recalled from history was
    tuned for SOME signature's space; seeding a different kernel/signature
    with it must fail loudly rather than search from an unrepresentable
    state (tests/test_autotune.py holds ``TuneHistory.warm_start`` to never
    producing one)."""
    if not space.contains(schedule.knobs):
        legal = {k.name: k.choices for k in space.knobs}
        raise ValueError(
            f"warm-start schedule {schedule.knobs!r} is not a point of "
            f"kernel {kernel!r}'s knob space {legal!r}")
    return schedule


def _make_policy(config: TuneConfig, space: SearchSpace,
                 program_for: Callable[[Schedule], Program]) -> MutationPolicy:
    """The proposal policy a tune run uses — guided when config.guided."""
    if config.guided:
        # lazy import: core.guided imports the repro.core package
        from repro.core.guided import GuidedMutationPolicy
        return GuidedMutationPolicy(space=space, program_for=program_for,
                                    knob_prob=config.knob_prob,
                                    greed=config.greed)
    return MutationPolicy(space=space, program_for=program_for,
                          knob_prob=config.knob_prob)


class SipKernel:
    """A kernel whose schedule is SIP-tunable and cache-backed."""

    def __init__(self, *, name: str,
                 build: Callable[..., Callable[..., Any]],
                 program_for: Callable[..., Program],
                 space_for: Callable[..., SearchSpace],
                 oracle: Callable[..., Any],
                 signature_fn: Callable[..., dict[str, Any]],
                 cache: ScheduleCache | None = None):
        self.name = name
        self._build = build              # build(schedule, **static) -> callable
        self._program_for = program_for  # program_for(schedule, **static) -> Program
        self._space_for = space_for      # space_for(**static) -> SearchSpace
        self.oracle = oracle
        self._signature_fn = signature_fn
        self.cache = cache if cache is not None else ScheduleCache()
        self._built: dict[tuple[str, str], Callable[..., Any]] = {}
        self._resolved: dict[str, Callable[..., Any]] = {}
        self._resolved_version = self.cache.version

    # ------------------------------------------------------------- plumbing
    def static_of(self, *args: Any) -> dict[str, Any]:
        return self._signature_fn(*args)

    @staticmethod
    def sig_str(static: dict[str, Any]) -> str:
        return json.dumps(static, sort_keys=True)

    def default_schedule(self, static: dict[str, Any]) -> Schedule:
        space = self._space_for(**static)
        return Schedule(knobs=space.default_knobs())

    def schedule_for(self, static: dict[str, Any]) -> Schedule:
        cached = self.cache.best(self.name, self.sig_str(static))
        return cached if cached is not None else self.default_schedule(static)

    # ------------------------------------------------------------ deployment
    def __call__(self, *args: Any) -> Any:
        static = self.static_of(*args)
        sig = self.sig_str(static)
        if self._resolved_version != self.cache.version:
            # the shared store gained entries — possibly tuned through a
            # DIFFERENT instance bound to it — so drop resolution memos and
            # let schedule_for pick the new best
            self._resolved.clear()
            self._resolved_version = self.cache.version
        fn = self._resolved.get(sig)         # steady state: one dict lookup
        if fn is None:
            sched = self.schedule_for(static)
            key = (sig, sched.signature())
            fn = self._built.get(key)
            if fn is None:
                fn = self._build(sched, **static)
                self._built[key] = fn
            self._resolved[sig] = fn
        return fn(*args)

    # ---------------------------------------------------------------- tuning
    def tune(self, example_args: Sequence[Any],
             config: TuneConfig | None = None,
             verbose: bool = False, *,
             quarantine: MutableSet[str] | None = None,
             x0: Schedule | None = None
             ) -> list[annealing.AnnealResult]:
        """Run the offline search.  ``quarantine`` (optional, caller-owned)
        collects the signatures of schedules whose evaluation crashed or
        blew ``config.eval_deadline_s`` — they score FAILED and are skipped
        on re-proposal; ``TuningSession`` persists the set across resumes.

        ``x0`` warm-starts every chain from the given schedule instead of
        the space default (the autotune history's nearest-tuned-neighbor
        seam).  Its knobs must be legal points of THIS signature's search
        space — an incompatible warm start raises instead of silently
        searching the wrong space; a stale order is fine (resolution falls
        back to the program default when lengths mismatch)."""
        config = TuneConfig() if config is None else config
        config.validate()
        static = self.static_of(*example_args)
        sig = self.sig_str(static)
        space = self._space_for(**static)
        if x0 is not None:
            check_space_compat(x0, space, kernel=self.name)
        specs = [testing.InputSpec(tuple(a.shape), a.dtype) for a in example_args]
        rng = np.random.default_rng(config.seed + 10_000)

        # programs depend only on the knobs (order is resolved against them),
        # so one IR build serves every permutation of a knob point — this is
        # hit by BOTH the mutation policy and the cost-model energy.
        programs: dict[str, Program] = {}

        def program_for(s: Schedule) -> Program:
            key = s.knob_signature()
            prog = programs.get(key)
            if prog is None:
                prog = programs[key] = self._program_for(s, **static)
            return prog

        # one built (jit'd) kernel per schedule, shared by the step-test
        # gate, wall-clock timing, and the final heavy test; bounded LRU so
        # a long search does not pin every compiled executable
        builds = LRUCache(maxsize=config.build_cache)

        def built(s: Schedule) -> Callable[..., Any]:
            return builds.get_or_build(
                s.signature(), lambda: self._build(s, **static))

        def step_test(s: Schedule) -> bool:
            if config.step_samples <= 0:
                return True
            rep = testing.probabilistic_test(built(s), self.oracle, specs,
                                             config.step_samples, rng,
                                             rtol=config.rtol, atol=config.atol)
            return rep.passed

        if config.energy == "costmodel":
            base = energy_mod.CostModelEnergy(program_for)
        elif config.energy == "wallclock":
            base = energy_mod.WallClockEnergy(
                build=built,
                make_args=lambda: [sp.sample(rng) for sp in specs])
        else:
            raise ValueError(config.energy)
        guarded: Callable[[Schedule], float] = energy_mod.GuardedEnergy(base, step_test)
        quarantine_wrap: energy_mod.QuarantineEnergy | None = None
        if config.eval_deadline_s is not None or quarantine is not None:
            # inside the memo wrapper: a quarantined verdict (FAILED) is as
            # cacheable as any other, and quarantine skips stay O(1)
            quarantine_wrap = energy_mod.QuarantineEnergy(
                guarded, deadline_s=config.eval_deadline_s,
                quarantine=quarantine)
            guarded = quarantine_wrap
        if config.memoize:
            # shared across all chains AND rounds: revisited schedules are
            # free.  This also freezes each schedule's step-test verdict at
            # its first evaluation (legacy re-drew step_samples inputs per
            # revisit); the final `final_samples` heavy test below remains
            # the authoritative gate on anything that can reach the cache,
            # and memoize=False restores per-revisit re-testing.
            guarded = energy_mod.CachedEnergy(guarded)
        policy = _make_policy(config, space, program_for)
        if x0 is None:
            x0 = self.default_schedule(static)
        else:
            # merge over the defaults so knobs the neighbor never set keep
            # their space defaults (a PARTIAL warm start is still legal)
            knobs = dict(space.default_knobs())
            knobs.update(x0.knobs)
            x0 = dataclasses.replace(x0, knobs=knobs)

        results = []
        for r in range(config.rounds):
            if r and callable(getattr(guarded, "reset_stats", None)):
                # zero the shared energy cache's hit/miss counters so this
                # round's cache_stats (and any direct guarded.stats() read)
                # describes this round alone; the memo itself persists
                guarded.reset_stats()
            builds.reset_stats()
            builds_before = builds.stats()
            # chains==1 with seed offset r*1 reproduces the legacy sequential
            # restart (anneal(seed=config.seed+r)) bit-for-bit
            with obs_trace.span("tune.round", kernel=self.name, round=r,
                                chains=config.chains) as sp:
                pop = population.population_anneal(
                    x0, guarded, policy.propose, chains=config.chains,
                    t_max=config.t_max, t_min=config.t_min,
                    cooling=config.cooling, ladder=config.ladder,
                    exchange_every=config.exchange_every,
                    seed=config.seed + r * config.chains, memoize=False)
                sp["evals"] = pop.evals
                sp["best_energy"] = pop.best_energy
            res = pop.best_result()
            results.append(res)
            # final, heavier probabilistic test before the entry may be ranked
            with obs_trace.span("tune.final_test", kernel=self.name, round=r):
                try:
                    rep = testing.probabilistic_test(
                        built(res.best), self.oracle, specs,
                        config.final_samples, rng,
                        rtol=config.rtol, atol=config.atol)
                except Exception:
                    # a best candidate that crashes the heavy gate must be
                    # recorded as failing, never kill the session
                    rep = testing.TestReport(passed=False, samples_run=0)
            meta: dict[str, Any] = dict(improvement=res.improvement,
                                        evals=pop.evals, chains=config.chains,
                                        exchanges=pop.exchanges)
            if quarantine_wrap is not None:
                meta["quarantine"] = quarantine_wrap.quarantine_stats()
            # built-kernel LRU over this round, incl. the derived hit ratio
            meta["build_cache"] = energy_mod.delta_stats(builds_before,
                                                         builds.stats())
            if res.cache_stats is not None:
                meta["cache_stats"] = res.cache_stats
            self.cache.put(self.name, sig, res.best, energy=res.best_raw,
                           tests_passed=rep.passed, test_samples=rep.samples_run,
                           round_id=r, **meta)
            self._resolved.pop(sig, None)    # new entries re-resolve on call
            if verbose:
                hits = (res.cache_stats or {}).get("hits", 0)
                print(f"[sip:{self.name}] round {r}: best={res.best_raw:.3e}s "
                      f"improvement={res.improvement:+.2%} "
                      f"chains={config.chains} evals={pop.evals} "
                      f"cache_hits={hits} tests="
                      f"{'PASS' if rep.passed else 'FAIL'}({rep.samples_run})")
        return results


def sip_jit(**kwargs: Any) -> Callable[[Callable[..., Any]], SipKernel]:
    """Decorator form: ``@sip_jit(name=..., program_for=..., ...)`` over the
    kernel factory ``build(schedule, **static)`` (Listing 2 analogue)."""

    def wrap(build: Callable[..., Any]) -> SipKernel:
        return SipKernel(build=build, **kwargs)

    return wrap
