"""Kernel-schedule instruction IR — the TPU stand-in for a disassembled sass stream.

SIP (the paper) mutates the order of *global-memory I/O instructions* inside a
disassembled ``cubin``.  On TPU there is no user-accessible native ISA, so the
mutable artifact here is a small dependency-annotated instruction list from
which the Pallas kernel body is *emitted*: executing the program inside a
``pl.pallas_call`` body traces the ops in schedule order, and Mosaic's static
VLIW scheduler honours the program order of memory operations.

The IR deliberately mirrors the paper's world:

* every :class:`Instr` is tagged ``MEM`` (load/store — the movable set after
  the paper's §3.1 pruning) or ``COMPUTE`` (everything else, immovable);
* dependencies are the usual RAW/WAR/WAW edges plus conservative same-buffer
  ordering between stores and any other access of that buffer — the analogue
  of the sass control-code wait/read/write barriers that make a reorder legal;
* a schedule is a permutation of instruction ids; §3.2's mutation policy only
  ever moves one MEM instruction up or down by one slot.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Sequence


class Kind(enum.Enum):
    MEM = "mem"          # global-memory I/O — the movable set (paper §3.1)
    COMPUTE = "compute"  # arithmetic / MXU / VPU — fixed relative order


@dataclasses.dataclass(frozen=True)
class Instr:
    """One schedulable instruction.

    ``fn(env)`` performs the op when the program is *executed* (emitted into a
    Pallas kernel body or run against plain arrays): it reads ``env[v]`` for
    each input value name ``v`` and must return a dict of output values.

    ``bytes`` / ``flops`` feed the analytic cost model; for MEM ops ``bytes``
    is the transfer size, for COMPUTE ops ``flops`` is the op's work.
    """

    name: str
    kind: Kind
    inputs: tuple[str, ...]
    outputs: tuple[str, ...]
    fn: Callable[[dict[str, Any]], dict[str, Any]]
    buffer: str | None = None       # buffer identity for memory-order edges
    is_store: bool = False
    bytes: int = 0
    flops: int = 0

    def __repr__(self) -> str:  # compact, sass-listing-like
        tag = "ST" if self.is_store else ("LD" if self.kind is Kind.MEM else "OP")
        return f"{tag} {self.name}({', '.join(self.inputs)}) -> {', '.join(self.outputs)}"


class Program:
    """An ordered instruction list with dependency analysis and legal ±1 moves.

    ``replications`` is the number of times the body executes per kernel
    launch (the grid size); the cost model multiplies by it so schedule
    knobs that shrink the body but multiply the grid are priced correctly.
    """

    def __init__(self, instrs: Sequence[Instr], replications: int = 1):
        self.instrs: list[Instr] = list(instrs)
        self.replications = max(int(replications), 1)
        names = [i.name for i in self.instrs]
        if len(set(names)) != len(names):
            raise ValueError("instruction names must be unique")
        self._deps = self._build_deps()

    # ------------------------------------------------------------------ deps
    def _build_deps(self) -> list[set[int]]:
        """deps[j] = set of instruction indices that must precede instr j."""
        deps: list[set[int]] = [set() for _ in self.instrs]
        last_writer: dict[str, int] = {}
        readers: dict[str, list[int]] = {}
        # memory-order state per buffer
        buf_last_store: dict[str, int] = {}
        buf_accesses: dict[str, list[int]] = {}
        for j, ins in enumerate(self.instrs):
            for v in ins.inputs:          # RAW
                if v in last_writer:
                    deps[j].add(last_writer[v])
            for v in ins.outputs:         # WAW / WAR
                if v in last_writer:
                    deps[j].add(last_writer[v])
                for r in readers.get(v, ()):
                    deps[j].add(r)
            if ins.buffer is not None:
                if ins.is_store:
                    # a store orders against every prior access of the buffer
                    for a in buf_accesses.get(ins.buffer, ()):
                        deps[j].add(a)
                elif ins.buffer in buf_last_store:
                    # a load orders against the last store to the buffer
                    deps[j].add(buf_last_store[ins.buffer])
            # update state
            for v in ins.inputs:
                readers.setdefault(v, []).append(j)
            for v in ins.outputs:
                last_writer[v] = j
                readers[v] = []
            if ins.buffer is not None:
                buf_accesses.setdefault(ins.buffer, []).append(j)
                if ins.is_store:
                    buf_last_store[ins.buffer] = j
        for j in range(len(deps)):
            deps[j].discard(j)
        return deps

    @property
    def deps(self) -> list[set[int]]:
        return self._deps

    def default_order(self) -> tuple[int, ...]:
        """The compiler-like baseline schedule: program order (= ptxas O3 stand-in)."""
        return tuple(range(len(self.instrs)))

    def mem_indices(self) -> list[int]:
        """Indices of the movable (global-memory I/O) instructions — §3.1 pruning."""
        return [i for i, ins in enumerate(self.instrs) if ins.kind is Kind.MEM]

    # ----------------------------------------------------------------- legal
    def is_legal(self, order: Sequence[int]) -> bool:
        if sorted(order) != list(range(len(self.instrs))):
            return False
        pos = {idx: p for p, idx in enumerate(order)}
        return all(pos[d] < pos[j] for j in range(len(self.instrs)) for d in self._deps[j])

    def swap_is_legal(self, order: Sequence[int], slot: int) -> bool:
        """Is swapping ``order[slot]`` and ``order[slot+1]`` dependency-legal?"""
        a, b = order[slot], order[slot + 1]
        return a not in self._deps[b] and b not in self._deps[a]

    def move(self, order: Sequence[int], instr_idx: int, direction: int) -> tuple[int, ...] | None:
        """Move instruction ``instr_idx`` up (-1) or down (+1) by one slot.

        Returns the new order, or None if the move is illegal / out of range.
        This is exactly the paper's §3.2 action: (which instruction, direction).
        """
        order = list(order)
        slot = order.index(instr_idx)
        tgt = slot + direction
        if tgt < 0 or tgt >= len(order):
            return None
        lo = min(slot, tgt)
        if not self.swap_is_legal(order, lo):
            return None
        order[slot], order[tgt] = order[tgt], order[slot]
        return tuple(order)

    def legal_moves(self, order: Sequence[int]) -> list[tuple[int, int]]:
        """All legal (mem_instr_idx, direction) actions from ``order``."""
        moves = []
        pos = {idx: p for p, idx in enumerate(order)}
        for idx in self.mem_indices():
            for direction in (-1, +1):
                slot = pos[idx]
                tgt = slot + direction
                if 0 <= tgt < len(order) and self.swap_is_legal(order, min(slot, tgt)):
                    moves.append((idx, direction))
        return moves

    # ------------------------------------------------------------------ emit
    def execute(self, env: dict[str, Any], order: Sequence[int] | None = None) -> dict[str, Any]:
        """Run / trace the program in schedule order.

        Inside a Pallas kernel body this *is* the emitter: the ``fn`` of each
        instruction issues ``pl.load`` / ``pl.store`` / jnp ops, and the trace
        order (hence Mosaic's program order) follows ``order``.
        """
        if order is None:
            order = self.default_order()
        if not self.is_legal(order):
            raise ValueError("illegal schedule order")
        env = dict(env)
        for idx in order:
            ins = self.instrs[idx]
            out = ins.fn(env)
            if out:
                env.update(out)
        return env

    # ----------------------------------------------------------------- repr
    def listing(self, order: Sequence[int] | None = None) -> str:
        """sass-listing-style dump (cf. paper Listings 4/5)."""
        if order is None:
            order = self.default_order()
        return "\n".join(f"{p:4d}  {self.instrs[idx]!r}" for p, idx in enumerate(order))

    def __len__(self) -> int:
        return len(self.instrs)
