"""Beyond-paper search upgrade: cost-model-guided mutation.

The paper's §6 notes simulated annealing "is unable to explore the search
space efficiently" and suggests better search as future work.  On TPU the
analytic cost model is cheap enough to evaluate EVERY legal ±1 action at a
state, which enables an epsilon-greedy proposal: with probability
``greed`` propose the best-scoring legal action, otherwise fall back to the
paper's uniform action.  Acceptance stays Metropolis (Alg. 1), so the
stationary behaviour is preserved while convergence accelerates — measured
in benchmarks/guided_search.py.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import costmodel
from repro.core.ir import Program
from repro.core.mutation import MutationPolicy
from repro.core.schedule import Schedule


_MEMO_MAX = 65536


def fit_greed(improvements: Sequence[float], default: float = 0.5,
              lo: float = 0.1, hi: float = 0.9) -> float:
    """Fit the guided policy's greed on accumulated accepted-move data.

    ``improvements`` are the relative improvements of past *accepted* search
    outcomes for a kernel (``AnnealResult.improvement`` of runs whose best
    passed the gate — what :class:`~repro.autotune.history.TuneHistory`
    accumulates across sessions).  The order statistic used is the fraction
    of accepted runs that actually improved on their start: when the cost
    model's greedy proposals have historically paid off, lean harder on them
    (greed toward ``hi``); when accepted moves mostly came from the uniform
    fallback (improvements ~0), drift back toward exploration (``lo``).
    With no history the caller's ``default`` stands.
    """
    xs = [float(v) for v in improvements if np.isfinite(v)]
    if not xs:
        return default
    win_rate = sum(1 for v in xs if v > 0) / len(xs)
    return float(np.clip(lo + (hi - lo) * win_rate, lo, hi))


@dataclasses.dataclass
class GuidedMutationPolicy(MutationPolicy):
    greed: float = 0.5
    machine: costmodel.Machine = costmodel.V5E
    # simulate() memo keyed on (knob point, order): a greedy sweep scores
    # every legal +-1 move, and neighbouring states share almost all of them,
    # so revisited orders dominate — the same memoization argument as
    # energy.CachedEnergy, one level down
    _memo: dict = dataclasses.field(default_factory=dict, repr=False)

    def _simulate(self, knob_key: str, program: Program,
                  order: tuple[int, ...]) -> float:
        key = (knob_key, order)
        t = self._memo.get(key)
        if t is None:
            if len(self._memo) >= _MEMO_MAX:
                self._memo.clear()
            t = self._memo[key] = costmodel.simulate(program, order, self.machine)
        return t

    def propose(self, schedule: Schedule, rng: np.random.Generator) -> Schedule | None:
        # greed<=0 degenerates to the paper's policy exactly (same rng stream)
        if self.greed <= 0 or rng.random() >= self.greed:
            return super().propose(schedule, rng)
        program: Program = self.program_for(schedule)
        order = schedule.resolve_order(program)
        moves = program.legal_moves(order)
        if not moves:
            return super().propose(schedule, rng)
        knob_key = schedule.knob_signature()
        best_order, best_t = None, float("inf")
        for idx, direction in moves:
            cand = program.move(order, idx, direction)
            if cand is None:
                continue
            t = self._simulate(knob_key, program, tuple(cand))
            if t < best_t:
                best_order, best_t = cand, t
        if best_order is None or best_order == tuple(order):
            return super().propose(schedule, rng)
        return schedule.with_order(best_order)
