"""Beyond-paper search upgrade: cost-model-guided mutation.

The paper's §6 notes simulated annealing "is unable to explore the search
space efficiently" and suggests better search as future work.  On TPU the
analytic cost model is cheap enough to evaluate EVERY legal ±1 action at a
state, which enables an epsilon-greedy proposal: with probability
``greed`` propose the best-scoring legal action, otherwise fall back to the
paper's uniform action.  Acceptance stays Metropolis (Alg. 1), so the
stationary behaviour is preserved while convergence accelerates — measured
in benchmarks/guided_search.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import costmodel
from repro.core.ir import Program
from repro.core.mutation import MutationPolicy
from repro.core.schedule import Schedule


@dataclasses.dataclass
class GuidedMutationPolicy(MutationPolicy):
    greed: float = 0.5
    machine: costmodel.Machine = costmodel.V5E

    def propose(self, schedule: Schedule, rng: np.random.Generator) -> Schedule | None:
        # greed<=0 degenerates to the paper's policy exactly (same rng stream)
        if self.greed <= 0 or rng.random() >= self.greed:
            return super().propose(schedule, rng)
        program: Program = self.program_for(schedule)
        order = schedule.resolve_order(program)
        moves = program.legal_moves(order)
        if not moves:
            return super().propose(schedule, rng)
        best_order, best_t = None, float("inf")
        for idx, direction in moves:
            cand = program.move(order, idx, direction)
            if cand is None:
                continue
            t = costmodel.simulate(program, cand, self.machine)
            if t < best_t:
                best_order, best_t = cand, t
        if best_order is None or best_order == tuple(order):
            return super().propose(schedule, rng)
        return schedule.with_order(best_order)
