"""SIP core — the paper's contribution as a composable JAX-facing library.

Public API:
    ir.Program / ir.Instr / ir.Kind      — the mutable schedule artifact
    schedule.Schedule / SearchSpace      — candidate representation
    mutation.MutationPolicy              — §3.2 mutation policy
    energy.{CostModelEnergy,WallClockEnergy,GuardedEnergy,CachedEnergy,reward}
    annealing.anneal / multi_round       — Algorithm 1
    population.population_anneal         — K lockstep chains + best-state exchange
    testing.probabilistic_test           — §4.2 (vectorized batches)
    cache.ScheduleCache / LRUCache       — §4.1 offline store + build LRU
    jit.sip_jit / SipKernel / TuneConfig — one-line integration
    registry.{KernelSpec,Workload,sip_kernel,registry,schedule_cache}
                                         — declarative kernel registration
    costmodel                            — TPU v5e constants + simulator
"""

from repro.core.annealing import AnnealResult, AnnealStep, Chain, anneal, multi_round
from repro.core.cache import CacheEntry, LRUCache, ScheduleCache
from repro.core.energy import (CachedEnergy, CostModelEnergy, GuardedEnergy,
                               WallClockEnergy, reward)
from repro.core.ir import Instr, Kind, Program
from repro.core.jit import SipKernel, TuneConfig, sip_jit
from repro.core.mutation import MutationPolicy
from repro.core.population import PopulationResult, population_anneal
from repro.core.registry import (KernelHandle, KernelRegistry, KernelSpec,
                                 Workload, active_schedule_cache,
                                 cache_for_path, registry, schedule_cache,
                                 sip_kernel, workload_seed)
from repro.core.schedule import KnobSpec, Schedule, SearchSpace
from repro.core.testing import FaultInjector, InputSpec, TestReport, probabilistic_test

__all__ = [
    "AnnealResult", "AnnealStep", "Chain", "anneal", "multi_round",
    "PopulationResult", "population_anneal",
    "CacheEntry", "LRUCache", "ScheduleCache",
    "CachedEnergy", "CostModelEnergy", "GuardedEnergy", "WallClockEnergy", "reward",
    "Instr", "Kind", "Program",
    "SipKernel", "TuneConfig", "sip_jit",
    "KernelHandle", "KernelRegistry", "KernelSpec", "Workload",
    "active_schedule_cache", "cache_for_path", "registry", "schedule_cache",
    "sip_kernel", "workload_seed",
    "MutationPolicy",
    "KnobSpec", "Schedule", "SearchSpace",
    "FaultInjector", "InputSpec", "TestReport", "probabilistic_test",
]
