"""SIP core — the paper's contribution as a composable JAX-facing library.

Public API:
    ir.Program / ir.Instr / ir.Kind      — the mutable schedule artifact
    schedule.Schedule / SearchSpace      — candidate representation
    mutation.MutationPolicy              — §3.2 mutation policy
    energy.{CostModelEnergy,WallClockEnergy,GuardedEnergy,reward}
    annealing.anneal / multi_round       — Algorithm 1
    testing.probabilistic_test           — §4.2
    cache.ScheduleCache                  — §4.1 offline store + greedy rank
    jit.sip_jit / SipKernel / TuneConfig — one-line integration
    costmodel                            — TPU v5e constants + simulator
"""

from repro.core.annealing import AnnealResult, AnnealStep, anneal, multi_round
from repro.core.cache import CacheEntry, ScheduleCache
from repro.core.energy import CostModelEnergy, GuardedEnergy, WallClockEnergy, reward
from repro.core.ir import Instr, Kind, Program
from repro.core.jit import SipKernel, TuneConfig, sip_jit
from repro.core.mutation import MutationPolicy
from repro.core.schedule import KnobSpec, Schedule, SearchSpace
from repro.core.testing import FaultInjector, InputSpec, TestReport, probabilistic_test

__all__ = [
    "AnnealResult", "AnnealStep", "anneal", "multi_round",
    "CacheEntry", "ScheduleCache",
    "CostModelEnergy", "GuardedEnergy", "WallClockEnergy", "reward",
    "Instr", "Kind", "Program",
    "SipKernel", "TuneConfig", "sip_jit",
    "MutationPolicy",
    "KnobSpec", "Schedule", "SearchSpace",
    "FaultInjector", "InputSpec", "TestReport", "probabilistic_test",
]
