"""Simulated annealing for Stochastic Instruction Perturbation (paper Alg. 1).

Faithful transcription:

    1:  Initialize T_max, T_min, x
    2:  x_best <- x
    3:  T <- T_max
    4:  while T > T_min do
    5:      x' <- perturb(x)
    6:      dE = Energy(x') - Energy(x)
    7:      if dE < 0:  x <- x';  if Energy(x) < Energy(x_best): x_best <- x
    13:     elif r < exp(-dE/T):  x <- x'
    17:     T <- T * L^-1
    19: return x_best

Energies are normalized by the initial runtime T_0 so that the temperature
scale is shape-independent; the paper's reward R = (T_{i-1}-T_i)/T_0 is then
exactly -dE and is recorded per step in the history.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.core.schedule import Schedule


@dataclasses.dataclass
class AnnealStep:
    step: int
    temperature: float
    energy: float          # normalized candidate energy (T_i / T_0)
    reward: float          # paper Eq. (1)
    accepted: bool
    best_energy: float


@dataclasses.dataclass
class AnnealResult:
    best: Schedule
    best_energy: float     # normalized
    best_raw: float        # seconds
    initial_raw: float     # T_0, seconds
    history: list[AnnealStep]
    evals: int

    @property
    def improvement(self) -> float:
        """Fractional runtime reduction vs the unmutated schedule."""
        if not math.isfinite(self.best_raw) or self.initial_raw == 0:
            return 0.0
        return (self.initial_raw - self.best_raw) / self.initial_raw


def anneal(x0: Schedule,
           energy: Callable[[Schedule], float],
           perturb: Callable[[Schedule, np.random.Generator], Schedule | None],
           *,
           t_max: float = 1.0,
           t_min: float = 1e-3,
           cooling: float = 1.05,          # the paper's L:  T <- T * L^-1
           seed: int = 0,
           on_step: Callable[[AnnealStep], None] | None = None) -> AnnealResult:
    if cooling <= 1.0:
        raise ValueError(f"cooling must be > 1 (T <- T/L each step), "
                         f"got {cooling}: the loop would never terminate")
    rng = np.random.default_rng(seed)
    t0_raw = energy(x0)
    if not math.isfinite(t0_raw) or t0_raw <= 0:
        raise ValueError("initial schedule must be runnable (finite positive energy)")

    def norm(e_raw: float) -> float:
        return e_raw / t0_raw if math.isfinite(e_raw) else float("inf")

    x, e_x = x0, 1.0
    x_best, e_best, raw_best = x0, 1.0, t0_raw
    history: list[AnnealStep] = []
    evals = 1
    T = t_max
    step = 0
    while T > t_min:
        cand = perturb(x, rng)
        if cand is None:                   # no legal action from x
            T /= cooling
            step += 1
            continue
        e_raw = energy(cand)
        evals += 1
        e_c = norm(e_raw)
        dE = e_c - e_x
        accepted = False
        if dE < 0:
            x, e_x = cand, e_c
            accepted = True
            if e_c < e_best:
                x_best, e_best, raw_best = cand, e_c, e_raw
        elif math.isfinite(dE) and rng.random() < math.exp(-dE / T):
            x, e_x = cand, e_c
            accepted = True
        rec = AnnealStep(step=step, temperature=T, energy=e_c,
                         reward=-dE if math.isfinite(dE) else 0.0,
                         accepted=accepted, best_energy=e_best)
        history.append(rec)
        if on_step is not None:
            on_step(rec)
        T /= cooling
        step += 1
    return AnnealResult(best=x_best, best_energy=e_best, best_raw=raw_best,
                        initial_raw=t0_raw, history=history, evals=evals)


def multi_round(x0: Schedule, energy, perturb, *, rounds: int = 4,
                seed: int = 0, **kw) -> list[AnnealResult]:
    """§4.1: "SIP is expected to perform offline searches and store results
    from multiple rounds of searches" — independent restarts, greedily ranked
    by the caller (see core.cache)."""
    return [anneal(x0, energy, perturb, seed=seed + r, **kw) for r in range(rounds)]
