"""Simulated annealing for Stochastic Instruction Perturbation (paper Alg. 1).

Faithful transcription:

    1:  Initialize T_max, T_min, x
    2:  x_best <- x
    3:  T <- T_max
    4:  while T > T_min do
    5:      x' <- perturb(x)
    6:      dE = Energy(x') - Energy(x)
    7:      if dE < 0:  x <- x';  if Energy(x) < Energy(x_best): x_best <- x
    13:     elif r < exp(-dE/T):  x <- x'
    17:     T <- T * L^-1
    19: return x_best

Energies are normalized by the initial runtime T_0 so that the temperature
scale is shape-independent; the paper's reward R = (T_{i-1}-T_i)/T_0 is then
exactly -dE and is recorded per step in the history.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.core.energy import delta_stats
from repro.core.schedule import Schedule
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class AnnealStep:
    step: int
    temperature: float
    energy: float          # normalized candidate energy (T_i / T_0)
    reward: float          # paper Eq. (1)
    accepted: bool
    best_energy: float


@dataclasses.dataclass
class AnnealResult:
    best: Schedule
    best_energy: float     # normalized
    best_raw: float        # seconds
    initial_raw: float     # T_0, seconds
    history: list[AnnealStep]
    evals: int
    cache_stats: dict[str, float] | None = None  # CachedEnergy hit/miss (+
    #                                              derived hit_rate), if used

    @property
    def improvement(self) -> float:
        """Fractional runtime reduction vs the unmutated schedule."""
        if not math.isfinite(self.best_raw) or self.initial_raw == 0:
            return 0.0
        return (self.initial_raw - self.best_raw) / self.initial_raw


class Chain:
    """One Alg.-1 chain, advanced one perturb/accept step at a time.

    :func:`anneal` drives a single chain to completion; population search
    (:mod:`repro.core.population`) drives K of them in lockstep on a
    temperature ladder.  The step logic lives here and only here, so a
    single chain behaves bit-identically however it is driven.
    """

    def __init__(self, x0: Schedule,
                 energy: Callable[[Schedule], float],
                 perturb: Callable[[Schedule, np.random.Generator], Schedule | None],
                 *, t_max: float, t_min: float, cooling: float, seed: int,
                 on_step: Callable[[AnnealStep], None] | None = None,
                 label: str = "chain0"):
        if cooling <= 1.0:
            raise ValueError(f"cooling must be > 1 (T <- T/L each step), "
                             f"got {cooling}: the loop would never terminate")
        self.energy = energy
        self.perturb = perturb
        self.t_min = t_min
        self.cooling = cooling
        self.on_step = on_step
        self.label = label
        # search-loop telemetry: counters land in the active metrics
        # registry (scoped or process default); the per-step energy
        # trajectory goes to the active tracer, if any, as a counter track
        # per chain label (plots energy-vs-step in Perfetto)
        reg = obs_metrics.active_registry()
        self._m_steps = reg.counter("search.steps")
        self._m_accepted = reg.counter("search.accepted")
        self._m_dead = reg.counter("search.dead_steps")
        self.rng = np.random.default_rng(seed)
        t0_raw = energy(x0)
        if not math.isfinite(t0_raw) or t0_raw <= 0:
            raise ValueError("initial schedule must be runnable "
                             "(finite positive energy)")
        self.t0_raw = t0_raw
        self.x, self.e_x = x0, 1.0
        self.x_best, self.e_best, self.raw_best = x0, 1.0, t0_raw
        self.history: list[AnnealStep] = []
        self.evals = 1
        self.T = t_max
        self.step = 0

    @property
    def done(self) -> bool:
        return self.T <= self.t_min

    def _norm(self, e_raw: float) -> float:
        return e_raw / self.t0_raw if math.isfinite(e_raw) else float("inf")

    def adopt(self, x: Schedule, e_x: float) -> None:
        """Replace the current state (population exchange); best is untouched."""
        self.x, self.e_x = x, e_x

    def advance(self) -> AnnealStep | None:
        """One while-loop iteration of Alg. 1: propose, accept/reject, cool.

        Returns the recorded step, or None when no legal action existed."""
        cand = self.perturb(self.x, self.rng)
        if cand is None:                   # no legal action from x
            self._m_dead.inc()
            self.T /= self.cooling
            self.step += 1
            return None
        e_raw = self.energy(cand)
        self.evals += 1
        e_c = self._norm(e_raw)
        dE = e_c - self.e_x
        accepted = False
        if dE < 0:
            self.x, self.e_x = cand, e_c
            accepted = True
            if e_c < self.e_best:
                self.x_best, self.e_best, self.raw_best = cand, e_c, e_raw
        elif math.isfinite(dE) and self.rng.random() < math.exp(-dE / self.T):
            self.x, self.e_x = cand, e_c
            accepted = True
        rec = AnnealStep(step=self.step, temperature=self.T, energy=e_c,
                         reward=-dE if math.isfinite(dE) else 0.0,
                         accepted=accepted, best_energy=self.e_best)
        self._m_steps.inc()
        if accepted:
            self._m_accepted.inc()
        tr = obs_trace.active_tracer()
        if tr is not None:
            vals = {"best": self.e_best, "T": self.T, "step": self.step}
            if math.isfinite(e_c):
                vals["energy"] = e_c
            tr.counter(f"search.energy/{self.label}", vals)
        self.history.append(rec)
        if self.on_step is not None:
            self.on_step(rec)
        self.T /= self.cooling
        self.step += 1
        return rec

    def result(self) -> AnnealResult:
        return AnnealResult(best=self.x_best, best_energy=self.e_best,
                            best_raw=self.raw_best, initial_raw=self.t0_raw,
                            history=self.history, evals=self.evals)


def anneal(x0: Schedule,
           energy: Callable[[Schedule], float],
           perturb: Callable[[Schedule, np.random.Generator], Schedule | None],
           *,
           t_max: float = 1.0,
           t_min: float = 1e-3,
           cooling: float = 1.05,          # the paper's L:  T <- T * L^-1
           seed: int = 0,
           on_step: Callable[[AnnealStep], None] | None = None) -> AnnealResult:
    stats = getattr(energy, "stats", None)
    before = stats() if callable(stats) else None
    chain = Chain(x0, energy, perturb, t_max=t_max, t_min=t_min,
                  cooling=cooling, seed=seed, on_step=on_step)
    while not chain.done:
        chain.advance()
    res = chain.result()
    if before is not None:
        res.cache_stats = delta_stats(before, stats())
    return res


def multi_round(x0: Schedule, energy, perturb, *, rounds: int = 4,
                seed: int = 0, **kw) -> list[AnnealResult]:
    """§4.1: "SIP is expected to perform offline searches and store results
    from multiple rounds of searches" — independent restarts, greedily ranked
    by the caller (see core.cache).

    This is the paper-faithful sequential form; the tuning hot path
    (``SipKernel.tune``) now runs :func:`repro.core.population.population_anneal`
    instead, which generalizes these restarts to lockstep chains with shared
    memoized energy (``chains=1`` reproduces one restart bit-for-bit)."""
    return [anneal(x0, energy, perturb, seed=seed + r, **kw) for r in range(rounds)]
