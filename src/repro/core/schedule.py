"""Schedule representation and search space.

A :class:`Schedule` is a point in the SIP search space: the instruction-order
permutation (the paper's space, §3.1) plus optional macro knobs (BlockSpec
tile shapes, grid ``dimension_semantics`` — TPU-specific, tagged beyond-paper;
faithful mode keeps knobs frozen and searches order only).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping, Sequence

from repro.core.ir import Program


@dataclasses.dataclass(frozen=True)
class KnobSpec:
    """One discrete macro knob, e.g. block_m in {128, 256, 512}."""

    name: str
    choices: tuple[Any, ...]

    def __post_init__(self):
        if not self.choices:
            raise ValueError(f"knob {self.name} has no choices")


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Search space = instruction permutations x knob grid."""

    knobs: tuple[KnobSpec, ...] = ()

    def default_knobs(self) -> dict[str, Any]:
        return {k.name: k.choices[0] for k in self.knobs}

    def knob(self, name: str) -> KnobSpec:
        for k in self.knobs:
            if k.name == name:
                return k
        raise KeyError(name)

    def contains(self, knobs: Mapping[str, Any]) -> bool:
        """True when every (name, value) is a legal point of this space —
        the warm-start compatibility check: a schedule imported from another
        signature's history may only seed a search here if its knobs all
        exist in THIS space and sit on declared choices."""
        by_name = {k.name: k.choices for k in self.knobs}
        return all(name in by_name and value in by_name[name]
                   for name, value in knobs.items())


@dataclasses.dataclass(frozen=True)
class Schedule:
    """An immutable schedule candidate.

    ``order`` is None until the kernel factory instantiates its Program for
    the chosen knobs (the instruction count can depend on tile sizes — e.g.
    the number of K-steps in a GEMM body).
    """

    knobs: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    order: tuple[int, ...] | None = None

    def with_order(self, order: Sequence[int]) -> "Schedule":
        return dataclasses.replace(self, order=tuple(int(i) for i in order))

    def with_knob(self, name: str, value: Any) -> "Schedule":
        knobs = dict(self.knobs)
        knobs[name] = value
        # knob changes invalidate the order (instruction count may change)
        return Schedule(knobs=knobs, order=None)

    def resolve_order(self, program: Program) -> tuple[int, ...]:
        if self.order is not None and len(self.order) == len(program):
            return self.order
        return program.default_order()

    # -------------------------------------------------------- serialization
    def to_json(self) -> str:
        return json.dumps({"knobs": dict(self.knobs),
                           "order": list(self.order) if self.order is not None else None},
                          sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "Schedule":
        d = json.loads(s)
        order = tuple(d["order"]) if d.get("order") is not None else None
        return Schedule(knobs=d.get("knobs", {}), order=order)

    def signature(self) -> str:
        return self.to_json()

    def knob_signature(self) -> str:
        """Canonical key for the knob point alone — the Program IR depends
        only on knobs, so program/simulation memos key on this."""
        return json.dumps(dict(self.knobs), sort_keys=True, default=str)
