"""Offline schedule store (paper §4.1).

"SIP is expected to perform offline searches and store results from multiple
rounds of searches.  Then it applies a greedy algorithm to rank all found
cubin and picks the best one if it passes all tests.  Finally, at deployment,
the best cubin is retrieved and loaded directly without incurring any runtime
overhead."

Entries are keyed by (kernel_name, signature) where signature encodes the
input shapes/dtypes and the hardware target — the analogue of one compiled
cubin per launch configuration.  Storage is a single JSON file with atomic
replace so concurrent searches do not corrupt it.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import tempfile
import threading
import warnings
from typing import Any, Callable, Sequence

from repro.core.schedule import Schedule


class LRUCache:
    """Small bounded LRU with hit/miss accounting.

    Used by ``SipKernel.tune`` to share built (jit'd) kernels between the
    step-test gate, wall-clock timing, and the final heavy test — one
    ``_build`` per schedule instead of three — while bounding the number of
    live compiled executables the search keeps around.
    """

    def __init__(self, maxsize: int = 32):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._data: collections.OrderedDict[Any, Any] = collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Any) -> bool:
        return key in self._data

    def get_or_build(self, key: Any, build: Callable[[], Any]) -> Any:
        """Return the cached value for ``key``, building (and possibly
        evicting the least-recently-used entry) on miss."""
        if key in self._data:
            self.hits += 1
            self._data.move_to_end(key)
            return self._data[key]
        self.misses += 1
        value = build()
        self._data[key] = value
        if len(self._data) > self.maxsize:
            self._data.popitem(last=False)
        return value

    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._data)}

    def reset_stats(self) -> None:
        """Zero the hit/miss counters (entries are kept) — used to scope
        build-cache stats to one tuning round."""
        self.hits = 0
        self.misses = 0


@dataclasses.dataclass
class CacheEntry:
    schedule_json: str
    energy: float              # seconds (raw)
    tests_passed: bool
    test_samples: int
    round_id: int
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "CacheEntry":
        return CacheEntry(**d)


@dataclasses.dataclass(frozen=True)
class PendingPut:
    """One staged :meth:`ScheduleCache.commit` entry — a ``put`` that has not
    happened yet.  The autotune promotion path stages every gated winner of a
    cycle and lands them in ONE commit: one version bump, one atomic flush,
    so engines watching :meth:`ScheduleCache.changed_since` re-resolve once
    per promotion batch instead of once per entry."""

    kernel_name: str
    signature: str
    schedule: Schedule
    energy: float
    tests_passed: bool
    test_samples: int = 0
    round_id: int = 0
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)


class ScheduleCache:
    def __init__(self, path: str | None = None):
        self.path = path
        self._lock = threading.Lock()
        self._data: dict[str, list[dict]] = {}
        # bumped on every put; SipKernel instances sharing this store compare
        # it against their resolution memo so a schedule tuned through ONE
        # instance invalidates every other instance's cached resolution
        self.version = 0
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    loaded = json.load(f)
                if not isinstance(loaded, dict):
                    raise ValueError(f"expected a JSON object, got "
                                     f"{type(loaded).__name__}")
                for key, entries in loaded.items():
                    if not isinstance(entries, list):
                        raise ValueError(f"entry list for {key!r} is "
                                         f"{type(entries).__name__}")
                    for d in entries:
                        CacheEntry.from_dict(d)   # raises on malformed entry
                self._data = loaded
            except (json.JSONDecodeError, ValueError, TypeError,
                    OSError) as e:
                # a truncated/corrupt store must not take tuning down with
                # it — degrade to empty (the next flush rewrites the file)
                warnings.warn(f"ScheduleCache: ignoring unreadable cache "
                              f"file {path!r} ({e}); starting empty",
                              RuntimeWarning, stacklevel=2)

    @staticmethod
    def key(kernel_name: str, signature: str) -> str:
        return f"{kernel_name}::{signature}"

    def put(self, kernel_name: str, signature: str, schedule: Schedule,
            energy: float, tests_passed: bool, test_samples: int = 0,
            round_id: int = 0, **meta: Any) -> None:
        self.commit([PendingPut(kernel_name=kernel_name, signature=signature,
                                schedule=schedule, energy=energy,
                                tests_passed=tests_passed,
                                test_samples=test_samples, round_id=round_id,
                                meta=meta)])

    def commit(self, puts: Sequence[PendingPut]) -> None:
        """Land a batch of entries atomically: every entry is appended under
        one lock hold, the version bumps ONCE, and the store flushes once
        (write-then-rename, so readers of ``path`` see the old file or the
        whole batch, never a torn state).  An empty batch is a no-op — no
        bump, no flush."""
        if not puts:
            return
        with self._lock:
            for p in puts:
                entry = CacheEntry(schedule_json=p.schedule.to_json(),
                                   energy=p.energy,
                                   tests_passed=p.tests_passed,
                                   test_samples=p.test_samples,
                                   round_id=p.round_id, meta=dict(p.meta))
                self._data.setdefault(self.key(p.kernel_name, p.signature),
                                      []).append(entry.to_dict())
            self.version += 1
            self._flush()

    def changed_since(self, version: int) -> bool:
        """True when the store has committed anything after ``version`` — the
        O(1) check engines run per step to detect a hot-swapped schedule
        (capture ``cache.version``, later ask ``cache.changed_since(v)``)."""
        return self.version != version

    def best(self, kernel_name: str, signature: str) -> Schedule | None:
        """Greedy rank: among all rounds, the lowest-energy entry that passed
        all tests (paper §4.1)."""
        entries = [CacheEntry.from_dict(d)
                   for d in self._data.get(self.key(kernel_name, signature), [])]
        passing = [e for e in entries if e.tests_passed]
        if not passing:
            return None
        best = min(passing, key=lambda e: e.energy)
        return Schedule.from_json(best.schedule_json)

    def entries(self, kernel_name: str, signature: str) -> list[CacheEntry]:
        return [CacheEntry.from_dict(d)
                for d in self._data.get(self.key(kernel_name, signature), [])]

    def drop(self, kernel_name: str, signature: str) -> int:
        """Remove every entry for one (kernel, signature) key.  Returns the
        number of entries removed.  Used by crash-safe tuning: a resumed
        session purges the partial rounds of the workload that was
        in-flight when the previous session died, then re-runs it from its
        deterministic seed — the store converges to exactly what an
        uninterrupted session would have written."""
        with self._lock:
            removed = self._data.pop(self.key(kernel_name, signature), None)
            if removed:
                self.version += 1
                self._flush()
        return len(removed) if removed else 0

    def _flush(self) -> None:
        if not self.path:
            return
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".sipcache")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
