"""Mutation policy (paper §3.2).

"If there exist k memory I/O instructions, the mutation policy may choose one
of them to move up or down by one.  The exact instruction to move and
direction is randomly chosen.  The action vector is two discrete numbers."

Faithful mode samples exactly that action.  An illegal action (dependency
violation or boundary) is resampled — equivalent to the paper's rejection of
schedules that cannot be assembled.  ``knob_prob > 0`` additionally mutates a
macro knob with that probability (beyond-paper TPU extension, off by default).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.ir import Program
from repro.core.schedule import Schedule, SearchSpace


@dataclasses.dataclass
class MutationPolicy:
    space: SearchSpace
    program_for: Callable[[Schedule], Program]   # kernel factory's IR builder
    knob_prob: float = 0.0                       # 0.0 == paper-faithful
    max_resample: int = 64

    def propose(self, schedule: Schedule, rng: np.random.Generator) -> Schedule | None:
        """One SIP action. Returns None if no legal action exists."""
        if self.space.knobs and rng.random() < self.knob_prob:
            mutated = self._mutate_knob(schedule, rng)
            if mutated is not None:
                return mutated
        return self._mutate_order(schedule, rng)

    # ---------------------------------------------------------------- order
    def _mutate_order(self, schedule: Schedule, rng: np.random.Generator) -> Schedule | None:
        program = self.program_for(schedule)
        order = schedule.resolve_order(program)
        mem = program.mem_indices()
        if not mem:
            return None
        for _ in range(self.max_resample):
            instr_idx = mem[int(rng.integers(len(mem)))]   # which instruction
            direction = -1 if rng.random() < 0.5 else +1   # which direction
            new_order = program.move(order, instr_idx, direction)
            if new_order is not None and new_order != tuple(order):
                return schedule.with_order(new_order)
        return None

    # ---------------------------------------------------------------- knobs
    def _mutate_knob(self, schedule: Schedule, rng: np.random.Generator) -> Schedule | None:
        knobs = [k for k in self.space.knobs if len(k.choices) > 1]
        if not knobs:
            return None
        k = knobs[int(rng.integers(len(knobs)))]
        cur = schedule.knobs.get(k.name, k.choices[0])
        alt = [c for c in k.choices if c != cur]
        return schedule.with_knob(k.name, alt[int(rng.integers(len(alt)))])
