"""Analytic TPU cost model for kernel schedules.

The paper (§3.3) considers two feedback sources and rejects cost modeling on
GPUs because the only available simulator (gpgpu-sim) is unmaintained and of
unknown fidelity for current hardware.  On TPU the situation is inverted:
the chip is a statically-scheduled VLIW machine with published peak numbers,
and DMA/MXU behaviour is deterministic enough that a two-pipeline latency
model is predictive.  We therefore provide BOTH feedback paths (documented
deviation, DESIGN.md §2):

* :func:`simulate` — a two-unit (memory pipe + compute pipe) in-order issue
  model over a :class:`~repro.core.ir.Program` schedule.  Memory ops are
  *asynchronous*: they occupy the memory pipe for their issue+transfer time
  and their results become available at completion; a compute op that reads a
  not-yet-ready value stalls.  Moving a load earlier (the paper's latency
  hiding, §2.3) therefore reduces simulated cycles exactly as it reduces
  wall time on the real machine.
* wall-clock measurement lives in :mod:`repro.core.energy`.

Hardware constants are TPU v5e (the assignment's target): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.ir import Kind, Program

# --- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link
VMEM_BYTES = 16 * 2 ** 20       # ~16 MiB lower bound of usable VMEM
VMEM_BW = 8 * HBM_BW            # VMEM is on-chip; ~an order faster than HBM
MXU_DIM = 128                   # systolic array edge
SUBLANE, LANE = 8, 128          # VREG tile geometry


@dataclasses.dataclass(frozen=True)
class Machine:
    """Latency parameters (seconds) for the two-pipe schedule simulator."""

    mem_issue: float = 30e-9            # fixed DMA issue overhead
    mem_bw: float = HBM_BW              # bytes/s for MEM instrs
    flops: float = PEAK_FLOPS_BF16      # FLOP/s for COMPUTE instrs
    compute_issue: float = 5e-9         # fixed per-op overhead (VLIW bundle)

    def mem_time(self, nbytes: int) -> float:
        return self.mem_issue + nbytes / self.mem_bw

    def compute_time(self, flops: int) -> float:
        return self.compute_issue + flops / self.flops


V5E = Machine()


def simulate(program: Program, order: Sequence[int] | None = None,
             machine: Machine = V5E) -> float:
    """Simulated execution time (seconds) of ``program`` under ``order``.

    STRICTLY IN-ORDER issue (the property the paper exploits, §2.3: since
    Kepler the hardware "obeys the compiler-generated instructions" — a
    stalled instruction blocks everything behind it; TPUs are statically
    scheduled VLIW, same property).  A MEM instruction occupies the front
    end only for its issue slot and completes asynchronously (LDGSTS / DMA
    semantics); a COMPUTE instruction stalls at issue until its inputs are
    ready, and that stall delays every later instruction.  Moving loads
    earlier in the schedule is therefore the only way to hide their latency.
    """
    if order is None:
        order = program.default_order()
    if not program.is_legal(order):
        raise ValueError("illegal schedule order")
    ready: dict[str, float] = {}          # value name -> time available
    cursor = 0.0                          # front-end: next issue time
    mem_free = 0.0                        # memory pipe next-free time
    comp_free = 0.0                       # compute pipe next-free time
    finish = 0.0
    for idx in order:
        ins = program.instrs[idx]
        deps_ready = max((ready.get(v, 0.0) for v in ins.inputs), default=0.0)
        if ins.kind is Kind.MEM:
            start = max(cursor, mem_free, deps_ready)
            mem_free = start + machine.mem_issue       # pipe frees after issue
            cursor = start + machine.mem_issue
            done = start + machine.mem_time(ins.bytes)  # data lands later
        else:
            start = max(cursor, comp_free, deps_ready)  # in-order stall
            dur = machine.compute_time(ins.flops)
            comp_free = start + dur
            cursor = start + machine.compute_issue
            done = start + dur
        for v in ins.outputs:
            ready[v] = done
        finish = max(finish, done)
    # grid cells execute back-to-back on a core; total scales with the
    # program's replication count (see ir.Program)
    return finish * program.replications


def roofline_time(flops: int, hbm_bytes: int, collective_bytes: int = 0,
                  chips: int = 1, links: int = 1) -> dict[str, float]:
    """The three roofline terms (seconds) used throughout EXPERIMENTS.md."""
    return {
        "compute_s": flops / (chips * PEAK_FLOPS_BF16),
        "memory_s": hbm_bytes / (chips * HBM_BW),
        "collective_s": collective_bytes / (chips * links * ICI_BW_PER_LINK),
    }


def dominant_term(terms: dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
