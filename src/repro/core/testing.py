"""Automatic probabilistic testing (paper §4.2).

Validation (theorem proving) is impossible for closed-semantics native code;
the paper instead draws random reference inputs, runs the *unmutated* kernel
to produce reference outputs, and rejects any mutated kernel whose outputs
mismatch.  We reproduce that contract: the oracle is the kernel's ``ref.py``
pure-jnp implementation (equivalently the unmutated kernel — tests assert the
two agree), inputs are drawn from the kernel's input specs, and a mismatch
anywhere in ``n_samples`` trials fails the candidate.

``FaultInjector`` supports the paper's Fig. 2 experiment (test samples vs
false positives): it wraps a correct kernel with a data-dependent fault that
only fires on rare inputs, so small sample counts let the broken kernel
through — exactly the false-positive mechanism the figure studies.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class InputSpec:
    shape: tuple[int, ...]
    dtype: Any = np.float32
    scale: float = 1.0

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        x = rng.standard_normal(self.shape).astype(np.float32) * self.scale
        return x.astype(self.dtype)


@dataclasses.dataclass
class TestReport:
    passed: bool
    samples_run: int
    first_failure: int | None = None
    max_err: float = 0.0


def probabilistic_test(candidate: Callable[..., Any],
                       oracle: Callable[..., Any],
                       specs: Sequence[InputSpec],
                       n_samples: int,
                       rng: np.random.Generator,
                       rtol: float = 2e-2,
                       atol: float = 2e-2,
                       batch: int = 16,
                       vectorize: str = "auto") -> TestReport:
    """Run up to ``n_samples`` random trials; stop at the first mismatch.

    All ``batch`` input sets of an outer iteration are drawn up front (in the
    same sample-major order one-at-a-time testing would draw them), stacked
    along a new leading axis, and evaluated together:

    * ``vectorize="vmap"`` — one ``jax.vmap`` call per batch for candidate and
      oracle (one dispatch for the whole batch; the win measured in
      ``benchmarks/search_throughput.py``);
    * ``vectorize="loop"`` — per-sample calls over the pre-drawn stack, for
      callables vmap cannot trace (numpy oracles, :class:`FaultInjector`);
    * ``vectorize="auto"`` (default) — try vmap once, fall back to loop for
      the rest of the call if it raises.

    Reported pass/fail, ``samples_run``, ``first_failure`` and ``max_err``
    are identical across modes and to one-at-a-time testing: comparisons run
    per sample in draw order and stop at the first mismatch.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if vectorize not in ("auto", "vmap", "loop"):
        raise ValueError(f"vectorize must be auto|vmap|loop, got {vectorize!r}")
    use_vmap = vectorize in ("auto", "vmap")
    vmapped: tuple[Callable, Callable] | None = None
    max_err = 0.0
    done = 0
    while done < n_samples:
        todo = min(batch, n_samples - done)
        draws = [[s.sample(rng) for s in specs] for _ in range(todo)]
        stacked = [np.stack([d[i] for d in draws]) for i in range(len(specs))]
        got = want = None
        if use_vmap:
            try:
                if vmapped is None:
                    import jax
                    vmapped = (jax.vmap(candidate), jax.vmap(oracle))
                got = np.asarray(vmapped[0](*stacked))
                want = np.asarray(vmapped[1](*stacked))
            except Exception:
                if vectorize == "vmap":
                    raise
                got = want = None          # candidate may have vmapped fine
                use_vmap = False           # auto: loop for the rest of the call
        if got is None:
            got = np.stack([np.asarray(candidate(*d)) for d in draws])
            want = np.stack([np.asarray(oracle(*d)) for d in draws])
        for j in range(todo):
            err = _rel_err(got[j], want[j])
            max_err = max(max_err, err)
            ok = np.allclose(got[j], want[j], rtol=rtol, atol=atol)
            done += 1
            if not ok:
                return TestReport(False, done, first_failure=done, max_err=max_err)
    return TestReport(True, done, max_err=max_err)


def _rel_err(got: np.ndarray, want: np.ndarray) -> float:
    denom = np.maximum(np.abs(want), 1e-6)
    return float(np.max(np.abs(got - want) / denom))


@dataclasses.dataclass
class FaultInjector:
    """Wrap ``fn`` with a fault that fires only when an input statistic
    exceeds ``threshold`` — a stand-in for a subtly-miscompiled schedule whose
    bug only manifests on rare data (Fig. 2's false-positive kernels).

    ``fire_prob`` is the per-sample probability that standard-normal inputs
    trip the threshold; it is determined by ``threshold`` and the input size.
    """

    fn: Callable[..., Any]
    threshold: float
    corruption: float = 1e-2

    def __call__(self, *args: Any) -> Any:
        out = np.asarray(self.fn(*args))
        stat = max(float(np.max(np.abs(np.asarray(a)))) for a in args)
        if stat > self.threshold:
            out = out + self.corruption * np.sign(out)   # silent corruption
        return out
