"""Automatic probabilistic testing (paper §4.2).

Validation (theorem proving) is impossible for closed-semantics native code;
the paper instead draws random reference inputs, runs the *unmutated* kernel
to produce reference outputs, and rejects any mutated kernel whose outputs
mismatch.  We reproduce that contract: the oracle is the kernel's ``ref.py``
pure-jnp implementation (equivalently the unmutated kernel — tests assert the
two agree), inputs are drawn from the kernel's input specs, and a mismatch
anywhere in ``n_samples`` trials fails the candidate.

``FaultInjector`` supports the paper's Fig. 2 experiment (test samples vs
false positives): it wraps a correct kernel with a data-dependent fault that
only fires on rare inputs, so small sample counts let the broken kernel
through — exactly the false-positive mechanism the figure studies.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class InputSpec:
    shape: tuple[int, ...]
    dtype: Any = np.float32
    scale: float = 1.0

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        x = rng.standard_normal(self.shape).astype(np.float32) * self.scale
        return x.astype(self.dtype)


@dataclasses.dataclass
class TestReport:
    passed: bool
    samples_run: int
    first_failure: int | None = None
    max_err: float = 0.0


def probabilistic_test(candidate: Callable[..., Any],
                       oracle: Callable[..., Any],
                       specs: Sequence[InputSpec],
                       n_samples: int,
                       rng: np.random.Generator,
                       rtol: float = 2e-2,
                       atol: float = 2e-2,
                       batch: int = 16) -> TestReport:
    """Run up to ``n_samples`` random trials; stop at the first mismatch.

    ``batch`` draws that many input sets per outer loop purely to amortize
    dispatch; semantics match one-at-a-time testing.
    """
    max_err = 0.0
    done = 0
    while done < n_samples:
        todo = min(batch, n_samples - done)
        for _ in range(todo):
            args = [s.sample(rng) for s in specs]
            got = np.asarray(candidate(*args))
            want = np.asarray(oracle(*args))
            err = _rel_err(got, want)
            max_err = max(max_err, err)
            ok = np.allclose(got, want, rtol=rtol, atol=atol)
            done += 1
            if not ok:
                return TestReport(False, done, first_failure=done, max_err=max_err)
    return TestReport(True, done, max_err=max_err)


def _rel_err(got: np.ndarray, want: np.ndarray) -> float:
    denom = np.maximum(np.abs(want), 1e-6)
    return float(np.max(np.abs(got - want) / denom))


@dataclasses.dataclass
class FaultInjector:
    """Wrap ``fn`` with a fault that fires only when an input statistic
    exceeds ``threshold`` — a stand-in for a subtly-miscompiled schedule whose
    bug only manifests on rare data (Fig. 2's false-positive kernels).

    ``fire_prob`` is the per-sample probability that standard-normal inputs
    trip the threshold; it is determined by ``threshold`` and the input size.
    """

    fn: Callable[..., Any]
    threshold: float
    corruption: float = 1e-2

    def __call__(self, *args: Any) -> Any:
        out = np.asarray(self.fn(*args))
        stat = max(float(np.max(np.abs(np.asarray(a)))) for a in args)
        if stat > self.threshold:
            out = out + self.corruption * np.sign(out)   # silent corruption
        return out
