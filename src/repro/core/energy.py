"""Feedback signal (paper §3.3).

Energy(x) is the (estimated or measured) runtime of schedule x.  The paper's
reward is ``R = (T_{i-1} - T_i) / T_0`` — positive when a mutation speeds the
kernel up.  The annealer works directly on energies; :func:`reward` is kept
for logging/parity with the paper.

Two energy backends:

* :class:`CostModelEnergy` — the two-pipe TPU latency simulator
  (:mod:`repro.core.costmodel`).  Deterministic, instant, and meaningful for
  the TPU target even inside this CPU-only container (DESIGN.md §2 records
  why this deviation from the paper is justified on TPU).
* :class:`WallClockEnergy` — compile-and-measure, the paper's choice.  Used
  for the paper-dynamics reproduction and wherever a real device exists.

A candidate that fails probabilistic testing gets energy = +inf (the paper's
"0 feedback signal" — the schedule can never be accepted as an improvement).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import numpy as np

from repro.core import costmodel
from repro.core.ir import Program
from repro.core.schedule import Schedule

FAILED = float("inf")


def reward(t_prev: float, t_cur: float, t0: float) -> float:
    """Paper Eq. (1): R = (T_{i-1} - T_i) / T_0."""
    if not np.isfinite(t_cur):
        return 0.0          # §4.2: failed test => 0 feedback
    return (t_prev - t_cur) / t0


@dataclasses.dataclass
class CostModelEnergy:
    """Energy from the analytic schedule simulator."""

    program_for: Callable[[Schedule], Program]
    machine: costmodel.Machine = costmodel.V5E

    def __call__(self, schedule: Schedule) -> float:
        program = self.program_for(schedule)
        return costmodel.simulate(program, schedule.resolve_order(program), self.machine)


@dataclasses.dataclass
class WallClockEnergy:
    """Energy from measured execution (CUDA-events analogue: timed jit calls).

    ``build(schedule)`` returns a callable taking ``*args``; ``make_args()``
    returns the positional inputs.  We warm up (compile + cache) then take the
    median of ``iters`` timed calls, blocking on the result.
    """

    build: Callable[[Schedule], Callable[..., Any]]
    make_args: Callable[[], Sequence[Any]]
    warmup: int = 2
    iters: int = 5

    def __call__(self, schedule: Schedule) -> float:
        try:
            fn = self.build(schedule)
            args = self.make_args()
            for _ in range(self.warmup):
                _block(fn(*args))
            times = []
            for _ in range(self.iters):
                t0 = time.perf_counter()
                out = fn(*args)
                _block(out)
                times.append(time.perf_counter() - t0)
            return float(np.median(times))
        except Exception:
            return FAILED   # unassemblable schedule (paper: cuasm failure)


def _block(out: Any) -> None:
    for leaf in _leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def _leaves(x: Any):
    if isinstance(x, (list, tuple)):
        for v in x:
            yield from _leaves(v)
    elif isinstance(x, dict):
        for v in x.values():
            yield from _leaves(v)
    else:
        yield x


class CachedEnergy:
    """Memoizing energy wrapper keyed on ``Schedule.signature()``.

    The SIP hot loop re-evaluates schedules constantly — Metropolis rejections
    re-propose from the same state, reverted moves regenerate earlier
    candidates, and every chain of a population search starts from the same
    x0.  Wrapping the (deterministic) energy makes all revisits free; the
    hit/miss counters are surfaced in ``AnnealResult.cache_stats`` /
    ``PopulationResult.cache_stats``.

    Share ONE instance across chains and rounds: the cache is exactly as
    deterministic as the wrapped energy.  Wrapping a stochastic energy
    freezes its first observation per schedule — for :class:`WallClockEnergy`
    a hit returns the first measurement instead of re-timing, and for
    :class:`GuardedEnergy` the probabilistic step-test verdict is drawn once
    per schedule rather than per revisit — trading noise re-sampling for
    throughput.  Callers that need a fresh verdict per visit (or a heavier
    final gate, as ``SipKernel.tune`` runs before caching) must arrange it
    outside the wrapper.
    """

    def __init__(self, energy: Callable[[Schedule], float],
                 maxsize: int | None = None):
        self.energy = energy
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._memo: dict[str, float] = {}

    def __call__(self, schedule: Schedule) -> float:
        key = schedule.signature()
        cached = self._memo.get(key)
        if cached is not None:
            self.hits += 1
            return cached
        self.misses += 1
        e = self.energy(schedule)
        if self.maxsize is not None and len(self._memo) >= self.maxsize:
            self._memo.pop(next(iter(self._memo)))   # FIFO bound
        self._memo[key] = e
        return e

    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._memo)}

    def reset_stats(self) -> None:
        """Zero the hit/miss counters (the memo itself is kept).

        ``SipKernel.tune`` calls this between rounds so each round's
        ``cache_stats`` is attributable to that round alone."""
        self.hits = 0
        self.misses = 0


def delta_stats(before: dict[str, int] | None,
                after: dict[str, int]) -> dict[str, float]:
    """Per-window cache stats: counter deltas plus the derived hit ratio.

    This is what lands in ``AnnealResult.cache_stats`` — callers get
    ``hit_rate`` (0.0 when the window saw no lookups) instead of having to
    re-derive it from raw hits/misses."""
    before = before or {}
    d: dict[str, float] = {k: after[k] - before.get(k, 0) for k in after}
    total = d.get("hits", 0) + d.get("misses", 0)
    d["hit_rate"] = d.get("hits", 0) / total if total > 0 else 0.0
    return d


@dataclasses.dataclass
class GuardedEnergy:
    """Energy guarded by probabilistic testing (paper §4.2).

    The test gate runs BEFORE timing: an incorrect kernel gets FAILED energy
    and thus zero reward, exactly as in the paper.
    """

    energy: Callable[[Schedule], float]
    test: Callable[[Schedule], bool]

    def __call__(self, schedule: Schedule) -> float:
        if not self.test(schedule):
            return FAILED
        return self.energy(schedule)
