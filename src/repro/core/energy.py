"""Feedback signal (paper §3.3).

Energy(x) is the (estimated or measured) runtime of schedule x.  The paper's
reward is ``R = (T_{i-1} - T_i) / T_0`` — positive when a mutation speeds the
kernel up.  The annealer works directly on energies; :func:`reward` is kept
for logging/parity with the paper.

Two energy backends:

* :class:`CostModelEnergy` — the two-pipe TPU latency simulator
  (:mod:`repro.core.costmodel`).  Deterministic, instant, and meaningful for
  the TPU target even inside this CPU-only container (DESIGN.md §2 records
  why this deviation from the paper is justified on TPU).
* :class:`WallClockEnergy` — compile-and-measure, the paper's choice.  Used
  for the paper-dynamics reproduction and wherever a real device exists.

A candidate that fails probabilistic testing gets energy = +inf (the paper's
"0 feedback signal" — the schedule can never be accepted as an improvement).
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import time
from typing import Any, Callable, MutableSet, Sequence

import numpy as np

from repro.core import costmodel
from repro.core.ir import Program
from repro.core.schedule import Schedule
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

FAILED = float("inf")


def reward(t_prev: float, t_cur: float, t0: float) -> float:
    """Paper Eq. (1): R = (T_{i-1} - T_i) / T_0."""
    if not np.isfinite(t_cur):
        return 0.0          # §4.2: failed test => 0 feedback
    return (t_prev - t_cur) / t0


@dataclasses.dataclass
class CostModelEnergy:
    """Energy from the analytic schedule simulator."""

    program_for: Callable[[Schedule], Program]
    machine: costmodel.Machine = costmodel.V5E

    def __call__(self, schedule: Schedule) -> float:
        program = self.program_for(schedule)
        return costmodel.simulate(program, schedule.resolve_order(program), self.machine)


@dataclasses.dataclass
class WallClockEnergy:
    """Energy from measured execution (CUDA-events analogue: timed jit calls).

    ``build(schedule)`` returns a callable taking ``*args``; ``make_args()``
    returns the positional inputs.  We warm up (compile + cache) then take the
    median of ``iters`` timed calls, blocking on the result.
    """

    build: Callable[[Schedule], Callable[..., Any]]
    make_args: Callable[[], Sequence[Any]]
    warmup: int = 2
    iters: int = 5

    def __call__(self, schedule: Schedule) -> float:
        try:
            fn = self.build(schedule)
            args = self.make_args()
            for _ in range(self.warmup):
                _block(fn(*args))
            times = []
            for _ in range(self.iters):
                t0 = time.perf_counter()
                out = fn(*args)
                _block(out)
                times.append(time.perf_counter() - t0)
            return float(np.median(times))
        except Exception:
            return FAILED   # unassemblable schedule (paper: cuasm failure)


def _block(out: Any) -> None:
    for leaf in _leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def _leaves(x: Any):
    if isinstance(x, (list, tuple)):
        for v in x:
            yield from _leaves(v)
    elif isinstance(x, dict):
        for v in x.values():
            yield from _leaves(v)
    else:
        yield x


class CachedEnergy:
    """Memoizing energy wrapper keyed on ``Schedule.signature()``.

    The SIP hot loop re-evaluates schedules constantly — Metropolis rejections
    re-propose from the same state, reverted moves regenerate earlier
    candidates, and every chain of a population search starts from the same
    x0.  Wrapping the (deterministic) energy makes all revisits free; the
    hit/miss counters are surfaced in ``AnnealResult.cache_stats`` /
    ``PopulationResult.cache_stats``.

    Share ONE instance across chains and rounds: the cache is exactly as
    deterministic as the wrapped energy.  Wrapping a stochastic energy
    freezes its first observation per schedule — for :class:`WallClockEnergy`
    a hit returns the first measurement instead of re-timing, and for
    :class:`GuardedEnergy` the probabilistic step-test verdict is drawn once
    per schedule rather than per revisit — trading noise re-sampling for
    throughput.  Callers that need a fresh verdict per visit (or a heavier
    final gate, as ``SipKernel.tune`` runs before caching) must arrange it
    outside the wrapper.
    """

    def __init__(self, energy: Callable[[Schedule], float],
                 maxsize: int | None = None):
        self.energy = energy
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._memo: dict[str, float] = {}

    def __call__(self, schedule: Schedule) -> float:
        key = schedule.signature()
        cached = self._memo.get(key)
        if cached is not None:
            self.hits += 1
            return cached
        self.misses += 1
        e = self.energy(schedule)
        if self.maxsize is not None and len(self._memo) >= self.maxsize:
            self._memo.pop(next(iter(self._memo)))   # FIFO bound
        self._memo[key] = e
        return e

    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._memo)}

    def reset_stats(self) -> None:
        """Zero the hit/miss counters (the memo itself is kept).

        ``SipKernel.tune`` calls this between rounds so each round's
        ``cache_stats`` is attributable to that round alone."""
        self.hits = 0
        self.misses = 0


def delta_stats(before: dict[str, int] | None,
                after: dict[str, int]) -> dict[str, float]:
    """Per-window cache stats: counter deltas plus the derived hit ratio.

    This is what lands in ``AnnealResult.cache_stats`` — callers get
    ``hit_rate`` (0.0 when the window saw no lookups) instead of having to
    re-derive it from raw hits/misses."""
    before = before or {}
    d: dict[str, float] = {k: after[k] - before.get(k, 0) for k in after}
    total = d.get("hits", 0) + d.get("misses", 0)
    d["hit_rate"] = d.get("hits", 0) / total if total > 0 else 0.0
    return d


class QuarantineEnergy:
    """Deadline + crash quarantine around an energy callable (crash-safe
    search).

    SIP's premise is that perturbed schedules are frequently invalid — a
    candidate can fail tests (handled by :class:`GuardedEnergy`), but it can
    also CRASH the evaluator or wedge it forever (a pathological compile, an
    interpreter loop).  This wrapper makes both non-fatal: the evaluation
    runs on a worker thread under ``deadline_s``; a candidate that raises or
    exceeds the deadline is added to ``quarantine`` (by schedule signature),
    scored ``FAILED``, and never evaluated again.  A wedged worker thread is
    abandoned (daemon) and a fresh one serves the next call, so one stuck
    schedule costs one deadline, not the session.

    ``quarantine`` may be a caller-owned set — ``TuningSession`` persists it
    in the search-state journal so a ``--resume`` skips known-bad schedules
    without re-paying their deadline.
    """

    def __init__(self, energy: Callable[[Schedule], float], *,
                 deadline_s: float | None = None,
                 quarantine: MutableSet[str] | None = None,
                 on_quarantine: Callable[[str, str], None] | None = None):
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        self.energy = energy
        self.deadline_s = deadline_s
        self.quarantine = quarantine if quarantine is not None else set()
        self.on_quarantine = on_quarantine
        self.timeouts = 0
        self.crashes = 0
        self.skips = 0                  # calls answered from the quarantine
        self._pool: concurrent.futures.ThreadPoolExecutor | None = None

    def _evaluate(self, schedule: Schedule) -> float:
        if self.deadline_s is None:
            return self.energy(schedule)
        if self._pool is None:
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="sip-eval")
        fut = self._pool.submit(self.energy, schedule)
        try:
            return fut.result(timeout=self.deadline_s)
        except concurrent.futures.TimeoutError:
            fut.cancel()
            # the worker may be wedged for good — abandon the pool (daemon
            # threads don't block exit) and lazily build a fresh one
            self._pool.shutdown(wait=False)
            self._pool = None
            raise TimeoutError(
                f"energy evaluation exceeded {self.deadline_s}s deadline")

    def __call__(self, schedule: Schedule) -> float:
        sig = schedule.signature()
        if sig in self.quarantine:
            self.skips += 1
            return FAILED
        try:
            return self._evaluate(schedule)
        except Exception as e:
            if isinstance(e, TimeoutError):
                self.timeouts += 1
            else:
                self.crashes += 1
            self.quarantine.add(sig)
            obs_metrics.active_registry().counter("ft.quarantined").inc()
            obs_trace.instant("ft.quarantine", kind=type(e).__name__,
                              detail=str(e)[:200])
            if self.on_quarantine is not None:
                self.on_quarantine(sig, f"{type(e).__name__}: {e}")
            return FAILED

    def quarantine_stats(self) -> dict[str, int]:
        return {"timeouts": self.timeouts, "crashes": self.crashes,
                "skips": self.skips, "quarantined": len(self.quarantine)}


@dataclasses.dataclass
class GuardedEnergy:
    """Energy guarded by probabilistic testing (paper §4.2).

    The test gate runs BEFORE timing: an incorrect kernel gets FAILED energy
    and thus zero reward, exactly as in the paper.
    """

    energy: Callable[[Schedule], float]
    test: Callable[[Schedule], bool]

    def __call__(self, schedule: Schedule) -> float:
        if not self.test(schedule):
            return FAILED
        return self.energy(schedule)
