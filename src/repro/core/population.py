"""Population-based annealing / parallel tempering (beyond-paper, §6).

The paper's Alg. 1 is one sequential chain; its §6 names inefficient search
as the main limitation.  This module runs K chains in lockstep on a
*temperature ladder* — chain ``c`` cools from ``t_max * ladder**c``, so hot
chains explore while cold chains exploit — with periodic best-state
exchange: every ``exchange_every`` lockstep rounds the chain whose *current*
state is worst adopts the current state of the chain whose state is best
(elitist migration).  Acceptance stays Metropolis per chain, so single-chain
dynamics are untouched.

Guarantees:

* ``chains=1`` is bit-identical to :func:`repro.core.annealing.anneal` under
  the same seed — the step logic is the shared :class:`~repro.core.annealing.Chain`,
  the ladder factor is ``ladder**0 == 1`` and exchange never fires.
* Chain ``c`` uses ``seed + c``, so population runs are fully deterministic.

All chains share one energy callable; wrap it (or let ``memoize=True`` wrap
it) in :class:`~repro.core.energy.CachedEnergy` so the K initial states and
every revisited/reverted schedule cost one evaluation total across the
population — the shared-state half of the throughput win measured in
``benchmarks/search_throughput.py``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.core.annealing import AnnealResult, AnnealStep, Chain
from repro.core.energy import CachedEnergy, delta_stats
from repro.core.schedule import Schedule
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class PopulationResult:
    """Per-chain results plus population-level accounting."""

    chains: list[AnnealResult]
    exchanges: int                           # state migrations that occurred
    cache_stats: dict[str, float] | None = None  # aggregate across chains,
    #                                              incl. derived hit_rate

    @property
    def best_index(self) -> int:
        return min(range(len(self.chains)),
                   key=lambda i: self.chains[i].best_energy)

    def best_result(self) -> AnnealResult:
        """The winning chain's result, annotated with population cache stats."""
        res = self.chains[self.best_index]
        return dataclasses.replace(res, cache_stats=self.cache_stats)

    @property
    def best(self) -> Schedule:
        return self.chains[self.best_index].best

    @property
    def best_energy(self) -> float:
        return self.chains[self.best_index].best_energy

    @property
    def best_raw(self) -> float:
        return self.chains[self.best_index].best_raw

    @property
    def initial_raw(self) -> float:
        return self.chains[0].initial_raw

    @property
    def evals(self) -> int:
        """Total energy queries across the population (cache hits included)."""
        return sum(c.evals for c in self.chains)

    @property
    def improvement(self) -> float:
        return self.best_result().improvement


def population_anneal(
        x0: Schedule,
        energy: Callable[[Schedule], float],
        perturb: Callable[[Schedule, np.random.Generator], Schedule | None],
        *,
        chains: int = 4,
        t_max: float = 1.0,
        t_min: float = 1e-3,
        cooling: float = 1.05,
        ladder: float = 1.5,                # T_max ratio between rungs
        exchange_every: int = 16,           # lockstep rounds between migrations
        seed: int = 0,
        memoize: bool = True,
        on_step: Callable[[AnnealStep], None] | None = None) -> PopulationResult:
    """Run ``chains`` lockstep annealing chains with best-state exchange.

    ``memoize=True`` wraps ``energy`` in a shared :class:`CachedEnergy`
    unless it already exposes ``stats()`` (i.e. is one).  With a
    deterministic energy this never changes search results, only cost.
    """
    if chains < 1:
        raise ValueError(f"chains must be >= 1, got {chains}")
    if ladder < 1.0:
        raise ValueError(f"ladder must be >= 1 (rung c starts at "
                         f"t_max*ladder**c), got {ladder}")
    if memoize and not callable(getattr(energy, "stats", None)):
        energy = CachedEnergy(energy)
    stats = getattr(energy, "stats", None)
    before = stats() if callable(stats) else None

    pool = [Chain(x0, energy, perturb,
                  t_max=t_max * ladder ** c, t_min=t_min,
                  cooling=cooling, seed=seed + c, on_step=on_step,
                  label=f"chain{c}")
            for c in range(chains)]
    exchanges = 0
    lockstep = 0
    m_exchanges = obs_metrics.active_registry().counter("search.exchanges")
    while any(not c.done for c in pool):
        for c in pool:
            if not c.done:
                c.advance()
        lockstep += 1
        if chains > 1 and exchange_every > 0 and lockstep % exchange_every == 0:
            moved = _exchange(pool)
            exchanges += moved
            if moved:
                m_exchanges.inc(moved)
                obs_trace.instant("search.exchange", lockstep=lockstep,
                                  exchanges=exchanges)

    result = PopulationResult(chains=[c.result() for c in pool],
                              exchanges=exchanges)
    if before is not None:
        result.cache_stats = delta_stats(before, stats())
    return result


def _exchange(pool: list[Chain]) -> int:
    """Elitist migration: worst live chain adopts the best live state.

    Returns the number of migrations performed (0 or 1).  Only chains still
    cooling participate — a finished chain's state is frozen.  Infinite
    (test-failing) current states always lose ties, so the migration can
    rescue a chain stranded on a rejected schedule.
    """
    live = [c for c in pool if not c.done]
    if len(live) < 2:
        return 0
    lo = min(live, key=lambda c: c.e_x)
    hi = max(live, key=lambda c: c.e_x)
    if lo is hi or not math.isfinite(lo.e_x) or hi.e_x <= lo.e_x:
        return 0
    hi.adopt(lo.x, lo.e_x)
    return 1
