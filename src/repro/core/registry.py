"""Declarative kernel registry — the paper's one-line integration surface.

§4.1 / Listing 2 promise *declarative* adoption: decorate the kernel, and SIP
handles interception, offline search, and cached deployment.  This module is
that surface for the repro:

* :class:`KernelSpec` — everything SIP needs to tune and deploy one kernel
  (build / program_for / space_for / oracle / signature_fn), plus the
  kernel's own :class:`Workload` declarations (deployment shapes), so the
  offline driver needs zero per-kernel code.
* :func:`sip_kernel` — registration decorator over the ``build`` factory.
* :class:`KernelRegistry` / :data:`registry` — name -> spec, with memoized
  ``SipKernel`` instances per (name, schedule-cache) so model code resolves
  ONE shared kernel object instead of constructing fresh instances (and
  fresh build caches) per call.
* :func:`schedule_cache` — contextvar-scoped active :class:`ScheduleCache`
  (mirroring ``dist.mesh_rules``): training/serving wrap their region in
  ``with schedule_cache(path):`` and every ``registry.get`` inside resolves
  tuned schedules from that store.

Deterministic seeding: :func:`workload_seed` derives a stable per
(kernel, workload) seed so tuning a subset of kernels — or reordering them —
never changes another kernel's inputs or search trajectory.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import hashlib
import os
import threading
from typing import Any, Callable, Iterator, Sequence

import numpy as np

from repro.core.cache import LRUCache, ScheduleCache
from repro.core.ir import Program
from repro.core.jit import SipKernel
from repro.core.schedule import SearchSpace


def workload_seed(kernel_name: str, workload_name: str, base: int = 0) -> int:
    """Stable seed for one (kernel, workload) pair.

    Hash-derived (not position-derived), so results are independent of which
    other kernels are tuned and in what order; ``base`` folds in the session
    seed so distinct sessions still decorrelate.
    """
    digest = hashlib.sha256(
        f"{kernel_name}::{workload_name}::{base}".encode()).digest()
    return int.from_bytes(digest[:4], "little")


@dataclasses.dataclass(frozen=True)
class Workload:
    """One deployment shape, declared next to the kernel that owns it.

    ``make_args(rng)`` returns the example argument list ``SipKernel.tune``
    consumes; ``suites`` tags which tuning suites include it ("default" for
    real deployment shapes, "smoke" for the tiny CI shapes every kernel must
    provide).
    """

    name: str
    make_args: Callable[[np.random.Generator], Sequence[Any]]
    suites: tuple[str, ...] = ("default",)


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Declarative description of one SIP-tunable kernel.

    The six callables are exactly ``SipKernel``'s constructor surface; the
    spec adds the kernel's workload declarations and is what lives in the
    registry (instances are materialized lazily per schedule cache).
    """

    name: str
    build: Callable[..., Callable[..., Any]]
    program_for: Callable[..., Program]
    space_for: Callable[..., SearchSpace]
    oracle: Callable[..., Any]
    signature_fn: Callable[..., dict[str, Any]]
    workloads: tuple[Workload, ...] = ()
    module: str = ""               # filled by register(); package provenance
    owner: "KernelRegistry | None" = dataclasses.field(
        default=None, repr=False, compare=False)  # filled by register()

    def instantiate(self, cache: ScheduleCache | None = None) -> SipKernel:
        """A fresh (unshared) SipKernel — the pre-registry construction path,
        kept for deprecation shims and bit-equivalence tests."""
        return SipKernel(name=self.name, build=self.build,
                         program_for=self.program_for,
                         space_for=self.space_for, oracle=self.oracle,
                         signature_fn=self.signature_fn, cache=cache)

    def workloads_in(self, suite: str) -> tuple[Workload, ...]:
        return tuple(w for w in self.workloads if suite in w.suites)

    def __call__(self, *args: Any) -> Any:
        """Deployment path: dispatch through the owning registry's shared
        instance for the active schedule cache."""
        return (self.owner if self.owner is not None else registry) \
            .get(self.name)(*args)


# ----------------------------------------------------------- active cache
# contextvar (not a module global), mirroring dist.partition.mesh_rules:
# concurrent scopes in different threads/tasks must not see each other's
# cache.
_ACTIVE_CACHE: contextvars.ContextVar[tuple[ScheduleCache, ...]] = \
    contextvars.ContextVar("repro_schedule_cache", default=())

# path -> ScheduleCache, so re-entering `schedule_cache(path)` (e.g. a server
# wrapping every request) resolves the SAME store object — and therefore the
# same memoized kernel instances — instead of re-reading the JSON and minting
# a fresh instance per scope.  Bounded by the number of distinct paths used.
_PATH_CACHES: dict[str, ScheduleCache] = {}
_PATH_LOCK = threading.Lock()


def cache_for_path(path: str) -> ScheduleCache:
    """The process-wide ScheduleCache for ``path`` (interned by abspath)."""
    key = os.path.abspath(path)
    with _PATH_LOCK:
        inst = _PATH_CACHES.get(key)
        if inst is None:
            # construct with the interned key, not the raw path: a relative
            # path would flush wherever the cwd happens to be at flush time
            inst = _PATH_CACHES[key] = ScheduleCache(key)
    return inst


@contextlib.contextmanager
def schedule_cache(cache: ScheduleCache | str) -> Iterator[ScheduleCache]:
    """Activate ``cache`` (an instance or a path) for a region of code.

    ``registry.get`` calls inside the region bind kernel instances to this
    store, so models/serving resolve tuned schedules without threading a
    cache argument through every layer.  Reentrant; innermost wins.  Paths
    are interned (``cache_for_path``), so repeated scopes over the same file
    share one store and one set of kernel instances.
    """
    if isinstance(cache, str):
        cache = cache_for_path(cache)
    token = _ACTIVE_CACHE.set(_ACTIVE_CACHE.get() + (cache,))
    try:
        yield cache
    finally:
        _ACTIVE_CACHE.reset(token)


def active_schedule_cache() -> ScheduleCache | None:
    """The innermost ``schedule_cache`` scope's store, or None."""
    stack = _ACTIVE_CACHE.get()
    return stack[-1] if stack else None


# ---------------------------------------------------------------- registry
class KernelRegistry:
    """Name -> KernelSpec, with shared SipKernel instances per cache."""

    def __init__(self):
        self._lock = threading.Lock()
        self._specs: dict[str, KernelSpec] = {}
        # bounded: each entry pins a SipKernel plus its compiled-build
        # caches AND its ScheduleCache, so an unbounded dict would grow
        # monotonically in a process that keeps opening fresh instance-form
        # caches; LRU eviction drops the pin (a later get re-instantiates)
        self._instances: LRUCache = LRUCache(maxsize=64)
        # the shared in-memory store used when no schedule_cache is active
        self._default_cache = ScheduleCache()

    # ------------------------------------------------------------- specs
    def register(self, spec: KernelSpec) -> KernelSpec:
        if not spec.module:
            spec = dataclasses.replace(
                spec, module=getattr(spec.build, "__module__", "") or "")
        if spec.owner is not self:
            spec = dataclasses.replace(spec, owner=self)
        with self._lock:
            if spec.name in self._specs:
                raise ValueError(
                    f"kernel {spec.name!r} is already registered "
                    f"(by {self._specs[spec.name].module or 'unknown'}); "
                    f"kernel names must be unique")
            self._specs[spec.name] = spec
        return spec

    def spec(self, name: str) -> KernelSpec:
        try:
            return self._specs[name]
        except KeyError:
            known = ", ".join(self.names()) or \
                "(none — import repro.kernels and call load_all())"
            raise KeyError(f"unknown kernel {name!r}; registered kernels: "
                           f"{known}") from None

    def names(self) -> list[str]:
        return sorted(self._specs)

    def specs(self) -> list[KernelSpec]:
        return [self._specs[n] for n in self.names()]

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __len__(self) -> int:
        return len(self._specs)

    def __iter__(self) -> Iterator[str]:
        return iter(self.names())

    # --------------------------------------------------------- instances
    def get(self, name: str, cache: ScheduleCache | None = None) -> SipKernel:
        """The shared SipKernel for ``name``, bound to ``cache`` (explicit >
        active ``schedule_cache`` scope > registry default).

        Memoized: repeated resolution — e.g. the model's attention path on
        every trace — returns ONE kernel object, preserving its build/resolve
        caches.  (The instance holds a strong reference to its cache, so the
        ``id``-based key cannot alias a collected store.)
        """
        spec = self.spec(name)
        if cache is None:
            cache = active_schedule_cache() or self._default_cache
        key = (name, id(cache))
        with self._lock:
            return self._instances.get_or_build(
                key, lambda: spec.instantiate(cache=cache))

    def instance_count(self) -> int:
        return len(self._instances)


class KernelHandle:
    """Late-binding module-level handle for a registered kernel.

    ``registry.get`` honors the ACTIVE ``schedule_cache`` scope, so a handle
    exported at module top (``gemm_leaky_relu = KernelHandle(NAME)``) must
    not freeze the instance that happened to be current at import time —
    every call/attribute access re-resolves the shared instance for the
    scope in effect *now*.
    """

    __slots__ = ("_name",)

    def __init__(self, name: str):
        self._name = name

    def __call__(self, *args: Any) -> Any:
        return registry.get(self._name)(*args)

    def __getattr__(self, attr: str) -> Any:
        return getattr(registry.get(self._name), attr)

    def __repr__(self) -> str:
        return f"<registry kernel {self._name!r}>"


def sip_kernel(*, name: str,
               program_for: Callable[..., Program],
               space_for: Callable[..., SearchSpace],
               oracle: Callable[..., Any],
               signature_fn: Callable[..., dict[str, Any]],
               workloads: Sequence[Workload] = (),
               registry_: KernelRegistry | None = None,
               ) -> Callable[[Callable[..., Any]], KernelSpec]:
    """Registration decorator over the kernel's ``build`` factory::

        @sip_kernel(name="my_kernel", program_for=program_for,
                    space_for=space, oracle=ref.my_kernel,
                    signature_fn=signature_fn,
                    workloads=[Workload("smoke", make_args, suites=("smoke",))])
        def build(schedule, **static): ...

    Returns the registered :class:`KernelSpec`; calling it dispatches through
    the registry's shared instance for the active schedule cache.
    """

    def wrap(build: Callable[..., Any]) -> KernelSpec:
        spec = KernelSpec(name=name, build=build, program_for=program_for,
                          space_for=space_for, oracle=oracle,
                          signature_fn=signature_fn,
                          workloads=tuple(workloads))
        # explicit None check: an empty KernelRegistry is falsy (__len__)
        target = registry if registry_ is None else registry_
        return target.register(spec)

    return wrap


#: process-wide registry; kernel modules register into it at import time
#: (``repro.kernels.load_all()`` imports every kernel package's integration
#: module).
registry = KernelRegistry()
