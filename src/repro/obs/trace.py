"""Structured event tracer with nested spans and a Chrome-trace exporter.

A :class:`Tracer` collects timestamped events — complete spans (``ph: "X"``),
instants (``"I"``) and counter samples (``"C"``) — in the Chrome Trace Event
format, so a tune or serve run saved with ``tracer.save("run.json")`` opens
directly in ``ui.perfetto.dev`` / ``chrome://tracing``: spans nest by time
containment per (pid, tid) track, counter tracks plot energy-vs-step or
queue depth over the run.

Event collection is thread-safe (the serve engine emits from its streaming
callback thread); an optional streaming JSONL sink writes each event as one
JSON line the moment it is recorded, so a crashed run still leaves a
readable trace.  ``save`` writes either the Chrome JSON object
(``{"traceEvents": [...]}``, for ``.json`` paths) or JSONL (one event per
line, anything else); :func:`load_trace` and :func:`validate_events` read
and schema-check both forms (``launch/obsreport.py --validate``).

Scoping mirrors :mod:`repro.obs.metrics`: ``with tracing(tracer):`` pushes
the tracer onto a contextvar stack; instrumented code calls the module-level
:func:`span` / :func:`instant` / :func:`counter` helpers, which are cheap
no-ops when no tracer is active — tracing disabled must stay off the serve
hot path.
"""

from __future__ import annotations

import contextlib
import contextvars
import json
import os
import threading
import time
from typing import Any, Iterator

#: event fields beyond these are rejected by the validator
_EVENT_KEYS = {"name", "ph", "ts", "dur", "pid", "tid", "args", "s", "cat"}
_PHASES = {"X", "I", "C", "M"}


class Tracer:
    """Collects Chrome-trace events; see module docstring."""

    def __init__(self, jsonl_path: str | None = None, *,
                 pid: int | None = None):
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._pid = os.getpid() if pid is None else pid
        self._file = open(jsonl_path, "w") if jsonl_path else None

    # ------------------------------------------------------------ recording
    def _now_us(self) -> float:
        return round((time.perf_counter() - self._t0) * 1e6, 3)

    def _emit(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)
            if self._file is not None:
                self._file.write(json.dumps(ev) + "\n")

    @contextlib.contextmanager
    def span(self, name: str, **args: Any) -> Iterator[dict]:
        """Complete-event span: emitted on exit with the measured duration.

        Yields the mutable ``args`` dict so the body can attach results
        (``s["tokens"] = n``) that land in the recorded event.
        """
        t0 = self._now_us()
        try:
            yield args
        finally:
            t1 = self._now_us()
            self._emit({"name": name, "ph": "X", "ts": t0,
                        "dur": round(t1 - t0, 3), "pid": self._pid,
                        "tid": threading.get_ident(),
                        "args": _jsonable(args)})

    def instant(self, name: str, **args: Any) -> None:
        self._emit({"name": name, "ph": "I", "ts": self._now_us(), "s": "t",
                    "pid": self._pid, "tid": threading.get_ident(),
                    "args": _jsonable(args)})

    def counter(self, name: str, values: dict[str, float]) -> None:
        """One sample on the counter track ``name`` (plots as a time series)."""
        self._emit({"name": name, "ph": "C", "ts": self._now_us(),
                    "pid": self._pid, "tid": 0, "args": _jsonable(values)})

    # -------------------------------------------------------------- export
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def to_chrome(self) -> dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        """Chrome JSON for ``.json`` paths, JSONL otherwise."""
        if path.endswith(".json"):
            with open(path, "w") as f:
                json.dump(self.to_chrome(), f)
        else:
            with open(path, "w") as f:
                for ev in self.events():
                    f.write(json.dumps(ev) + "\n")

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None


def _jsonable(d: dict[str, Any]) -> dict[str, Any]:
    out = {}
    for k, v in d.items():
        if hasattr(v, "item"):           # numpy scalar
            v = v.item()
        if isinstance(v, float) and not (v == v and abs(v) != float("inf")):
            out[k] = repr(v)             # inf/NaN would break strict JSON
        elif isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        else:
            out[k] = repr(v)
    return out


# --------------------------------------------------------------- scoping
_ACTIVE: contextvars.ContextVar[tuple[Tracer, ...]] = \
    contextvars.ContextVar("repro_tracer", default=())


@contextlib.contextmanager
def tracing(tracer: Tracer | None = None) -> Iterator[Tracer]:
    """Activate ``tracer`` (a fresh one when None) for a region of code."""
    tracer = Tracer() if tracer is None else tracer
    token = _ACTIVE.set(_ACTIVE.get() + (tracer,))
    try:
        yield tracer
    finally:
        _ACTIVE.reset(token)


def active_tracer() -> Tracer | None:
    """The innermost ``tracing`` scope's tracer, or None (tracing off)."""
    stack = _ACTIVE.get()
    return stack[-1] if stack else None


@contextlib.contextmanager
def span(name: str, **args: Any) -> Iterator[dict]:
    """Module-level span helper: records on the active tracer, no-op
    (yielding a throwaway dict the body may still write to) when tracing
    is off."""
    t = active_tracer()
    if t is None:
        yield args
    else:
        with t.span(name, **args) as s:
            yield s


def instant(name: str, **args: Any) -> None:
    t = active_tracer()
    if t is not None:
        t.instant(name, **args)


def counter(name: str, values: dict[str, float]) -> None:
    t = active_tracer()
    if t is not None:
        t.counter(name, values)


# ------------------------------------------------------- load + validation
def load_trace(path: str) -> list[dict]:
    """Events from a Chrome JSON object, a bare JSON array, or JSONL."""
    with open(path) as f:
        text = f.read()
    try:
        loaded = json.loads(text)
    except json.JSONDecodeError:
        # JSONL: every line is its own object (including single-event files,
        # which also parse above — either way the events come out the same)
        return [json.loads(line) for line in text.splitlines() if line.strip()]
    if isinstance(loaded, dict):
        events = loaded.get("traceEvents")
        if isinstance(events, list):
            return events
        if "ph" in loaded:                       # one-line JSONL file
            return [loaded]
        raise ValueError(f"{path}: JSON object without a "
                         f"'traceEvents' list")
    if isinstance(loaded, list):
        return loaded
    return [loaded]


def validate_events(events: list[dict]) -> list[str]:
    """Schema + nesting errors for a trace (empty list == valid).

    Checks each event's shape (known phase, finite non-negative ts/dur,
    required ids) and that "X" spans on each (pid, tid) track nest properly
    by time containment — a child must end no later than its parent, which
    is exactly what Perfetto assumes when it stacks them.
    """
    errors: list[str] = []
    spans: dict[tuple, list[tuple[float, float, str]]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object: {ev!r}")
            continue
        extra = set(ev) - _EVENT_KEYS
        if extra:
            errors.append(f"event {i}: unknown fields {sorted(extra)}")
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"event {i}: bad phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            errors.append(f"event {i}: missing name")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i}: bad ts {ts!r}")
            continue
        if "pid" not in ev or "tid" not in ev:
            errors.append(f"event {i}: missing pid/tid")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i}: X event with bad dur {dur!r}")
                continue
            spans.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ts), float(dur), ev["name"]))
        if ph == "C" and not isinstance(ev.get("args"), dict):
            errors.append(f"event {i}: C event without args values")
    eps = 0.01                                   # µs; ts is rounded to 1e-3
    for track, evs in spans.items():
        # outermost-first at equal start, then check stack containment
        evs.sort(key=lambda e: (e[0], -e[1]))
        stack: list[tuple[float, float, str]] = []
        for ts, dur, name in evs:
            while stack and stack[-1][0] + stack[-1][1] <= ts + eps:
                stack.pop()
            if stack:
                pts, pdur, pname = stack[-1]
                if ts + dur > pts + pdur + eps:
                    errors.append(
                        f"track {track}: span {name!r} [{ts}, {ts + dur}] "
                        f"overlaps parent {pname!r} [{pts}, {pts + pdur}] "
                        f"without nesting")
            stack.append((ts, dur, name))
    return errors


def validate_trace(path: str) -> list[str]:
    try:
        events = load_trace(path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable trace ({e})"]
    return validate_events(events)
