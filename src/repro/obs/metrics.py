"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

Dependency-free (stdlib only) and thread-safe: the serve engine records from
its streaming callback thread, the search loop from the tuning thread, and a
snapshot can be taken from either at any time.

Scoping mirrors ``repro.core.registry.schedule_cache``: a process-wide
default :class:`MetricsRegistry` serves production (one long-lived process,
monotonic counters), while ``with metrics_scope() as reg:`` pushes a fresh —
or caller-provided — registry onto a contextvar stack so tests and
concurrent sessions get isolated instruments without touching each other or
the default.  Instrument factories (:func:`counter` & friends and
``MetricsRegistry.counter``) are get-or-create by name, so independent call
sites share one instrument per name within a registry.
"""

from __future__ import annotations

import bisect
import contextlib
import contextvars
import json
import math
import threading
from typing import Iterator, Sequence


class Counter:
    """Monotonic counter (int increments stay int, float make it float)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int | float:
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        self.set(0.0)

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self._value}


def exponential_edges(lo: float, hi: float, n: int) -> tuple[float, ...]:
    """``n`` geometrically spaced bucket edges covering [lo, hi]."""
    if not (lo > 0 and hi > lo and n >= 2):
        raise ValueError(f"need 0 < lo < hi and n >= 2, got "
                         f"lo={lo} hi={hi} n={n}")
    ratio = (hi / lo) ** (1.0 / (n - 1))
    return tuple(lo * ratio ** i for i in range(n))


class Histogram:
    """Fixed-bucket histogram with percentile estimation.

    ``edges`` are the (sorted, finite) bucket upper bounds; values land in
    ``len(edges) + 1`` counts — an implicit underflow bucket below
    ``edges[0]`` is counts[0] and the overflow bucket above ``edges[-1]`` is
    counts[-1], so out-of-range observations are counted, never dropped.
    Percentiles interpolate linearly inside a bucket, clamped to the
    observed min/max for the open-ended end buckets.
    """

    __slots__ = ("name", "edges", "_lock", "_counts", "_count", "_sum",
                 "_min", "_max")

    DEFAULT_EDGES = exponential_edges(1e-5, 100.0, 24)   # seconds-ish scale

    def __init__(self, name: str, edges: Sequence[float] | None = None):
        edges = tuple(edges) if edges is not None else self.DEFAULT_EDGES
        if len(edges) < 1 or list(edges) != sorted(edges) \
                or len(set(edges)) != len(edges) \
                or not all(math.isfinite(e) for e in edges):
            raise ValueError(f"edges must be finite, strictly increasing and "
                             f"non-empty, got {edges!r}")
        self.name = name
        self.edges = edges
        self._lock = threading.Lock()
        self._counts = [0] * (len(edges) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def record(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v):
            return                      # inf/NaN would poison sum/percentiles
        i = bisect.bisect_left(self.edges, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        """Estimated ``q``-th percentile (q in [0, 100]); 0.0 when empty."""
        with self._lock:
            count = self._count
            counts = list(self._counts)
            lo_obs, hi_obs = self._min, self._max
        if count == 0:
            return 0.0
        rank = (q / 100.0) * (count - 1)          # 0-based fractional rank
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c > rank:
                # bucket i spans (edges[i-1], edges[i]]; clamp the open ends
                # to what was actually observed
                lo = self.edges[i - 1] if i > 0 else lo_obs
                hi = self.edges[i] if i < len(self.edges) else hi_obs
                lo = max(lo, lo_obs)
                hi = min(hi, hi_obs)
                frac = (rank - cum) / c
                return lo + (hi - lo) * frac
            cum += c
        return hi_obs

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.edges) + 1)
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf

    def snapshot(self) -> dict:
        with self._lock:
            return {"type": "histogram", "count": self._count,
                    "sum": self._sum,
                    "min": self._min if self._count else 0.0,
                    "max": self._max if self._count else 0.0,
                    "edges": list(self.edges), "counts": list(self._counts)}

    def snapshot_with_percentiles(self) -> dict:
        d = self.snapshot()
        d.update(p50=self.percentile(50), p95=self.percentile(95),
                 p99=self.percentile(99), mean=self.mean)
        return d


class MetricsRegistry:
    """Name -> instrument, get-or-create, with a JSON-able snapshot."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name, *args)
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  edges: Sequence[float] | None = None) -> Histogram:
        h = self._get(name, Histogram, edges)
        if edges is not None and tuple(edges) != h.edges:
            raise ValueError(f"histogram {name!r} already registered with "
                             f"edges {h.edges!r}")
        return h

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._instruments)

    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def __len__(self) -> int:
        return len(self._instruments)

    def reset(self) -> None:
        with self._lock:
            insts = list(self._instruments.values())
        for inst in insts:
            inst.reset()

    def snapshot(self) -> dict[str, dict]:
        with self._lock:
            insts = dict(self._instruments)
        return {name: (inst.snapshot_with_percentiles()
                       if isinstance(inst, Histogram) else inst.snapshot())
                for name, inst in sorted(insts.items())}

    def save_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)


#: process-wide default — production instruments land here when no scope is
#: active (long-lived process, monotonic counters)
default_registry = MetricsRegistry()

# contextvar stack (not a module global), mirroring registry.schedule_cache:
# concurrent scopes in different threads/tasks must not see each other's
# registry.
_ACTIVE: contextvars.ContextVar[tuple[MetricsRegistry, ...]] = \
    contextvars.ContextVar("repro_metrics_registry", default=())


@contextlib.contextmanager
def metrics_scope(reg: MetricsRegistry | None = None) \
        -> Iterator[MetricsRegistry]:
    """Activate an isolated registry for a region of code.

    ``active_registry()`` calls inside the region resolve ``reg`` (a fresh
    registry when None), so instrumented code — engines, search chains,
    train steps — records there instead of the process default.  Reentrant;
    innermost wins.
    """
    reg = MetricsRegistry() if reg is None else reg
    token = _ACTIVE.set(_ACTIVE.get() + (reg,))
    try:
        yield reg
    finally:
        _ACTIVE.reset(token)


def active_registry() -> MetricsRegistry:
    """The innermost ``metrics_scope`` registry, or the process default."""
    stack = _ACTIVE.get()
    return stack[-1] if stack else default_registry


def counter(name: str) -> Counter:
    return active_registry().counter(name)


def gauge(name: str) -> Gauge:
    return active_registry().gauge(name)


def histogram(name: str, edges: Sequence[float] | None = None) -> Histogram:
    return active_registry().histogram(name, edges)
