"""WorkloadRecorder — record the live serving mix, replay it into tuning.

The serve engine sees the *actual* deployment distribution — prompt lengths,
dtypes, batch occupancy at each prefill and decode step — which is exactly
the workload set offline tuning should optimize for (ROADMAP: always-on
autotuning).  A :class:`WorkloadRecorder` hooked into ``ContinuousEngine``
logs one record per prefill/decode dispatch to a replayable JSONL; the
aggregated mix converts into :class:`~repro.core.registry.Workload` entries
(via a caller-supplied args adapter, since each kernel takes its own
argument shapes) that ``TuningSession.run_workload`` consumes directly.

Round trip::

    rec = WorkloadRecorder()
    eng = ContinuousEngine(params, cfg, recorder=rec)
    ... serve traffic ...
    rec.save("live.jsonl")

    rec = WorkloadRecorder.load("live.jsonl")
    wls = rec.to_workloads(my_args_for)         # -> list[Workload]
    for wl in wls:
        session.run_workload("my_kernel", wl)
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Callable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkloadKey:
    """Aggregation key for one observed dispatch shape."""

    kind: str            # "prefill" | "decode"
    prompt_len: int      # tokens per row at prefill; 0 for decode
    batch: int           # dispatch batch (prefill group / occupied slots)
    dtype: str

    @property
    def name(self) -> str:
        return f"live_{self.kind}_p{self.prompt_len}_b{self.batch}_{self.dtype}"


class WorkloadRecorder:
    """Thread-safe recorder of the live (shape, dtype, occupancy) mix.

    Raw records are kept up to ``max_records`` (and streamed to
    ``jsonl_path`` as they arrive, when given); the per-key aggregation in
    :meth:`mix` is always complete regardless of the raw-record cap.
    """

    def __init__(self, jsonl_path: str | None = None, *,
                 max_records: int = 1_000_000):
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._records: list[dict] = []
        self._counts: dict[WorkloadKey, int] = {}
        self._last_t: dict[WorkloadKey, float] = {}
        self.dropped = 0
        self.max_records = max_records
        self._file = open(jsonl_path, "w") if jsonl_path else None

    def record(self, kind: str, *, prompt_len: int = 0, batch: int = 1,
               dtype: str = "int32", occupancy: int = 0,
               queue_depth: int = 0, new_tokens: int = 0,
               t: float | None = None) -> None:
        rec = {"t": round(time.perf_counter() - self._t0, 6)
               if t is None else t,
               "kind": kind, "prompt_len": int(prompt_len),
               "batch": int(batch), "dtype": str(dtype),
               "occupancy": int(occupancy),
               "queue_depth": int(queue_depth),
               "new_tokens": int(new_tokens)}
        key = WorkloadKey(kind=rec["kind"], prompt_len=rec["prompt_len"],
                          batch=rec["batch"], dtype=rec["dtype"])
        with self._lock:
            self._counts[key] = self._counts.get(key, 0) + 1
            self._last_t[key] = rec["t"]
            if len(self._records) < self.max_records:
                self._records.append(rec)
            else:
                self.dropped += 1
            if self._file is not None:
                self._file.write(json.dumps(rec) + "\n")

    # -------------------------------------------------------------- queries
    @property
    def records(self) -> list[dict]:
        with self._lock:
            return list(self._records)

    def __len__(self) -> int:
        return len(self._records)

    def mix(self) -> dict[WorkloadKey, int]:
        """Observed dispatch mix: key -> occurrence count (complete even
        past the raw-record cap)."""
        with self._lock:
            return dict(self._counts)

    def mix_snapshot(self) -> dict[WorkloadKey, tuple[int, float]]:
        """Drain surface for live consumers (the autotune service): key ->
        (cumulative count, last-seen t).  Like :meth:`mix` this is complete
        past the raw-record cap, so a consumer that diffs successive
        snapshots sees every dispatch — including ones whose raw record was
        dropped — and can staleness-weight each key by when it last fired."""
        with self._lock:
            return {k: (n, self._last_t.get(k, 0.0))
                    for k, n in self._counts.items()}

    @property
    def clock(self) -> float:
        """Seconds since the recorder started — the timebase of every
        record's ``t`` (and of :meth:`mix_snapshot`'s last-seen times)."""
        return time.perf_counter() - self._t0

    def summary(self) -> dict[str, Any]:
        """JSON-able aggregate view (what obsreport renders)."""
        mix = self.mix()
        by_kind = {kind: sum(n for k, n in mix.items() if k.kind == kind)
                   for kind in ("submit", "prefill", "decode")}
        occ = [r["occupancy"] for r in self.records if r["kind"] == "decode"]
        return {
            "records": sum(mix.values()), "dropped": self.dropped,
            "submitted": by_kind["submit"],
            "prefill_dispatches": by_kind["prefill"],
            "decode_steps": by_kind["decode"],
            "mean_decode_occupancy": float(np.mean(occ)) if occ else 0.0,
            "mix": {k.name: n for k, n in
                    sorted(mix.items(), key=lambda kv: -kv[1])},
        }

    # ----------------------------------------------------------- round trip
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self.records:
                f.write(json.dumps(rec) + "\n")

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None

    @classmethod
    def load(cls, path: str) -> "WorkloadRecorder":
        rec = cls()
        with open(path) as f:
            for line in f:
                if not line.strip():
                    continue
                d = json.loads(line)
                rec.record(d["kind"], prompt_len=d.get("prompt_len", 0),
                           batch=d.get("batch", 1),
                           dtype=d.get("dtype", "int32"),
                           occupancy=d.get("occupancy", 0),
                           queue_depth=d.get("queue_depth", 0),
                           new_tokens=d.get("new_tokens", 0),
                           t=d.get("t", 0.0))
        return rec

    def to_workloads(self, args_for: Callable[[WorkloadKey],
                                              Callable[[np.random.Generator],
                                                       Sequence[Any]] | None],
                     *, suites: tuple[str, ...] = ("live",),
                     top: int | None = None) -> list:
        """The recorded mix as TuningSession-ready ``Workload`` entries.

        ``args_for(key)`` maps one observed dispatch shape to the kernel's
        ``make_args(rng)`` callable (each kernel takes its own argument
        shapes, so the adapter is the caller's); returning None skips the
        key.  Keys are ordered by observed frequency; ``top`` bounds how
        many distinct shapes are emitted.
        """
        from repro.core.registry import Workload   # lazy: obs stays stdlib
        out = []
        ranked = sorted(self.mix().items(), key=lambda kv: -kv[1])
        if top is not None:
            ranked = ranked[:top]
        for key, _count in ranked:
            make_args = args_for(key)
            if make_args is None:
                continue
            out.append(Workload(name=key.name, make_args=make_args,
                                suites=suites))
        return out


def tail_jsonl(path: str, offset: int = 0) -> tuple[list[dict], int]:
    """Incrementally read recorder records appended to ``path`` since byte
    ``offset`` — the cross-process drain the autotune daemon uses to follow
    a serving process's ``--record-workloads`` stream.

    Returns ``(records, new_offset)``.  A trailing line without a newline is
    assumed mid-write and left for the next call (its bytes are not
    consumed); a complete-but-corrupt line is skipped, not fatal.  A missing
    file (the server has not started writing yet) yields ``([], offset)``.
    """
    try:
        f = open(path, "rb")
    except FileNotFoundError:
        return [], offset
    records: list[dict] = []
    with f:
        f.seek(offset)
        buf = f.read()
    end = buf.rfind(b"\n")
    if end < 0:
        return [], offset
    for line in buf[:end].splitlines():
        if not line.strip():
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError:
            continue
    return records, offset + end + 1
