"""repro.obs — dependency-free telemetry: metrics, tracing, workload capture.

Three pieces, wired through every layer of the stack:

* :mod:`repro.obs.metrics` — process-wide :class:`MetricsRegistry`
  (counters / gauges / fixed-bucket histograms) with a contextvar-scoped
  override (``metrics_scope``) so tests and sessions get isolated
  registries.
* :mod:`repro.obs.trace` — structured event :class:`Tracer` with nested
  spans and a Chrome-trace/Perfetto export; ``with tracing(t):`` activates
  it, the module-level ``span``/``instant``/``counter`` helpers are no-ops
  when tracing is off.
* :mod:`repro.obs.recorder` — :class:`WorkloadRecorder`, the live-traffic →
  offline-tuning seam (ROADMAP: always-on autotuning).

Everything is stdlib + numpy; nothing here imports jax.
"""

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               active_registry, counter, default_registry,
                               exponential_edges, gauge, histogram,
                               metrics_scope)
from repro.obs.recorder import WorkloadKey, WorkloadRecorder, tail_jsonl
from repro.obs.trace import (Tracer, active_tracer, instant, load_trace,
                             span, tracing, validate_events, validate_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "active_registry",
    "counter", "default_registry", "exponential_edges", "gauge", "histogram",
    "metrics_scope", "WorkloadKey", "WorkloadRecorder", "tail_jsonl",
    "Tracer",
    "active_tracer", "instant", "load_trace", "span", "tracing",
    "validate_events", "validate_trace",
]
