"""AdamW with cosine schedule, global-norm clipping and microbatch-friendly
fp32 moments.  Optimizer state shards exactly like the parameters (ZeRO-3:
the FSDP 'embed'->data rule applies to mu/nu too)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params: Any) -> dict[str, Any]:
    zeros = lambda t: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads: Any, state: dict[str, Any], params: Any,
                 cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_at(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, n):
        mhat = m / bc1
        nhat = n / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    new_state = {"mu": mu, "nu": nu, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
