"""rmsnorm kernel package (kernel.py emission, ref.py oracle, SIP integration)."""
