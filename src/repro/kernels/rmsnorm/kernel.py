"""Schedule-parameterized Pallas RMSNorm — a memory-bound SIP target.

Rows are tiled over a 1-D parallel grid; the feature dimension is processed
in ``n_chunks`` pieces so the body contains several independent MEM loads
(x chunks + the gamma chunks) whose placement SIP can permute against the
square/accumulate compute.  For a bandwidth-bound kernel the win comes from
issuing every load before the reduction chain — which is exactly what the
annealer converges to (see benchmarks/table3_gemm.py's rmsnorm sibling).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ir import Instr, Kind, Program

INTERPRET = jax.default_backend() != "tpu"
EPS = 1e-6


def make_program(*, br: int, d: int, n_chunks: int, dtype=jnp.float32,
                 rows: int = 0) -> Program:
    assert d % n_chunks == 0
    replications = max(rows // br, 1) if rows else 1
    cd = d // n_chunks
    esize = jnp.dtype(dtype).itemsize
    instrs: list[Instr] = []

    def ld_x(env, c):
        return {f"x{c}": env["x_ref"][:, pl.ds(c * cd, cd)].astype(jnp.float32)}

    def ld_g(env, c):
        return {f"g{c}": env["g_ref"][0, pl.ds(c * cd, cd)].astype(jnp.float32)}

    def sq(env, c):
        x = env[f"x{c}"]
        return {f"ss{c}": jnp.sum(x * x, axis=1, keepdims=True)}

    for c in range(n_chunks):
        instrs.append(Instr(name=f"ld_x{c}", kind=Kind.MEM, inputs=(),
                            outputs=(f"x{c}",), fn=functools.partial(ld_x, c=c),
                            buffer="x", bytes=br * cd * esize))
        instrs.append(Instr(name=f"sq{c}", kind=Kind.COMPUTE, inputs=(f"x{c}",),
                            outputs=(f"ss{c}",), fn=functools.partial(sq, c=c),
                            flops=2 * br * cd))

    def rstd(env):
        tot = env["ss0"]
        for c in range(1, n_chunks):
            tot = tot + env[f"ss{c}"]
        return {"rstd": jax.lax.rsqrt(tot / d + EPS)}

    instrs.append(Instr(name="rstd", kind=Kind.COMPUTE,
                        inputs=tuple(f"ss{c}" for c in range(n_chunks)),
                        outputs=("rstd",), fn=rstd, flops=2 * br))

    def scale(env, c):
        return {f"y{c}": (env[f"x{c}"] * env["rstd"] * env[f"g{c}"])}

    def st_y(env, c):
        env["o_ref"][:, pl.ds(c * cd, cd)] = env[f"y{c}"].astype(dtype)
        return {}

    for c in range(n_chunks):
        instrs.append(Instr(name=f"ld_g{c}", kind=Kind.MEM, inputs=(),
                            outputs=(f"g{c}",), fn=functools.partial(ld_g, c=c),
                            buffer="g", bytes=cd * esize))
        instrs.append(Instr(name=f"scale{c}", kind=Kind.COMPUTE,
                            inputs=(f"x{c}", "rstd", f"g{c}"),
                            outputs=(f"y{c}",), fn=functools.partial(scale, c=c),
                            flops=2 * br * cd))
        instrs.append(Instr(name=f"st_y{c}", kind=Kind.MEM, inputs=(f"y{c}",),
                            outputs=(), fn=functools.partial(st_y, c=c),
                            buffer="o", is_store=True, bytes=br * cd * esize))
    return Program(instrs, replications=replications)


def pallas_rmsnorm(x: jax.Array, gamma: jax.Array, *, br: int,
                   n_chunks: int = 1, order=None,
                   interpret: bool = INTERPRET) -> jax.Array:
    rows, d = x.shape
    assert rows % br == 0 and gamma.shape == (d,)
    program = make_program(br=br, d=d, n_chunks=n_chunks, dtype=x.dtype)

    def kernel(x_ref, g_ref, o_ref):
        program.execute({"x_ref": x_ref, "g_ref": g_ref, "o_ref": o_ref}, order)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        interpret=interpret,
        **kwargs,
    )(x, gamma[None, :])
