"""Pure-jnp oracle for fused RMSNorm (scale)."""

import jax.numpy as jnp

EPS = 1e-6


def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = EPS) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (1.0 / jnp.sqrt(ms + eps)) * gamma.astype(jnp.float32)
    return y.astype(x.dtype)
