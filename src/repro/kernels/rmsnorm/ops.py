"""SIP integration for the fused RMSNorm kernel (registry-based)."""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jit import SipKernel
from repro.core.registry import KernelHandle, Workload, sip_kernel
from repro.core.schedule import KnobSpec, Schedule, SearchSpace
from repro.kernels.rmsnorm import kernel as K
from repro.kernels.rmsnorm import ref

NAME = "rmsnorm_fused"


def _choices(dim: int, prefs) -> tuple[int, ...]:
    ch = tuple(c for c in prefs if dim % c == 0 and c <= dim)
    return ch or (dim,)


def space(*, rows: int, d: int, dtype: str = "float32") -> SearchSpace:
    return SearchSpace(knobs=(
        KnobSpec("br", _choices(rows, (256, 512, 128, 64, 32, 16, 8, 1))),
        KnobSpec("n_chunks", _choices(d, (4, 2, 8, 1))),
    ))


def _knobs(schedule: Schedule, **static):
    sp = space(**static)
    d = sp.default_knobs()
    d.update(schedule.knobs)
    return d["br"], d["n_chunks"]


def program_for(schedule: Schedule, **static):
    br, n_chunks = _knobs(schedule, **static)
    return K.make_program(br=br, d=static["d"], n_chunks=n_chunks,
                          dtype=jnp.dtype(static["dtype"]),
                          rows=static["rows"])


def signature_fn(x, gamma) -> dict:
    rows, d = x.shape
    return {"rows": int(rows), "d": int(d), "dtype": str(jnp.dtype(x.dtype))}


def _rmsnorm_args(rows: int, d: int):
    def make_args(rng: np.random.Generator):
        x = rng.standard_normal((rows, d)).astype(np.float32)
        g = rng.standard_normal((d,)).astype(np.float32)
        return [x, g]
    return make_args


WORKLOADS = (
    Workload("smoke_16x32", _rmsnorm_args(16, 32), suites=("smoke",)),
    Workload("deploy_64x128", _rmsnorm_args(64, 128)),
)


def build(schedule: Schedule, **static):
    br, n_chunks = _knobs(schedule, **static)
    program = program_for(schedule, **static)
    order = schedule.resolve_order(program)
    return jax.jit(functools.partial(K.pallas_rmsnorm, br=br,
                                     n_chunks=n_chunks, order=order))


SPEC = sip_kernel(name=NAME, program_for=program_for, space_for=space,
                  oracle=ref.rmsnorm, signature_fn=signature_fn,
                  workloads=WORKLOADS)(build)


def make(cache=None) -> SipKernel:
    """Deprecated pre-registry constructor (fresh, unshared instance)."""
    warnings.warn("rmsnorm.ops.make() is deprecated; resolve the kernel via "
                  "repro.core.registry.registry.get(ops.NAME) instead",
                  DeprecationWarning, stacklevel=2)
    return SPEC.instantiate(cache=cache)


rmsnorm = KernelHandle(NAME)   # late-binding: honors the active schedule_cache
