"""jit'd wrapper + SIP integration for the fused RMSNorm kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.jit import SipKernel
from repro.core.schedule import KnobSpec, Schedule, SearchSpace
from repro.kernels.rmsnorm import kernel as K
from repro.kernels.rmsnorm import ref

NAME = "rmsnorm_fused"


def _choices(dim: int, prefs) -> tuple[int, ...]:
    ch = tuple(c for c in prefs if dim % c == 0 and c <= dim)
    return ch or (dim,)


def space(*, rows: int, d: int, dtype: str = "float32") -> SearchSpace:
    return SearchSpace(knobs=(
        KnobSpec("br", _choices(rows, (256, 512, 128, 64, 32, 16, 8, 1))),
        KnobSpec("n_chunks", _choices(d, (4, 2, 8, 1))),
    ))


def _knobs(schedule: Schedule, **static):
    sp = space(**static)
    d = sp.default_knobs()
    d.update(schedule.knobs)
    return d["br"], d["n_chunks"]


def program_for(schedule: Schedule, **static):
    br, n_chunks = _knobs(schedule, **static)
    return K.make_program(br=br, d=static["d"], n_chunks=n_chunks,
                          dtype=jnp.dtype(static["dtype"]),
                          rows=static["rows"])


def build(schedule: Schedule, **static):
    br, n_chunks = _knobs(schedule, **static)
    program = program_for(schedule, **static)
    order = schedule.resolve_order(program)
    return jax.jit(functools.partial(K.pallas_rmsnorm, br=br,
                                     n_chunks=n_chunks, order=order))


def signature_fn(x, gamma) -> dict:
    rows, d = x.shape
    return {"rows": int(rows), "d": int(d), "dtype": str(jnp.dtype(x.dtype))}


def make(cache=None) -> SipKernel:
    return SipKernel(name=NAME, build=build, program_for=program_for,
                     space_for=space, oracle=ref.rmsnorm,
                     signature_fn=signature_fn, cache=cache)


rmsnorm = make()
