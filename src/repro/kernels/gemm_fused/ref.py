"""Pure-jnp oracle for fused GEMM + LeakyReLU (paper Table 3 workload)."""

import jax.numpy as jnp

ALPHA = 0.01


def gemm_leaky_relu(x: jnp.ndarray, w: jnp.ndarray, alpha: float = ALPHA) -> jnp.ndarray:
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    y = jnp.where(y >= 0, y, alpha * y)
    return y.astype(x.dtype)
