"""jit'd wrapper + SIP integration for the fused GEMM+LeakyReLU kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.jit import SipKernel
from repro.core.schedule import KnobSpec, Schedule, SearchSpace
from repro.kernels.gemm_fused import kernel as K
from repro.kernels.gemm_fused import ref

NAME = "gemm_fused_leaky_relu"


def _knob_choices(dim: int, prefs: tuple[int, ...]) -> tuple[int, ...]:
    ch = tuple(c for c in prefs if dim % c == 0 and c <= dim)
    return ch or (dim,)


def space(*, m: int, n: int, k: int, dtype: str = "float32") -> SearchSpace:
    return SearchSpace(knobs=(
        KnobSpec("bm", _knob_choices(m, (128, 256, 512, 64, 32, 16, 8))),
        KnobSpec("bn", _knob_choices(n, (128, 256, 512, 64, 32, 16, 8))),
        KnobSpec("bk", _knob_choices(k, (128, 256, 512, 64, 32, 16, 8))),
    ))


def _blocks(schedule: Schedule, m: int, n: int, k: int, dtype: str):
    sp = space(m=m, n=n, k=k, dtype=dtype)
    d = sp.default_knobs()
    d.update(schedule.knobs)
    return d["bm"], d["bn"], d["bk"]


def program_for(schedule: Schedule, *, m: int, n: int, k: int,
                dtype: str = "float32"):
    bm, bn, bk = _blocks(schedule, m, n, k, dtype)
    return K.make_program(m=m, n=n, k=k, bm=bm, bn=bn, bk=bk,
                          dtype=jnp.dtype(dtype))


def build(schedule: Schedule, *, m: int, n: int, k: int,
          dtype: str = "float32"):
    bm, bn, bk = _blocks(schedule, m, n, k, dtype)
    program = program_for(schedule, m=m, n=n, k=k, dtype=dtype)
    order = schedule.resolve_order(program)
    fn = functools.partial(K.pallas_gemm_leaky_relu, bm=bm, bn=bn, bk=bk,
                           order=order)
    return jax.jit(fn)


def signature_fn(x, w) -> dict:
    (m, k), (_, n) = x.shape, w.shape
    return {"m": int(m), "n": int(n), "k": int(k), "dtype": str(jnp.dtype(x.dtype))}


def make(cache=None) -> SipKernel:
    return SipKernel(name=NAME, build=build, program_for=program_for,
                     space_for=space, oracle=ref.gemm_leaky_relu,
                     signature_fn=signature_fn, cache=cache)


# module-level kernel instance (in-memory cache; launchers pass a persistent one)
gemm_leaky_relu = make()
