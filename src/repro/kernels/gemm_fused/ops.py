"""SIP integration for the fused GEMM+LeakyReLU kernel (registry-based).

The kernel registers a declarative :class:`KernelSpec` — six callables plus
its own deployment workloads — so the offline driver, models, and serving
all resolve it by name through ``repro.core.registry``.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jit import SipKernel
from repro.core.registry import KernelHandle, Workload, sip_kernel
from repro.core.schedule import KnobSpec, Schedule, SearchSpace
from repro.kernels.gemm_fused import kernel as K
from repro.kernels.gemm_fused import ref

NAME = "gemm_fused_leaky_relu"


def _knob_choices(dim: int, prefs: tuple[int, ...]) -> tuple[int, ...]:
    ch = tuple(c for c in prefs if dim % c == 0 and c <= dim)
    return ch or (dim,)


def space(*, m: int, n: int, k: int, dtype: str = "float32") -> SearchSpace:
    return SearchSpace(knobs=(
        KnobSpec("bm", _knob_choices(m, (128, 256, 512, 64, 32, 16, 8))),
        KnobSpec("bn", _knob_choices(n, (128, 256, 512, 64, 32, 16, 8))),
        KnobSpec("bk", _knob_choices(k, (128, 256, 512, 64, 32, 16, 8))),
    ))


def _blocks(schedule: Schedule, m: int, n: int, k: int, dtype: str):
    sp = space(m=m, n=n, k=k, dtype=dtype)
    d = sp.default_knobs()
    d.update(schedule.knobs)
    return d["bm"], d["bn"], d["bk"]


def program_for(schedule: Schedule, *, m: int, n: int, k: int,
                dtype: str = "float32"):
    bm, bn, bk = _blocks(schedule, m, n, k, dtype)
    return K.make_program(m=m, n=n, k=k, bm=bm, bn=bn, bk=bk,
                          dtype=jnp.dtype(dtype))


def signature_fn(x, w) -> dict:
    (m, k), (_, n) = x.shape, w.shape
    return {"m": int(m), "n": int(n), "k": int(k), "dtype": str(jnp.dtype(x.dtype))}


def _gemm_args(m: int, n: int, k: int):
    def make_args(rng: np.random.Generator):
        x = rng.standard_normal((m, k)).astype(np.float32)
        w = rng.standard_normal((k, n)).astype(np.float32)
        return [x, w]
    return make_args


WORKLOADS = (
    Workload("smoke_16x16x32", _gemm_args(16, 16, 32), suites=("smoke",)),
    Workload("deploy_64x64x128", _gemm_args(64, 64, 128)),
    Workload("deploy_128x128x256", _gemm_args(128, 128, 256)),
)


def build(schedule: Schedule, *, m: int, n: int, k: int,
          dtype: str = "float32"):
    bm, bn, bk = _blocks(schedule, m, n, k, dtype)
    program = program_for(schedule, m=m, n=n, k=k, dtype=dtype)
    order = schedule.resolve_order(program)
    fn = functools.partial(K.pallas_gemm_leaky_relu, bm=bm, bn=bn, bk=bk,
                           order=order)
    return jax.jit(fn)


SPEC = sip_kernel(name=NAME, program_for=program_for, space_for=space,
                  oracle=ref.gemm_leaky_relu, signature_fn=signature_fn,
                  workloads=WORKLOADS)(build)


def make(cache=None) -> SipKernel:
    """Deprecated pre-registry constructor (fresh, unshared instance).

    Use ``registry.get(NAME)`` — optionally under ``schedule_cache(...)`` —
    to share one instance and its build caches."""
    warnings.warn("gemm_fused.ops.make() is deprecated; resolve the kernel "
                  "via repro.core.registry.registry.get(ops.NAME) instead",
                  DeprecationWarning, stacklevel=2)
    return SPEC.instantiate(cache=cache)


# late-binding handle: resolves the registry's shared instance — honoring
# the schedule_cache scope active at CALL time — on every use
gemm_leaky_relu = KernelHandle(NAME)
