"""Schedule-parameterized Pallas kernel: fused GEMM + LeakyReLU.

The kernel body is *emitted* from a :class:`~repro.core.ir.Program`: the K
dimension is processed in ``bk``-sized steps inside the body, each step
contributing two MEM loads (an x-tile and a w-tile — the analogue of the
paper's LDGSTS global-memory instructions) and one MXU dot (COMPUTE).  The
default order interleaves ``ld_x, ld_w, dot`` per step, which is what a
straightforward compiler emits (cf. Listing 4); SIP's annealer reorders the
loads ahead of the dots (software pipelining / latency hiding, cf. Listing 5).

Grid: ``(M/bm, N/bn)`` with both dimensions parallel; the accumulator lives in
registers/VREGs as a traced value, accumulated in fp32, with the LeakyReLU
epilogue fused before the single store.

VMEM working set per program: ``bm*K + K*bn + bm*bn`` elements — the knob
choices keep this under the v5e VMEM budget for the benchmarked shapes.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ir import Instr, Kind, Program

INTERPRET = jax.default_backend() != "tpu"
ALPHA = 0.01


def make_program(*, m: int, n: int, k: int, bm: int, bn: int, bk: int,
                 dtype=jnp.float32) -> Program:
    """Build the instruction stream for one (bm x bn) output tile."""
    esize = jnp.dtype(dtype).itemsize
    k_steps = math.ceil(k / bk)
    instrs: list[Instr] = []

    def ld_x(env, s=0, bk=bk):
        return {f"x{s}": env["x_ref"][:, pl.ds(s * bk, bk)]}

    def ld_w(env, s=0, bk=bk):
        return {f"w{s}": env["w_ref"][pl.ds(s * bk, bk), :]}

    def dot(env, s=0):
        part = jnp.dot(env[f"x{s}"], env[f"w{s}"],
                       preferred_element_type=jnp.float32)
        return {f"acc{s + 1}": env[f"acc{s}"] + part}

    instrs.append(Instr(name="init_acc", kind=Kind.COMPUTE, inputs=(),
                        outputs=("acc0",),
                        fn=lambda env: {"acc0": jnp.zeros((bm, bn), jnp.float32)},
                        flops=0))
    for s in range(k_steps):
        instrs.append(Instr(name=f"ld_x{s}", kind=Kind.MEM, inputs=(),
                            outputs=(f"x{s}",), fn=functools.partial(ld_x, s=s),
                            buffer="x", bytes=bm * bk * esize))
        instrs.append(Instr(name=f"ld_w{s}", kind=Kind.MEM, inputs=(),
                            outputs=(f"w{s}",), fn=functools.partial(ld_w, s=s),
                            buffer="w", bytes=bk * bn * esize))
        instrs.append(Instr(name=f"dot{s}", kind=Kind.COMPUTE,
                            inputs=(f"x{s}", f"w{s}", f"acc{s}"),
                            outputs=(f"acc{s + 1}",),
                            fn=functools.partial(dot, s=s),
                            flops=2 * bm * bn * bk))
    acc_final = f"acc{k_steps}"

    def epilogue(env):
        y = env[acc_final]
        return {"y": jnp.where(y >= 0, y, ALPHA * y).astype(dtype)}

    instrs.append(Instr(name="leaky_relu", kind=Kind.COMPUTE,
                        inputs=(acc_final,), outputs=("y",), fn=epilogue,
                        flops=bm * bn))

    def store(env):
        env["o_ref"][...] = env["y"]
        return {}

    instrs.append(Instr(name="st_o", kind=Kind.MEM, inputs=("y",), outputs=(),
                        fn=store, buffer="o", is_store=True,
                        bytes=bm * bn * esize))
    return Program(instrs, replications=(m // bm) * (n // bn))


def pallas_gemm_leaky_relu(x: jax.Array, w: jax.Array, *, bm: int, bn: int,
                           bk: int, order=None,
                           interpret: bool = INTERPRET) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    program = make_program(m=m, n=n, k=k, bm=bm, bn=bn, bk=bk, dtype=x.dtype)

    def kernel(x_ref, w_ref, o_ref):
        program.execute({"x_ref": x_ref, "w_ref": w_ref, "o_ref": o_ref}, order)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, bn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
        **kwargs,
    )(x, w)
