"""jit'd wrapper + SIP integration for the fused attention kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.jit import SipKernel
from repro.core.schedule import KnobSpec, Schedule, SearchSpace
from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref


def _choices(dim: int, prefs: tuple[int, ...]) -> tuple[int, ...]:
    ch = tuple(c for c in prefs if dim % c == 0 and c <= dim)
    return ch or (dim,)


def space(*, b, hq, hkv, sq, skv, d, causal, window, dtype="float32") -> SearchSpace:
    bks = _choices(skv, (256, 512, 128, 64, 32, 16, 8))
    return SearchSpace(knobs=(
        KnobSpec("bq", _choices(sq, (256, 512, 128, 64, 32, 16, 8, 1))),
        KnobSpec("bk", bks),
        KnobSpec("n_chunks", tuple(c for c in (2, 4, 1) if bks[0] % c == 0)),
    ))


def _knobs(schedule: Schedule, **static):
    sp = space(**static)
    d = sp.default_knobs()
    d.update(schedule.knobs)
    return d["bq"], d["bk"], d["n_chunks"]


def program_for(schedule: Schedule, **static):
    bq, bk, n_chunks = _knobs(schedule, **static)
    return K.make_program(bq=bq, bk=bk, n_chunks=n_chunks, d=static["d"],
                          sq=static["sq"], skv=static["skv"],
                          causal=static["causal"], window=static["window"],
                          dtype=jnp.dtype(static["dtype"]),
                          batch_heads=static["b"] * static["hq"])


def build(schedule: Schedule, **static):
    bq, bk, n_chunks = _knobs(schedule, **static)
    program = program_for(schedule, **static)
    order = schedule.resolve_order(program)
    fn = functools.partial(K.pallas_attention, bq=bq, bk=bk, n_chunks=n_chunks,
                           causal=static["causal"], window=static["window"],
                           order=order)
    return jax.jit(fn)


def make(causal: bool = True, window: int | None = None, cache=None) -> SipKernel:
    name = "flash_attention" + ("_causal" if causal else "") + \
        (f"_w{window}" if window else "")

    def signature_fn(q, k, v) -> dict:
        b, hq, sq, d = q.shape
        _, hkv, skv, _ = k.shape
        return {"b": int(b), "hq": int(hq), "hkv": int(hkv), "sq": int(sq),
                "skv": int(skv), "d": int(d), "causal": causal,
                "window": window, "dtype": str(jnp.dtype(q.dtype))}

    oracle = functools.partial(ref.attention, causal=causal, window=window)
    return SipKernel(name=name, build=build, program_for=program_for,
                     space_for=space, oracle=oracle,
                     signature_fn=signature_fn, cache=cache)


flash_attention = make(causal=True)
flash_attention_bidir = make(causal=False)
