"""SIP integration for the fused attention kernel (registry-based).

Attention is a *family* of kernels — (causal, window) variants share the
build/program/space callables but differ in oracle and name.  The common
variants register at import; :func:`kernel` resolves (and lazily registers)
any variant as ONE shared, registry-cached instance, so the model's
attention path never constructs fresh kernels per call.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jit import SipKernel
from repro.core.registry import KernelHandle, KernelSpec, Workload, registry
from repro.core.schedule import KnobSpec, Schedule, SearchSpace
from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref


def _choices(dim: int, prefs: tuple[int, ...]) -> tuple[int, ...]:
    ch = tuple(c for c in prefs if dim % c == 0 and c <= dim)
    return ch or (dim,)


def space(*, b, hq, hkv, sq, skv, d, causal, window, dtype="float32") -> SearchSpace:
    bks = _choices(skv, (256, 512, 128, 64, 32, 16, 8))
    return SearchSpace(knobs=(
        KnobSpec("bq", _choices(sq, (256, 512, 128, 64, 32, 16, 8, 1))),
        KnobSpec("bk", bks),
        KnobSpec("n_chunks", tuple(c for c in (2, 4, 1) if bks[0] % c == 0)),
    ))


def _knobs(schedule: Schedule, **static):
    sp = space(**static)
    d = sp.default_knobs()
    d.update(schedule.knobs)
    return d["bq"], d["bk"], d["n_chunks"]


def program_for(schedule: Schedule, **static):
    bq, bk, n_chunks = _knobs(schedule, **static)
    return K.make_program(bq=bq, bk=bk, n_chunks=n_chunks, d=static["d"],
                          sq=static["sq"], skv=static["skv"],
                          causal=static["causal"], window=static["window"],
                          dtype=jnp.dtype(static["dtype"]),
                          batch_heads=static["b"] * static["hq"])


def build(schedule: Schedule, **static):
    bq, bk, n_chunks = _knobs(schedule, **static)
    program = program_for(schedule, **static)
    order = schedule.resolve_order(program)
    fn = functools.partial(K.pallas_attention, bq=bq, bk=bk, n_chunks=n_chunks,
                           causal=static["causal"], window=static["window"],
                           order=order)
    return jax.jit(fn)


def variant_name(causal: bool = True, window: int | None = None) -> str:
    return "flash_attention" + ("_causal" if causal else "") + \
        (f"_w{window}" if window else "")


def _attn_args(b: int, hq: int, hkv: int, s: int, d: int):
    def make_args(rng: np.random.Generator):
        q = rng.standard_normal((b, hq, s, d)).astype(np.float32)
        k = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
        v = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
        return [q, k, v]
    return make_args


def register_variant(causal: bool, window: int | None,
                     workloads: tuple[Workload, ...] = ()) -> KernelSpec:
    """Register a (causal, window) variant, optionally with its own
    deployment workloads.

    Deployments that serve a sliding-window arch and want it OFFLINE-tuned
    (not just lazily served with default schedules) declare it here — next
    to the kernel, never in the launcher::

        register_variant(True, 128, workloads=(
            Workload("deploy_w128", _attn_args(1, 8, 8, 2048, 64)),))
    """
    def signature_fn(q, k, v) -> dict:
        b, hq, sq, d = q.shape
        _, hkv, skv, _ = k.shape
        return {"b": int(b), "hq": int(hq), "hkv": int(hkv), "sq": int(sq),
                "skv": int(skv), "d": int(d), "causal": causal,
                "window": window, "dtype": str(jnp.dtype(q.dtype))}

    oracle = functools.partial(ref.attention, causal=causal, window=window)
    return registry.register(KernelSpec(
        name=variant_name(causal, window), build=build,
        program_for=program_for, space_for=space, oracle=oracle,
        signature_fn=signature_fn, workloads=workloads, module=__name__))


CAUSAL_SPEC = register_variant(True, None, workloads=(
    Workload("smoke_b1_h2kv2_s16_d8", _attn_args(1, 2, 2, 16, 8),
             suites=("smoke",)),
    Workload("deploy_b1_h4kv2_s128_d32", _attn_args(1, 4, 2, 128, 32)),
))
BIDIR_SPEC = register_variant(False, None)


def ensure_registered(causal: bool = True, window: int | None = None) -> str:
    """Name of the (causal, window) variant, registering it on first use."""
    name = variant_name(causal, window)
    if name not in registry:
        try:
            register_variant(causal, window)
        except ValueError:
            # lost a concurrent first-use race; the variant exists now
            if name not in registry:
                raise
    return name


def kernel(causal: bool = True, window: int | None = None) -> SipKernel:
    """The shared registry instance for a variant, bound to the active
    schedule cache — the model/serving resolution path."""
    return registry.get(ensure_registered(causal, window))


def make(causal: bool = True, window: int | None = None,
         cache=None) -> SipKernel:
    """Deprecated pre-registry constructor (fresh, unshared instance).

    Use :func:`kernel` (or ``registry.get``) to share one instance and its
    build caches."""
    warnings.warn("flash_attention.ops.make() is deprecated; resolve the "
                  "kernel via flash_attention.ops.kernel(causal, window) "
                  "instead", DeprecationWarning, stacklevel=2)
    name = ensure_registered(causal, window)
    return registry.spec(name).instantiate(cache=cache)


# late-binding handles: honor the schedule_cache scope active at call time
flash_attention = KernelHandle(variant_name(True, None))
flash_attention_bidir = KernelHandle(variant_name(False, None))
