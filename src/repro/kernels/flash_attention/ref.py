"""Pure-jnp oracle for fused attention (paper Table 2 workload).

Supports GQA (kv heads broadcast over query-head groups), causal masking and
sliding-window masking — the variants the assigned architectures need.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int | None = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  Returns (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    rows = jnp.arange(sq)[:, None] + (skv - sq)   # right-aligned for decode
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vf) / jnp.maximum(l, 1e-30)
    return o.astype(q.dtype)
