"""Schedule-parameterized Pallas flash-attention (fwd), TPU-tiled.

Online-softmax attention with the kv sequence swept by the innermost
(sequential) grid dimension and running (m, l, acc) statistics carried in
VMEM scratch — the standard TPU flash-attention structure.  As with the GEMM
kernel, the body is emitted from a :class:`~repro.core.ir.Program`:

* MEM instructions: the q-tile load, per-chunk K loads, per-chunk V loads,
  the output store.  These are SIP's movable set — the analogue of the
  LDGSTS instructions the paper reorders (Listings 4/5).  In particular the
  V loads have no dependency on the softmax chain, so the annealer can hoist
  them next to the K loads (overlapping the V transfer with QK^T + softmax),
  which is exactly the latency-hiding schedule hand-tuned in prior work.
* COMPUTE instructions: QK^T dots (MXU), masking, the online-softmax update,
  PV dots, the scratch read/update (VPU).

GQA is handled in the K/V BlockSpec index maps (query head -> kv head), so
no materialized head broadcast is needed.  Causal and sliding-window masks
are applied in-body from global row/col indices; fully-masked blocks are
numerically safe (finite NEG_INF + explicit re-masking of p).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ir import Instr, Kind, Program

INTERPRET = jax.default_backend() != "tpu"
NEG_INF = -1e30


def make_program(*, bq: int, bk: int, n_chunks: int, d: int, sq: int, skv: int,
                 causal: bool, window: int | None, dtype=jnp.float32,
                 batch_heads: int = 1) -> Program:
    assert bk % n_chunks == 0
    ck = bk // n_chunks
    replications = batch_heads * (sq // bq) * (skv // bk)
    esize = jnp.dtype(dtype).itemsize
    scale = d ** -0.5
    instrs: list[Instr] = []

    # ---- loads -------------------------------------------------------------
    instrs.append(Instr(
        name="ld_q", kind=Kind.MEM, inputs=(), outputs=("q",),
        fn=lambda env: {"q": env["q_ref"][0].astype(jnp.float32)},
        buffer="q", bytes=bq * d * esize))

    def ld_k(env, c):
        return {f"k{c}": env["k_ref"][0, pl.ds(c * ck, ck), :].astype(jnp.float32)}

    def ld_v(env, c):
        return {f"v{c}": env["v_ref"][0, pl.ds(c * ck, ck), :].astype(jnp.float32)}

    def qk(env, c):
        s = jax.lax.dot_general(env["q"], env[f"k{c}"],
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        return {f"s{c}": s}

    def mk_mask(env, c):
        i, j = env["i"], env["j"]
        rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, ck), 0) + (skv - sq)
        cols = j * bk + c * ck + jax.lax.broadcasted_iota(jnp.int32, (bq, ck), 1)
        m = jnp.ones((bq, ck), dtype=bool)
        if causal:
            m &= cols <= rows
        if window is not None:
            m &= cols > rows - window
        return {f"mask{c}": m,
                f"sm{c}": jnp.where(m, env[f"s{c}"], NEG_INF)}

    for c in range(n_chunks):
        instrs.append(Instr(name=f"ld_k{c}", kind=Kind.MEM, inputs=(),
                            outputs=(f"k{c}",), fn=functools.partial(ld_k, c=c),
                            buffer="k", bytes=ck * d * esize))
        instrs.append(Instr(name=f"qk{c}", kind=Kind.COMPUTE,
                            inputs=("q", f"k{c}"), outputs=(f"s{c}",),
                            fn=functools.partial(qk, c=c),
                            flops=2 * bq * ck * d))
        instrs.append(Instr(name=f"mask{c}", kind=Kind.COMPUTE,
                            inputs=(f"s{c}",), outputs=(f"sm{c}", f"mask{c}"),
                            fn=functools.partial(mk_mask, c=c),
                            flops=bq * ck))

    # ---- read running stats (VMEM scratch; init on first kv block) ----------
    def ld_stats(env):
        j = env["j"]
        first = j == 0
        m_prev = jnp.where(first, jnp.full((bq, 1), NEG_INF, jnp.float32),
                           env["m_ref"][...])
        l_prev = jnp.where(first, jnp.zeros((bq, 1), jnp.float32),
                           env["l_ref"][...])
        acc_prev = jnp.where(first, jnp.zeros((bq, d), jnp.float32),
                             env["acc_ref"][...])
        return {"m_prev": m_prev, "l_prev": l_prev, "acc_prev": acc_prev}

    instrs.append(Instr(name="ld_stats", kind=Kind.COMPUTE, inputs=(),
                        outputs=("m_prev", "l_prev", "acc_prev"),
                        fn=ld_stats, buffer="stats", flops=0))

    # ---- online softmax ------------------------------------------------------
    def softmax_update(env):
        m_cur = env["m_prev"]
        for c in range(n_chunks):
            m_cur = jnp.maximum(m_cur, jnp.max(env[f"sm{c}"], axis=1, keepdims=True))
        corr = jnp.exp(env["m_prev"] - m_cur)
        l_new = corr * env["l_prev"]
        out = {"m_new": m_cur, "corr": corr}
        for c in range(n_chunks):
            p = jnp.exp(env[f"sm{c}"] - m_cur) * env[f"mask{c}"]
            out[f"p{c}"] = p
            l_new = l_new + jnp.sum(p, axis=1, keepdims=True)
        out["l_new"] = l_new
        return out

    instrs.append(Instr(
        name="softmax", kind=Kind.COMPUTE,
        inputs=("m_prev", "l_prev") + tuple(f"sm{c}" for c in range(n_chunks))
               + tuple(f"mask{c}" for c in range(n_chunks)),
        outputs=("m_new", "l_new", "corr") + tuple(f"p{c}" for c in range(n_chunks)),
        fn=softmax_update, flops=6 * bq * bk))

    # ---- PV and accumulator ---------------------------------------------------
    def pv(env, c):
        return {f"pv{c}": jnp.dot(env[f"p{c}"], env[f"v{c}"],
                                  preferred_element_type=jnp.float32)}

    for c in range(n_chunks):
        instrs.append(Instr(name=f"ld_v{c}", kind=Kind.MEM, inputs=(),
                            outputs=(f"v{c}",), fn=functools.partial(ld_v, c=c),
                            buffer="v", bytes=ck * d * esize))
        instrs.append(Instr(name=f"pv{c}", kind=Kind.COMPUTE,
                            inputs=(f"p{c}", f"v{c}"), outputs=(f"pv{c}",),
                            fn=functools.partial(pv, c=c),
                            flops=2 * bq * ck * d))

    def accumulate(env):
        acc = env["corr"] * env["acc_prev"]
        for c in range(n_chunks):
            acc = acc + env[f"pv{c}"]
        return {"acc_new": acc}

    instrs.append(Instr(
        name="accum", kind=Kind.COMPUTE,
        inputs=("corr", "acc_prev") + tuple(f"pv{c}" for c in range(n_chunks)),
        outputs=("acc_new",), fn=accumulate, flops=2 * bq * d * n_chunks))

    # ---- write-back -----------------------------------------------------------
    def st_stats(env):
        env["m_ref"][...] = env["m_new"]
        env["l_ref"][...] = env["l_new"]
        env["acc_ref"][...] = env["acc_new"]
        return {}

    instrs.append(Instr(name="st_stats", kind=Kind.COMPUTE,
                        inputs=("m_new", "l_new", "acc_new"), outputs=(),
                        fn=st_stats, buffer="stats", is_store=True, flops=0))

    def st_o(env):
        @pl.when(env["j"] == env["nkv"] - 1)
        def _():
            l_safe = jnp.maximum(env["l_new"], 1e-30)
            env["o_ref"][0] = (env["acc_new"] / l_safe).astype(dtype)
        return {}

    instrs.append(Instr(name="st_o", kind=Kind.MEM,
                        inputs=("acc_new", "l_new"), outputs=(),
                        fn=st_o, buffer="o", is_store=True,
                        bytes=bq * d * esize))
    return Program(instrs, replications=replications)


def pallas_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     bq: int, bk: int, n_chunks: int = 1,
                     causal: bool = True, window: int | None = None,
                     order=None, interpret: bool = INTERPRET) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0 and sq % bq == 0 and skv % bk == 0
    group = hq // hkv
    program = make_program(bq=bq, bk=bk, n_chunks=n_chunks, d=d, sq=sq,
                           skv=skv, causal=causal, window=window,
                           dtype=q.dtype)

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        env = {"q_ref": q_ref, "k_ref": k_ref, "v_ref": v_ref, "o_ref": o_ref,
               "m_ref": m_ref, "l_ref": l_ref, "acc_ref": acc_ref,
               "i": pl.program_id(1), "j": pl.program_id(2),
               "nkv": pl.num_programs(2)}
        program.execute(env, order)

    def kv_index(bh, i, j):
        return ((bh // hq) * hkv + (bh % hq) // group, j, 0)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        grid=(b * hq, sq // bq, skv // bk),
        in_specs=[pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
                  pl.BlockSpec((1, bk, d), kv_index),
                  pl.BlockSpec((1, bk, d), kv_index)],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
