"""SIP integration for the paged-KV gather (registry-based).

One kernel, ``paged_gather``: the page-table-indirect cache read the paged
serving path puts in front of attention.  Registered declaratively so
``launch/tune.py --smoke`` tunes it like any other kernel and the serving
engine resolves the ONE registry-cached instance bound to the active
``schedule_cache`` scope.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import KernelHandle, Workload, registry, sip_kernel
from repro.core.schedule import KnobSpec, Schedule, SearchSpace
from repro.kernels.paged_attention import kernel as K
from repro.kernels.paged_attention import ref

NAME = "paged_gather"


def _divisors(dim: int, prefs: tuple[int, ...]) -> tuple[int, ...]:
    ch = tuple(c for c in prefs if dim % c == 0 and c <= dim)
    return ch or (1,)


def space(*, p, ps, h, d, b, n, dtype="float32") -> SearchSpace:
    """Copy-tiling knobs: ``rows`` splits the page's ps positions into row
    blocks, ``n_chunks`` splits the head dim — together they set the tile
    count of the movable load/store stream."""
    return SearchSpace(knobs=(
        KnobSpec("rows", _divisors(ps, (1, 2, 4, 8))),
        KnobSpec("n_chunks", _divisors(d, (1, 2, 4))),
    ))


def _knobs(schedule: Schedule, **static):
    sp = space(**static)
    k = sp.default_knobs()
    k.update(schedule.knobs)
    return k["rows"], k["n_chunks"]


def program_for(schedule: Schedule, **static):
    rows, n_chunks = _knobs(schedule, **static)
    return K.make_program(ps=static["ps"], h=static["h"], d=static["d"],
                          rows=rows, n_chunks=n_chunks,
                          dtype=jnp.dtype(static["dtype"]),
                          total_pages=static["b"] * static["n"])


def signature_fn(store, page_table) -> dict:
    p, ps, h, d = store.shape
    b, n = page_table.shape
    return {"p": int(p), "ps": int(ps), "h": int(h), "d": int(d),
            "b": int(b), "n": int(n), "dtype": str(jnp.dtype(store.dtype))}


def _gather_args(p: int, ps: int, h: int, d: int, b: int, n: int):
    def make_args(rng: np.random.Generator):
        store = rng.standard_normal((p, ps, h, d)).astype(np.float32)
        pt = rng.integers(0, p, (b, n)).astype(np.int32)
        return [store, pt]
    return make_args


@sip_kernel(
    name=NAME, program_for=program_for, space_for=space,
    oracle=ref.paged_gather, signature_fn=signature_fn,
    workloads=[
        Workload("smoke_p8_ps8_h2_d8_b2_n4", _gather_args(8, 8, 2, 8, 2, 4),
                 suites=("smoke",)),
        Workload("deploy_p64_ps16_h4_d32_b8_n8",
                 _gather_args(64, 16, 4, 32, 8, 8)),
    ])
def build(schedule: Schedule, **static):
    rows, n_chunks = _knobs(schedule, **static)
    program = program_for(schedule, **static)
    order = schedule.resolve_order(program)
    fn = functools.partial(K.paged_gather, rows=rows, n_chunks=n_chunks,
                           order=order)
    return jax.jit(fn)


def kernel():
    """The shared registry instance bound to the active schedule cache —
    the serving resolution path."""
    return registry.get(NAME)


# late-binding handle: honors the schedule_cache scope active at call time
paged_gather = KernelHandle(NAME)
