"""Schedule-parameterized Pallas paged-KV gather.

``out[b, i] = store[page_table[b, i]]`` — the cache-read indirection that
paged serving memory puts on the decode hot path.  One grid step copies one
page; the page table rides in scalar-prefetch memory (SMEM), so the *input*
BlockSpec's index map is data-dependent — each step's DMA source block is
steered by ``pt_ref[b, i]`` at page granularity, the Pallas analogue of the
page-table walk a paged-attention CUDA kernel does per block.

The body is emitted from a :class:`~repro.core.ir.Program` whose
instructions are pure MEM traffic: the page is tiled into (row-block x
d-chunk) pieces, each moved by a load/store pair.  That tile set is SIP's
movable set — the stochastic search reorders the copy stream (e.g.
interleaving loads of tile ``i+1`` with the store of tile ``i``), the same
LDGSTS-style latency hiding the paper perturbs in SASS.  There is no
compute chain; the schedule family is all memory-level parallelism.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ir import Instr, Kind, Program

INTERPRET = jax.default_backend() != "tpu"


def make_program(*, ps: int, h: int, d: int, rows: int, n_chunks: int,
                 dtype=jnp.float32, total_pages: int = 1) -> Program:
    """The per-grid-step copy program: ``rows`` row-blocks x ``n_chunks``
    d-chunks, one (load, store) MEM pair per tile."""
    assert ps % rows == 0 and d % n_chunks == 0
    rb, cd = ps // rows, d // n_chunks
    esize = jnp.dtype(dtype).itemsize
    instrs: list[Instr] = []

    def ld(env, r, c):
        tile = env["store_ref"][0, pl.ds(r * rb, rb), :, pl.ds(c * cd, cd)]
        return {f"t{r}_{c}": tile}

    def st(env, r, c):
        env["out_ref"][0, 0, pl.ds(r * rb, rb), :, pl.ds(c * cd, cd)] = \
            env[f"t{r}_{c}"]
        return {}

    for r in range(rows):
        for c in range(n_chunks):
            nbytes = rb * h * cd * esize
            instrs.append(Instr(
                name=f"ld_r{r}c{c}", kind=Kind.MEM, inputs=(),
                outputs=(f"t{r}_{c}",), fn=functools.partial(ld, r=r, c=c),
                buffer="store", bytes=nbytes))
            instrs.append(Instr(
                name=f"st_r{r}c{c}", kind=Kind.MEM, inputs=(f"t{r}_{c}",),
                outputs=(), fn=functools.partial(st, r=r, c=c),
                buffer="out", is_store=True, bytes=nbytes))
    return Program(instrs, replications=total_pages)


def paged_gather(store: jax.Array, page_table: jax.Array, *,
                 rows: int, n_chunks: int, order=None,
                 interpret: bool = INTERPRET) -> jax.Array:
    """store: (P, ps, H, D); page_table: (B, n) int32 -> (B, n, ps, H, D)."""
    p, ps, h, d = store.shape
    b, n = page_table.shape
    program = make_program(ps=ps, h=h, d=d, rows=rows, n_chunks=n_chunks,
                           dtype=store.dtype, total_pages=b * n)

    def kernel(pt_ref, store_ref, out_ref):
        del pt_ref      # consumed by the BlockSpec index maps
        env = {"store_ref": store_ref, "out_ref": out_ref}
        program.execute(env, order)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n),
        in_specs=[pl.BlockSpec((1, ps, h, d),
                               lambda bi, i, pt_ref: (pt_ref[bi, i], 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, ps, h, d),
                               lambda bi, i, pt_ref: (bi, i, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, n, ps, h, d), store.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(page_table.astype(jnp.int32), store)
