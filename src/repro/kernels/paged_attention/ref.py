"""Oracle for the paged KV gather: a plain dense take along the page axis."""

from __future__ import annotations

import jax.numpy as jnp


def paged_gather(store: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """store: (P, ps, H, D); page_table: (B, n) int32 -> (B, n, ps, H, D).

    ``out[b, i] = store[page_table[b, i]]`` — the cache-read indirection of
    paged attention.  Reshaping the result to (B, n*ps, H, D) yields the
    per-slot contiguous KV view the dense attention math consumes.
    """
    return jnp.take(store, page_table, axis=0)
