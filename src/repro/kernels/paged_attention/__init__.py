"""Paged-attention cache gather: page-table-indirect KV reads as a
SIP-tunable Pallas kernel (kernel.py), its dense-gather oracle (ref.py),
and the registry integration (ops.py)."""
