"""Kernel packages.  Each compute hot-spot ships ``kernel.py`` (the Pallas
emission), ``ref.py`` (the oracle), and an integration module (``ops.py`` /
``pallas_ops.py``) that registers a declarative ``KernelSpec`` with
``repro.core.registry`` at import time.

Adding a kernel touches ONLY its own package: drop a new directory with an
integration module and :func:`load_all` discovers it — the tuning driver,
smoke CI, and deployment resolve it by name with no launcher edits.
"""

from __future__ import annotations

import importlib
import importlib.util
import pkgutil

# integration modules probed inside each kernel package, in import order
_INTEGRATION_MODULES = ("ops", "pallas_ops")


def load_all() -> list[str]:
    """Import every kernel package's integration module(s), registering
    their KernelSpecs.  Returns the registered kernel names.

    Fails loudly (instead of silently dropping a kernel from tuning/CI)
    when a kernel package has no integration module or registers nothing.
    """
    from repro.core.registry import registry

    for info in pkgutil.iter_modules(__path__):
        if not info.ispkg:
            continue
        found = False
        for mod in _INTEGRATION_MODULES:
            full = f"{__name__}.{info.name}.{mod}"
            if importlib.util.find_spec(full) is not None:
                importlib.import_module(full)
                found = True
        if not found:
            raise RuntimeError(
                f"kernel package {info.name!r} has no integration module "
                f"({' / '.join(_INTEGRATION_MODULES)})")
        prefix = f"{__name__}.{info.name}"
        if not any(s.module == prefix or s.module.startswith(prefix + ".")
                   for s in registry.specs()):
            raise RuntimeError(
                f"kernel package {info.name!r} registers no KernelSpec — "
                f"decorate its build factory with @sip_kernel (or call "
                f"registry.register) in its integration module")
    return registry.names()
