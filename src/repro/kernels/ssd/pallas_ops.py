"""SIP integration + chunked-SSD assembly for the Pallas intra-chunk kernel.

``ssd_chunked_pallas`` reproduces ops.ssd_chunked exactly, but computes the
quadratic intra-chunk term with the Pallas kernel (kernel.py); the chunk
states and inter-chunk recurrence stay in jnp (they are linear-cost)."""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jit import SipKernel
from repro.core.registry import KernelHandle, Workload, registry, sip_kernel
from repro.core.schedule import Schedule, SearchSpace
from repro.kernels.ssd import kernel as K
from repro.kernels.ssd import ops as jops

NAME = "ssd_intra_chunk"


def space(**static) -> SearchSpace:
    return SearchSpace()        # order-only (paper-faithful) space


def program_for(schedule: Schedule, *, g, q, h, p, n, dtype="float32"):
    return K.make_program(q=q, n=n, p=p, dtype=jnp.dtype(dtype), grid=g * h)


def signature_fn(xb, la, B, C) -> dict:
    g, q, h, p = xb.shape
    return {"g": int(g), "q": int(q), "h": int(h), "p": int(p),
            "n": int(B.shape[-1]), "dtype": str(jnp.dtype(xb.dtype))}


def _oracle(xb, la, B, C):
    """Pure-jnp intra-chunk reference (the y_diag term of ops.ssd_chunked)."""
    lam = jnp.moveaxis(la.astype(jnp.float32), -1, 1)      # (G, H, Q)
    Lm = jnp.exp(jops.segsum(lam))
    Lm = jnp.where(jnp.isfinite(Lm), Lm, 0.0)
    cb = jnp.einsum("gin,gjn->gij", C.astype(jnp.float32),
                    B.astype(jnp.float32))
    return jnp.einsum("gij,ghij,gjhp->gihp", cb, Lm,
                      xb.astype(jnp.float32)).astype(xb.dtype)


def _ssd_args(g: int, q: int, h: int, p: int, n: int):
    def make_args(rng: np.random.Generator):
        xb = rng.standard_normal((g, q, h, p)).astype(np.float32)
        la = -np.abs(rng.standard_normal((g, q, h))).astype(np.float32) * 0.1
        B = rng.standard_normal((g, q, n)).astype(np.float32) * 0.3
        C = rng.standard_normal((g, q, n)).astype(np.float32) * 0.3
        return [xb, la, B, C]
    return make_args


WORKLOADS = (
    Workload("smoke_g2_q8_h2_p4_n8", _ssd_args(2, 8, 2, 4, 8),
             suites=("smoke",)),
    Workload("deploy_g4_q16_h4_p8_n16", _ssd_args(4, 16, 4, 8, 16)),
)


def build(schedule: Schedule, *, g, q, h, p, n, dtype="float32"):
    program = program_for(schedule, g=g, q=q, h=h, p=p, n=n, dtype=dtype)
    order = schedule.resolve_order(program)
    return jax.jit(functools.partial(K.pallas_ssd_intra, order=order))


SPEC = sip_kernel(name=NAME, program_for=program_for, space_for=space,
                  oracle=_oracle, signature_fn=signature_fn,
                  workloads=WORKLOADS)(build)


def make(cache=None) -> SipKernel:
    """Deprecated pre-registry constructor (fresh, unshared instance)."""
    warnings.warn("ssd.pallas_ops.make() is deprecated; resolve the kernel "
                  "via repro.core.registry.registry.get(pallas_ops.NAME) "
                  "instead", DeprecationWarning, stacklevel=2)
    return SPEC.instantiate(cache=cache)


ssd_intra = KernelHandle(NAME)   # late-binding: honors the active schedule_cache


def ssd_chunked_pallas(x, dt, A, B, C, D, *, chunk: int = 64,
                       init_state=None, return_state: bool = False):
    """ops.ssd_chunked with the intra-chunk term on the Pallas kernel."""
    bt, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    f32 = jnp.float32
    xr = x.astype(f32).reshape(bt * nc, chunk, h, p)
    dtr = dt.astype(f32).reshape(bt * nc, chunk, h)
    Br = B.astype(f32).reshape(bt * nc, chunk, n)
    Cr = C.astype(f32).reshape(bt * nc, chunk, n)
    la = dtr * A.astype(f32)[None, None, :]
    xb = xr * dtr[..., None]

    # resolved through the registry at call time so an active schedule_cache
    # scope (serving with a persistent tuned store) is honored
    y_diag = registry.get(NAME)(xb, la, Br, Cr).reshape(bt, nc, chunk, h, p)

    # states + inter-chunk recurrence (identical to ops.ssd_chunked)
    la_b = la.reshape(bt, nc, chunk, h)
    xb_b = xb.reshape(bt, nc, chunk, h, p)
    Br_b = Br.reshape(bt, nc, chunk, n)
    Cr_b = Cr.reshape(bt, nc, chunk, n)
    cum = jnp.cumsum(la_b, axis=2)
    tail = jnp.exp(cum[:, :, -1:, :] - cum)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Br_b, tail, xb_b)
    chunk_decay = jnp.exp(cum[:, :, -1, :])
    if init_state is None:
        init_state = jnp.zeros((bt, h, n, p), f32)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry

    final, prev_states = jax.lax.scan(
        step, init_state.astype(f32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)
    in_decay = jnp.exp(cum)
    y_off = jnp.einsum("bcin,bcih,bchnp->bcihp", Cr_b, in_decay, prev_states)

    y = (y_diag.astype(f32) + y_off).reshape(bt, s, h, p)
    y = y + D.astype(f32)[None, None, :, None] * x.astype(f32)
    if return_state:
        return y, final
    return y
