"""Chunked SSD (state-space dual) — the efficient O(S·Q + S·N·P) algorithm.

Pure-JAX (differentiable, shardable under pjit); this is the production path
used by the mamba2/zamba2 models for both train and serve.  The recurrent
single-step form (`ssd_step`) drives decode with O(1) state.

The paper's SIP technique applies at the kernel level (attention / GEMM);
SSD here is substrate — see DESIGN.md §4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segsum(la: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} la[..., k] (j<=i).

    la: (..., Q) -> (..., Q, Q) lower-triangular log-decay matrix.
    """
    q = la.shape[-1]
    cum = jnp.cumsum(la, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
                *, chunk: int = 64,
                init_state: jnp.ndarray | None = None,
                return_state: bool = False):
    """x: (Bt,S,H,P); dt: (Bt,S,H); A: (H,); B,C: (Bt,S,N); D: (H,).

    Returns y (Bt,S,H,P) [and final state (Bt,H,N,P) if return_state].
    """
    bt, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32
    xr = x.astype(f32).reshape(bt, nc, chunk, h, p)
    dtr = dt.astype(f32).reshape(bt, nc, chunk, h)
    Br = B.astype(f32).reshape(bt, nc, chunk, n)
    Cr = C.astype(f32).reshape(bt, nc, chunk, n)
    la = dtr * A.astype(f32)[None, None, None, :]            # (b,c,q,h)
    xb = xr * dtr[..., None]                                  # dt-weighted input

    # ---- 1. intra-chunk (quadratic within chunk) ---------------------------
    Lm = jnp.exp(segsum(jnp.moveaxis(la, -1, -2)))            # (b,c,h,q,q)
    cb = jnp.einsum("bcin,bcjn->bcij", Cr, Br)                # (b,c,q,q)
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", cb, Lm, xb)

    # ---- 2. per-chunk final states -----------------------------------------
    cum = jnp.cumsum(la, axis=2)                              # (b,c,q,h)
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                   # decay to chunk end
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Br, tail, xb)

    # ---- 3. inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (b,c,h)
    if init_state is None:
        init_state = jnp.zeros((bt, h, n, p), f32)

    def step(carry, inp):
        st, dec = inp                                         # (b,h,n,p),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                     # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        step, init_state.astype(f32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)             # (b,c,h,n,p)

    # ---- 4. state -> output contribution ------------------------------------
    in_decay = jnp.exp(cum)                                    # decay from chunk start
    y_off = jnp.einsum("bcin,bcih,bchnp->bcihp", Cr, in_decay, prev_states)

    y = (y_diag + y_off).reshape(bt, s, h, p)
    y = y + D.astype(f32)[None, None, :, None] * x.astype(f32)
    if return_state:
        return y, final
    return y


def ssd_step(state: jnp.ndarray, x_t: jnp.ndarray, dt_t: jnp.ndarray,
             A: jnp.ndarray, B_t: jnp.ndarray, C_t: jnp.ndarray,
             D: jnp.ndarray):
    """One recurrent decode step.

    state: (Bt,H,N,P); x_t: (Bt,H,P); dt_t: (Bt,H); B_t, C_t: (Bt,N).
    Returns (new_state, y_t (Bt,H,P)).
    """
    f32 = jnp.float32
    xf, dtf = x_t.astype(f32), dt_t.astype(f32)
    dec = jnp.exp(dtf * A.astype(f32)[None, :])                      # (b,h)
    upd = jnp.einsum("bn,bhp->bhnp", B_t.astype(f32), xf * dtf[..., None])
    new_state = state * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C_t.astype(f32), new_state)
    y = y + D.astype(f32)[None, :, None] * xf
    return new_state, y
