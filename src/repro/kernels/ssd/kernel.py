"""Schedule-parameterized Pallas kernel for the SSD intra-chunk block.

The Mamba-2 chunked algorithm (ops.ssd_chunked) splits into an intra-chunk
quadratic part — for each (sequence-chunk, head): ``y = (C B^T ⊙ L) x`` with
L the cumulative-decay lower-triangular matrix — and a cheap inter-chunk
recurrence.  The quadratic part is the compute hot spot and maps cleanly to
one MXU-friendly Pallas body per (batch·chunk, head) grid cell.

As with the other kernels the body is emitted from a
:class:`~repro.core.ir.Program`: four MEM loads (C, B, decay, x) whose
placement SIP permutes against the two MXU dots and the VPU decay math.
This kernel has NO macro knobs (the chunk length is fixed by the caller) —
it exercises the paper-faithful, order-only search space.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ir import Instr, Kind, Program

INTERPRET = jax.default_backend() != "tpu"


def make_program(*, q: int, n: int, p: int, dtype=jnp.float32,
                 grid: int = 1) -> Program:
    esize = jnp.dtype(dtype).itemsize
    instrs: list[Instr] = []

    instrs.append(Instr(name="ld_c", kind=Kind.MEM, inputs=(), outputs=("c",),
                        fn=lambda env: {"c": env["c_ref"][0].astype(jnp.float32)},
                        buffer="c", bytes=q * n * esize))
    instrs.append(Instr(name="ld_b", kind=Kind.MEM, inputs=(), outputs=("b",),
                        fn=lambda env: {"b": env["b_ref"][0].astype(jnp.float32)},
                        buffer="b", bytes=q * n * esize))
    instrs.append(Instr(name="ld_la", kind=Kind.MEM, inputs=(), outputs=("la",),
                        fn=lambda env: {"la": env["la_ref"][0, 0].astype(jnp.float32)},
                        buffer="la", bytes=q * esize))
    instrs.append(Instr(name="ld_x", kind=Kind.MEM, inputs=(), outputs=("x",),
                        fn=lambda env: {"x": env["x_ref"][0, :, 0].astype(jnp.float32)},
                        buffer="x", bytes=q * p * esize))

    instrs.append(Instr(
        name="dot_cb", kind=Kind.COMPUTE, inputs=("c", "b"), outputs=("s",),
        fn=lambda env: {"s": jax.lax.dot_general(
            env["c"], env["b"], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)},
        flops=2 * q * q * n))

    def decay(env):
        cum = jnp.cumsum(env["la"], axis=0)               # (Q, 1)
        diff = cum - cum[:, 0][None, :]                    # (Q, Q) i,j
        mask = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >=
                jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
        return {"L": jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)}

    instrs.append(Instr(name="decay", kind=Kind.COMPUTE, inputs=("la",),
                        outputs=("L",), fn=decay, flops=4 * q * q))
    instrs.append(Instr(name="mask_mul", kind=Kind.COMPUTE, inputs=("s", "L"),
                        outputs=("w",),
                        fn=lambda env: {"w": env["s"] * env["L"]},
                        flops=q * q))
    instrs.append(Instr(
        name="dot_y", kind=Kind.COMPUTE, inputs=("w", "x"), outputs=("y",),
        fn=lambda env: {"y": jnp.dot(env["w"], env["x"],
                                     preferred_element_type=jnp.float32)},
        flops=2 * q * q * p))

    def store(env):
        env["o_ref"][0, :, 0] = env["y"].astype(dtype)
        return {}

    instrs.append(Instr(name="st_y", kind=Kind.MEM, inputs=("y",), outputs=(),
                        fn=store, buffer="o", is_store=True,
                        bytes=q * p * esize))
    return Program(instrs, replications=grid)


def pallas_ssd_intra(xb: jax.Array, la: jax.Array, B: jax.Array,
                     C: jax.Array, *, order=None,
                     interpret: bool = INTERPRET) -> jax.Array:
    """Intra-chunk SSD.  xb: (G, Q, H, P) dt-weighted inputs; la: (G, Q, H)
    log-decays; B, C: (G, Q, N).  G = batch*chunks.  Returns (G, Q, H, P)."""
    g, q, h, p = xb.shape
    n = B.shape[-1]
    program = make_program(q=q, n=n, p=p, dtype=xb.dtype)

    def kernel(c_ref, b_ref, la_ref, x_ref, o_ref):
        program.execute({"c_ref": c_ref, "b_ref": b_ref, "la_ref": la_ref,
                         "x_ref": x_ref, "o_ref": o_ref}, order)

    la3 = jnp.moveaxis(la, -1, 1)[..., None]      # (G, H, Q, 1)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"))
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((g, q, h, p), xb.dtype),
        grid=(g, h),
        in_specs=[pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
                  pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
                  pl.BlockSpec((1, 1, q, 1), lambda i, j: (i, j, 0, 0)),
                  pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0))],
        out_specs=pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
        interpret=interpret,
        **kwargs,
    )(C, B, la3, xb)
    return out
