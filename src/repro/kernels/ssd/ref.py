"""Naive O(S^2) oracle for the Mamba-2 SSD (state-space dual) operator.

The "attention form" of SSD [arXiv:2405.21060]: with per-step decay
``a_t = exp(dt_t * A_h)`` the output is

    y_i = sum_{j<=i} (C_i . B_j) * prod_{k=j+1..i} a_k * dt_j * x_j + D_h x_i

Used as the correctness oracle for the chunked implementation in ops.py.
"""

from __future__ import annotations

import jax.numpy as jnp


def ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
        C: jnp.ndarray, D: jnp.ndarray) -> jnp.ndarray:
    """x: (Bt, S, H, P); dt: (Bt, S, H) (post-softplus, > 0); A: (H,) (< 0);
    B, C: (Bt, S, N); D: (H,).  Returns (Bt, S, H, P)."""
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    la = dt * A.astype(jnp.float32)[None, None, :]          # log a_t  (Bt,S,H)
    cum = jnp.cumsum(la, axis=1)                            # (Bt,S,H)
    # L[b,h,i,j] = exp(cum_i - cum_j) for j <= i else 0
    Lm = cum[:, :, None, :] - cum[:, None, :, :]            # (Bt,S,S,H) i,j
    s = x.shape[1]
    mask = (jnp.arange(s)[:, None] >= jnp.arange(s)[None, :])[None, :, :, None]
    Lm = jnp.where(mask, jnp.exp(Lm), 0.0)
    cb = jnp.einsum("bin,bjn->bij", C.astype(jnp.float32),
                    B.astype(jnp.float32))                   # (Bt,S,S)
    w = cb[:, :, :, None] * Lm * dt[:, None, :, :]           # (Bt,S,S,H)
    y = jnp.einsum("bijh,bjhp->bihp", w, x)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x
    return y
