"""Manual tensor parallelism for the serving path (Megatron-style).

The training stack shards through GSPMD: ``shard`` constraints under
``mesh_rules`` let the compiler place collectives.  The serving decode step
wants the opposite trade — *explicit* collectives at the two per-layer seams
(attention output projection, MLP down projection) so their payloads can be
int8-compressed (``dist.collectives.compressed_psum``), which GSPMD cannot
express.  This module is that explicit path:

* :data:`TP_RULES` — the serving partition rules for a 1-D ``("model",)``
  mesh: attention heads / kv heads and the MLP hidden dim shard; embeddings,
  the vocab projection, experts and every SSM axis stay replicated, so the
  only cross-device traffic per layer is the two post-contraction psums
  (plus none at the logits: the lm_head is replicated, argmax is local).
* :func:`tp_context` — a contextvar scope entered INSIDE a ``shard_map``
  body while it traces; model code stays unconditional.
* :func:`tp_allreduce` — the seam primitive: identity without an active
  context (single-device and GSPMD paths pay nothing), ``jax.lax.psum`` or
  ``compressed_psum`` inside one.
* :func:`tp_eligible` — the gate: manual TP sums *partial* products, so a
  head/mlp dim that silently fell back to replication (divisibility) would
  be summed N times — every seam dimension must divide the mesh exactly or
  the engine falls back to GSPMD.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Iterator

import jax

from repro.dist.collectives import compressed_psum
from repro.models.config import ModelConfig

#: Serving tensor-parallel rules (1-D ``("model",)`` mesh).  Differences
#: from ``partition.DEFAULT_RULES`` are deliberate: ``experts`` replicate
#: (MoE routing/dispatch is replicated computation under manual TP — only
#: the expert FFN hidden dim shards), ``vocab`` replicates (local argmax,
#: no masked-gather embedding), and batch/SSM axes never shard.
TP_RULES: dict[str, Any] = {
    "batch": None,
    "embed": None,
    "vocab": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": None,
    "ssm_heads": None,
    "ssm_inner": None,
    "conv_ch": None,
    "act_seq": None,
    "seq": None,
    "kv_seq": None,
    "head_dim": None,
    "ssm_state": None,
    "layers": None,
    "embed_act": None,
}

#: model families the manual path covers (the attention families the
#: continuous engine's paged mode already serves)
TP_FAMILIES = ("dense", "moe", "vlm")

_TP: contextvars.ContextVar[tuple[str, bool, int] | None] = \
    contextvars.ContextVar("repro_dist_tp", default=None)


@contextlib.contextmanager
def tp_context(axis_name: str, *, compressed: bool = False,
               block: int = 64) -> Iterator[None]:
    """Activate the TP seams over mapped mesh axis ``axis_name``.

    Enter this inside the ``shard_map`` body (it is active while the body
    traces, which is when ``tp_allreduce`` call sites resolve).  With
    ``compressed`` the seams reduce through ``compressed_psum`` — int8
    payloads, bounded per-block error, only a win on small axes (see
    ``dist.collectives``); callers wanting bit-exact parity leave it off.
    """
    token = _TP.set((axis_name, compressed, block))
    try:
        yield
    finally:
        _TP.reset(token)


def tp_axis() -> str | None:
    """Mapped axis name of the active TP scope, or None."""
    ctx = _TP.get()
    return ctx[0] if ctx else None


def tp_allreduce(x: jax.Array) -> jax.Array:
    """Sum ``x``'s partial products over the TP axis (identity when no TP
    scope is active).  This is the one primitive model code calls — placed
    immediately after every contraction over a sharded dimension."""
    ctx = _TP.get()
    if ctx is None:
        return x
    axis, compressed, block = ctx
    if compressed:
        return compressed_psum(x, axis, block=block)
    return jax.lax.psum(x, axis)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def tp_specs(axes_tree):
    """Logical-axes tree -> PartitionSpec tree under :data:`TP_RULES`.

    No divisibility fallback on purpose — :func:`tp_eligible` already
    guarantees every seam dimension divides the mesh, and a silent
    replication here would corrupt the partial sums (see module docstring).
    """
    from jax.sharding import PartitionSpec as P

    return jax.tree.map(lambda la: P(*[TP_RULES.get(a) for a in la]),
                        axes_tree, is_leaf=_is_axes_leaf)


def tp_shardings(axes_tree, mesh):
    """Logical-axes tree -> NamedSharding tree under :data:`TP_RULES`."""
    from jax.sharding import NamedSharding

    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        tp_specs(axes_tree))


def tp_eligible(cfg: ModelConfig, n_shards: int) -> tuple[bool, str]:
    """Can ``cfg`` run the manual shard_map TP path over ``n_shards``?

    Returns ``(ok, reason)``; the reason names the first disqualifier so
    engine logs say *why* a mesh fell back to GSPMD.  The divisibility
    checks are load-bearing, not a preference: a seam dimension that does
    not divide the mesh would be silently replicated by the partition
    fallback, and ``tp_allreduce`` would then multiply its contribution by
    the mesh size.
    """
    if n_shards <= 1:
        return False, "mesh has no model-parallel extent"
    if cfg.family not in TP_FAMILIES:
        return False, (f"family {cfg.family!r} not in {TP_FAMILIES} "
                       f"(dense per-slot SSM/cross state)")
    if cfg.padded_heads:
        return False, ("padded_heads uses a q->kv head map built from "
                       "global head counts")
    for name, dim in (("n_heads", cfg.n_heads), ("n_kv_heads",
                                                 cfg.n_kv_heads),
                      ("d_ff", cfg.d_ff)):
        if dim % n_shards:
            return False, f"{name}={dim} not divisible by {n_shards} shards"
    return True, "ok"
