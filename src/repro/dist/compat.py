"""jax API drift shims.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``
along the way.  Everything in this repo (and its test subprocesses) goes
through this wrapper so the call sites are written against the new spelling
only.
"""

from __future__ import annotations

from typing import Any, Callable

import jax


def shard_map(f: Callable[..., Any], mesh, in_specs, out_specs,
              check_vma: bool = True):
    """``jax.shard_map`` with the modern signature on any supported jax."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError as e:  # top-level API but pre-rename kwarg
            if "check_vma" not in str(e):
                raise
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
