"""GPipe-style pipeline parallelism over one mesh axis.

``pipeline_apply`` runs S identical stages (stacked params, leading dim S)
over M microbatches on the mesh axis ``axis``: every step each device applies
its stage to the activation it holds, then rotates activations one stage
forward with ``ppermute``.  Stage 0 injects microbatch t at step t; stage S-1
emits microbatch t-(S-1) at step t; the fill/drain steps where a stage holds
no live microbatch are the schedule's bubble, ``bubble_fraction`` =
(S-1)/(M+S-1) of the S*(M+S-1) device-steps.

The whole schedule is a ``lax.scan`` of M+S-1 steps inside one ``shard_map``,
so it is differentiable end-to-end (ppermute transposes to the reverse
rotation) — the grad-parity test in tests/pipeline_subprocess.py relies on
exactly that.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map


def bubble_fraction(stages: int, microbatches: int) -> float:
    """Idle fraction of the GPipe schedule: (S-1)/(M+S-1)."""
    if stages <= 1:
        return 0.0
    return (stages - 1) / (microbatches + stages - 1)


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, x: jax.Array, *, mesh, axis: str,
                   n_micro: int) -> jax.Array:
    """Apply S stacked stages to ``x`` (batch-leading), pipelined over ``axis``.

    ``stage_params``: pytree whose leaves have leading dim S = mesh.shape[axis]
    (one slice per stage).  ``stage_fn(params_slice, h) -> h`` must preserve
    the activation shape.  ``x.shape[0]`` must divide into ``n_micro``
    microbatches.  Mesh axes other than ``axis`` replicate.
    """
    n_stages = mesh.shape[axis]
    batch = x.shape[0]
    if batch % n_micro:
        raise ValueError(f"batch {batch} not divisible by n_micro {n_micro}")
    x_micro = x.reshape((n_micro, batch // n_micro) + x.shape[1:])
    n_steps = n_micro + n_stages - 1

    def run(p_stages, xm):
        p_local = jax.tree.map(lambda a: a[0], p_stages)   # this stage's slice
        idx = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def step(carry, t):
            state, out = carry
            x_t = jax.lax.dynamic_index_in_dim(
                xm, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            x_t = jnp.where(t < n_micro, x_t, jnp.zeros_like(x_t))
            h = jnp.where(idx == 0, x_t, state)    # stage 0 injects; rest relay
            y = stage_fn(p_local, h)
            m = t - (n_stages - 1)                 # microbatch finishing now
            written = jax.lax.dynamic_update_index_in_dim(
                out, y, jnp.clip(m, 0, n_micro - 1), 0)
            out = jnp.where((idx == n_stages - 1) & (m >= 0), written, out)
            return (jax.lax.ppermute(y, axis, perm), out), None

        out0 = jnp.zeros(xm.shape, xm.dtype)
        state0 = jnp.zeros(xm.shape[1:], xm.dtype)
        (_, out), _ = jax.lax.scan(step, (state0, out0), jnp.arange(n_steps))
        # only the last stage holds real outputs; psum replicates them (the
        # other stages contribute zeros) so out_specs can be unsharded
        return jax.lax.psum(
            jnp.where(idx == n_stages - 1, out, jnp.zeros_like(out)), axis)

    y = shard_map(run, mesh, in_specs=(P(axis), P()), out_specs=P(),
                  check_vma=False)(stage_params, x_micro)
    return y.reshape((batch,) + y.shape[2:])
