"""Compressed collectives: per-block symmetric int8 quantization.

Gradient all-reduce is the bandwidth-dominant collective of data-parallel
training (see benchmarks/roofline.py); quantizing the payload to int8 cuts
every hop's bytes 4x at a bounded, test-asserted accuracy cost.

Scheme: flatten, pad to a multiple of ``block``, one float32 scale per block
(symmetric, scale = max|block| / 127) so the round-trip error of every
element is at most scale/2 = max|block|/254.  Zero blocks quantize to exact
zeros.  ``compressed_psum`` is the shard_map-level reduction built on it:
all-gather the int8 payload + scales, dequantize, and sum locally — the
result is value-replicated like a psum, and (like ``jax.lax.psum``) comes
back in the INPUT dtype: the f32 dequantize+accumulate is internal, so a
bf16 activation stays bf16 on the wire-facing API (dtype-parity is a tested
contract — a silent bf16 -> f32 widening would double every downstream
buffer the collective feeds).

Non-finite contract (also tested): quantization SANITIZES.  A NaN element
quantizes to 0 and ±Inf clamps to the block's finite-magnitude extreme;
scales are computed over finite elements only.  The failure mode this buys
out of: one overflowed activation would otherwise turn the block's scale
into NaN/Inf and poison all ``block`` elements (and, through a psum, every
shard's copy).  Serving collectives prefer bounded local error over
amplifying one bad element into a whole-block (then whole-mesh) corruption;
callers that want NaN *propagation* for divergence detection should check
finiteness before quantizing (the training nan-rollback path does).

Traffic honesty: the all-gather formulation moves ~(N-1)·|x| int8 bytes per
device on an N-way axis, vs ~8·|x| bytes for a ring fp32 all-reduce — it
only wins for small axes (N <= 8, e.g. a pod axis or a node-local replica
group), which is exactly where it is deployed and tested here.  Larger axes
need a quantized reduce-scatter (requantizing partial sums), which this
module does not implement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

QMAX = 127.0


def quantize_int8(x: jax.Array, block: int = 64):
    """x (any shape) -> (q int8 (nblocks, block), scales f32 (nblocks,), pad).

    ``pad`` is the (static) number of zero elements appended so the flat size
    divides ``block``; callers thread it to :func:`dequantize_int8`.

    Non-finite inputs are sanitized per element (see the module docstring):
    scales see only finite magnitudes, NaN quantizes to 0, ±Inf clamps to
    the block's finite extreme — one bad element can never corrupt its
    block's other ``block - 1`` elements.
    """
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    finite = jnp.isfinite(blocks)
    amax = jnp.max(jnp.where(finite, jnp.abs(blocks), 0.0), axis=1)
    scales = jnp.where(amax > 0, amax, 1.0) / QMAX
    # NaN -> 0 first (clip propagates NaN), then ±Inf -> ±amax
    blocks = jnp.where(jnp.isnan(blocks), 0.0, blocks)
    blocks = jnp.clip(blocks, -amax[:, None], amax[:, None])
    q = jnp.clip(jnp.round(blocks / scales[:, None]), -QMAX, QMAX)
    return q.astype(jnp.int8), scales.astype(jnp.float32), pad


def dequantize_int8(q: jax.Array, scales: jax.Array, pad: int,
                    shape, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_int8` (up to the per-block error bound)."""
    flat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str, block: int = 64) -> jax.Array:
    """Sum ``x`` over the mapped mesh axis with int8-compressed traffic.

    For use inside ``shard_map``: each shard quantizes its local value, the
    int8 payload and scales are all-gathered over ``axis_name``, and every
    shard dequantizes and sums — the result is replicated (like psum) with
    each hop carrying 1/4 of the fp32 bytes.  Only beneficial on small axes
    (see the module docstring's traffic accounting).
    """
    q, scales, pad = quantize_int8(x, block)
    qg = jax.lax.all_gather(q, axis_name)            # (N, nblocks, block) int8
    sg = jax.lax.all_gather(scales, axis_name)       # (N, nblocks)
    total = jnp.sum(qg.astype(jnp.float32) * sg[..., None], axis=0).reshape(-1)
    if pad:
        total = total[:-pad]
    # dtype parity with jax.lax.psum: the f32 dequantize+accumulate is an
    # internal detail — a bf16 input comes back bf16 (tested contract)
    return total.reshape(x.shape).astype(x.dtype)


def compression_ratio(x: jax.Array, block: int = 64) -> float:
    """Wire-bytes ratio of the compressed representation vs fp32."""
    n = x.size
    nblocks = -(-n // block)
    return (nblocks * block * 1 + nblocks * 4) / (n * 4)
