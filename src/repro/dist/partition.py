"""Logical-axis sharding (MaxText-style partition rules).

Model code names the *logical* meaning of every tensor dimension ("batch",
"embed", "mlp", ...); this module resolves those names to *mesh* axes
("pod", "data", "model") under a rule table.  Resolution enforces two
invariants GSPMD requires:

* a mesh axis is used at most once within one PartitionSpec (no-reuse);
* a dimension is only sharded if its size divides the product of the mesh
  axes assigned to it — otherwise axes are dropped innermost-first until it
  does (divisibility fallback), degenerating to replication.

``shard`` is the in-model constraint primitive: a no-op without an active
mesh (single-device tests), ``with_sharding_constraint`` under
``mesh_rules``.  The rules are data, not code — sequence parallelism, for
example, is just ``rules["seq"] = "model"`` (see launch/dryrun.py).
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any, Iterator, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec

# Logical axis -> mesh axis (or tuple of mesh axes, outermost first).
# ``None`` documents an axis that deliberately stays replicated/unsharded.
DEFAULT_RULES: dict[str, Any] = {
    # data-parallel axes
    "batch": ("pod", "data"),          # global batch over pod x data
    # fully-sharded (ZeRO/FSDP-style) parameter embed dim
    "embed": "data",
    # tensor/expert-parallel axes
    "vocab": "model",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "ssm_heads": "model",
    "ssm_inner": "model",
    "conv_ch": "model",
    # sequence parallelism: activations' seq dim when cfg.seq_shard is on
    "act_seq": "model",
    # replicated-by-default axes
    "seq": None,                       # input token dim (SP overrides to model)
    "kv_seq": None,                    # decode-cache length
    "head_dim": None,
    "ssm_state": None,
    "layers": None,                    # lax.scan stacking dim
    "embed_act": None,                 # activations' embed dim (residual)
}

#: serving rules: DEFAULT_RULES with the batch axis forced replicated — in
#: the continuous engine the cache "batch" dim is the slot (or page-id) axis,
#: spliced by per-request index at admission/eviction, and sharding it would
#: turn every slot insert into cross-device traffic.  The head-like axes
#: (kv_heads / heads / mlp / conv_ch ...) keep their "model" mapping, which
#: is the natural mesh seam for both the per-slot segments and the paged
#: flat store (the host-side page tables are shard-invariant page ids).
SERVE_RULES: dict[str, Any] = {**DEFAULT_RULES, "batch": None}

# --------------------------------------------------------------- active mesh
# contextvar (not a module global): concurrent mesh_rules scopes in different
# threads/tasks must not see each other's mesh
_ACTIVE: contextvars.ContextVar[tuple[tuple[Any, dict[str, Any]], ...]] = \
    contextvars.ContextVar("repro_dist_mesh_rules", default=())


@contextlib.contextmanager
def mesh_rules(mesh, rules: dict[str, Any] | None = None) -> Iterator[Any]:
    """Activate ``mesh`` (+ optional rule overrides) for a region of code.

    ``rules`` entries are merged over :data:`DEFAULT_RULES` (override an axis
    with ``None`` to force replication).  ``shard`` calls trace to
    ``with_sharding_constraint`` while a mesh is active and to the identity
    otherwise.  Reentrant; innermost wins.
    """
    entry = (mesh, {**DEFAULT_RULES, **(rules or {})})
    token = _ACTIVE.set(_ACTIVE.get() + (entry,))
    try:
        yield mesh
    finally:
        _ACTIVE.reset(token)


def active_mesh_rules() -> tuple[Any, dict[str, Any] | None]:
    """(mesh, rules) of the innermost ``mesh_rules`` scope, or (None, None)."""
    stack = _ACTIVE.get()
    return stack[-1] if stack else (None, None)


# ---------------------------------------------------------------- resolution
def resolve_spec(axes: Sequence[str | None], mesh,
                 shape: Sequence[int] | None = None,
                 rules: dict[str, Any] | None = None) -> PartitionSpec:
    """Logical axes -> PartitionSpec for ``mesh``.

    Mesh axes absent from ``mesh`` are dropped (e.g. "pod" on a single-pod
    mesh); a mesh axis already consumed by an earlier dimension of this spec
    is skipped; with ``shape``, assigned axes are dropped innermost-first
    until the dimension size divides their product.
    """
    rules = DEFAULT_RULES if rules is None else rules
    sizes = dict(mesh.shape)
    used: set[str] = set()
    entries: list[Any] = []
    for i, logical in enumerate(axes):
        target = rules.get(logical) if logical is not None else None
        if target is None:
            entries.append(None)
            continue
        cand = (target,) if isinstance(target, str) else tuple(target)
        chosen = [a for a in cand if a in sizes and a not in used]
        if shape is not None:
            while chosen and shape[i] % math.prod(sizes[a] for a in chosen):
                chosen.pop()
        if not chosen:
            entries.append(None)
            continue
        used.update(chosen)
        entries.append(chosen[0] if len(chosen) == 1 else tuple(chosen))
    return PartitionSpec(*entries)


def named_sharding(axes: Sequence[str | None], mesh,
                   shape: Sequence[int] | None = None,
                   rules: dict[str, Any] | None = None) -> NamedSharding:
    """NamedSharding for one tensor's logical axes on ``mesh``."""
    return NamedSharding(mesh, resolve_spec(axes, mesh, shape=shape,
                                            rules=rules))


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def tree_shardings(axes_tree: Any, mesh, *, sds_tree: Any = None,
                   rules: dict[str, Any] | None = None) -> Any:
    """Tree of logical-axes tuples -> matching tree of NamedShardings.

    ``sds_tree`` (same structure, ShapeDtypeStruct/array leaves) enables the
    divisibility fallback per leaf.
    """
    if sds_tree is None:
        return jax.tree.map(
            lambda ax: named_sharding(ax, mesh, rules=rules),
            axes_tree, is_leaf=_is_axes_leaf)
    return jax.tree.map(
        lambda ax, sds: named_sharding(ax, mesh, shape=sds.shape, rules=rules),
        axes_tree, sds_tree, is_leaf=_is_axes_leaf)


# ---------------------------------------------------------------- constraint
def shard(x, *axes: str | None):
    """Constrain activation ``x`` to its logical axes' sharding.

    Identity (returns ``x`` itself) when no mesh is active, so model code is
    unconditional and single-device paths pay nothing.
    """
    mesh, rules = active_mesh_rules()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(axes, mesh, shape=x.shape, rules=rules))
