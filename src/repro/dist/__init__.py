"""Distributed execution subsystem: sharding, collectives, pipelining.

Three modules, one contract — the same model/step code runs unsharded on a
single CPU device and fully sharded on the (pod, data, model) production
meshes:

* :mod:`repro.dist.partition` — MaxText-style logical-axis sharding rules,
  ``shard``/``named_sharding``/``tree_shardings`` resolution, and the
  ``mesh_rules`` context that activates a mesh for a region of code.
* :mod:`repro.dist.collectives` — per-block symmetric int8 gradient
  compression and a compressed ``psum`` for bandwidth-bound reductions.
* :mod:`repro.dist.pipeline` — GPipe-style pipeline parallelism over a mesh
  axis (``pipeline_apply``) plus bubble accounting.
* :mod:`repro.dist.tp` — manual (shard_map) tensor parallelism for the
  serving path: explicit per-layer allreduce seams that can run the
  compressed collective, where GSPMD could only place exact psums.

:mod:`repro.dist.compat` papers over jax API drift (``jax.shard_map`` vs
``jax.experimental.shard_map``) so callers never branch on version.
"""

from repro.dist import collectives, partition, pipeline, tp
from repro.dist.compat import shard_map
from repro.dist.partition import (DEFAULT_RULES, mesh_rules, named_sharding,
                                  resolve_spec, shard, tree_shardings)

__all__ = [
    "collectives", "partition", "pipeline", "tp", "shard_map",
    "DEFAULT_RULES", "mesh_rules", "named_sharding", "resolve_spec",
    "shard", "tree_shardings",
]
