"""Fixed-capacity slot allocator with FIFO admission — the bookkeeping core
of the continuous-batching engine, kept model-free so its invariants are
property-testable in isolation (tests/test_slot_allocator.py):

* no aliasing — a slot is held by at most one item at a time;
* FIFO admission — items are admitted strictly in submit order, even (and
  especially) under full occupancy;
* liveness — as long as slots keep being released, every submitted item is
  eventually admitted.

Under tensor-parallel serving the engine's cache shards on head-like axes
but never on the slot axis (``partition.SERVE_RULES`` forces "batch" to
replicate), so a slot index names the same batch row on every device and
this allocator runs unchanged on the host — admission/eviction decisions
are made once and apply to every shard.
"""

from __future__ import annotations

import collections
from typing import Any, Iterator


class SlotPool:
    """``capacity`` slots + a FIFO queue of waiting items.

    ``submit`` enqueues; ``admit`` pops waiting items into the lowest free
    slots (deterministic placement) and returns the ``(slot, item)`` pairs
    admitted now; ``release`` frees a slot for the next admission.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._free = list(range(capacity - 1, -1, -1))   # pop() -> lowest
        self._held: dict[int, Any] = {}                  # slot -> item
        self._queue: collections.deque[Any] = collections.deque()

    # ------------------------------------------------------------- queueing
    def submit(self, item: Any) -> None:
        self._queue.append(item)

    def admit(self) -> list[tuple[int, Any]]:
        admitted = []
        while self._queue and self._free:
            slot = self._free.pop()
            item = self._queue.popleft()
            self._held[slot] = item
            admitted.append((slot, item))
        return admitted

    def peek(self) -> Any | None:
        """The item at the head of the queue (the next FIFO admission), or
        None — lets resource-gated admission (the paged engine's page check)
        inspect head-of-line cost before committing a slot."""
        return self._queue[0] if self._queue else None

    def admit_one(self) -> tuple[int, Any] | None:
        """Admit exactly the head-of-line item into the lowest free slot, or
        None when the queue is empty / no slot is free.  With :meth:`peek`
        this is the FIFO-preserving building block for admission loops that
        must stop when some *other* resource (cache pages) runs out."""
        if not self._queue or not self._free:
            return None
        slot = self._free.pop()
        item = self._queue.popleft()
        self._held[slot] = item
        return slot, item

    def release(self, slot: int) -> Any:
        if slot not in self._held:
            raise KeyError(f"slot {slot} is not held")
        item = self._held.pop(slot)
        self._free.append(slot)
        self._free.sort(reverse=True)                    # keep lowest-first
        return item

    # -------------------------------------------------------------- queries
    def item(self, slot: int) -> Any:
        return self._held[slot]

    def held(self) -> Iterator[tuple[int, Any]]:
        return iter(sorted(self._held.items()))

    @property
    def occupancy(self) -> int:
        return len(self._held)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def idle(self) -> bool:
        return not self._held and not self._queue

    def __contains__(self, slot: int) -> bool:
        return slot in self._held

    def __repr__(self) -> str:
        return (f"SlotPool(capacity={self.capacity}, "
                f"occupancy={self.occupancy}, queued={self.queue_depth})")
