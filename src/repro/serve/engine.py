"""Serving engines over the SIP-tuned model stack.

Two engines share the jitted prefill/decode step functions (models/model.py —
the same functions the dry-run lowers, so schedules cached by SIP benefit
serving directly):

* :class:`Engine` — static batch: one prefill over (B, S) prompts, lockstep
  decode until every row stops.  Kept as the differential-correctness
  reference (single-request generation) and the throughput baseline.
* :class:`ContinuousEngine` — continuous batching: a FIFO request queue with
  slot-based admission into a fixed-capacity decode batch.  Each arriving
  request is prefilled alone (exact prompt length, batch 1), its KV/SSM cache
  segment is spliced into a free slot (models/model.py per-slot helpers), and
  all occupied slots decode in lockstep — finished slots are evicted and
  refilled from the queue without stalling the batch.  Per-request stop
  (eos / max tokens), streaming emission via ``on_token``, and a stats
  surface (queue depth, slot occupancy, prefill/decode split, tokens/s)
  built on :mod:`repro.obs` — counters/gauges/latency histograms in a
  metrics registry, prefill/decode spans on the active tracer, and an
  optional live-workload recorder (see :class:`ContinuousEngine`).

Kernel resolution happens at trace time, so wrap serving in
``repro.core.registry.schedule_cache(path)`` to serve SIP-tuned schedules on
the hot path (see launch/serve.py).  Registry handles are late-binding: a
scope entered before engine construction is honored, and tuning that bumps
``ScheduleCache.version`` mid-flight re-resolves on the next trace.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.recorder import WorkloadRecorder
from repro.serve.slots import SlotPool


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256              # per-slot cache length (prompt + new)
    temperature: float = 0.0        # 0 = greedy
    seed: int = 0
    capacity: int = 8               # decode-batch slots (ContinuousEngine)


class Engine:
    """Static-batch engine: one prefill, lockstep decode, whole batch stops
    together.  The B=1 case is the correctness reference for the
    continuous-batching engine."""

    def __init__(self, params, cfg: ModelConfig,
                 scfg: ServeConfig | None = None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg = ServeConfig() if scfg is None else scfg
        self._prefill = jax.jit(functools.partial(
            M.prefill, cfg=cfg, max_len=scfg.max_len))
        # donate the cache buffers: decode updates them in place instead of
        # copying the full KV tree every step
        self._decode = jax.jit(functools.partial(
            _decode_sample, cfg=cfg, temperature=scfg.temperature),
            donate_argnums=(1,))
        self.stats: dict[str, Any] = {"prefill_s": 0.0, "decode_s": 0.0,
                                      "tokens_out": 0}

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 extra_inputs: dict[str, Any] | None = None,
                 eos_id: int | None = None) -> np.ndarray:
        """prompts: (B, S) int32 -> (B, <=max_new_tokens) int32."""
        b = prompts.shape[0]
        inputs = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            inputs.update(extra_inputs)
        key = jax.random.PRNGKey(self.scfg.seed)

        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, inputs)
        jax.block_until_ready(logits)
        self.stats["prefill_s"] += time.perf_counter() - t0

        out = []
        token = _pick(logits, self.scfg.temperature, key)
        done = np.zeros(b, bool)
        t0 = time.perf_counter()
        for i in range(max_new_tokens):
            out.append(np.asarray(token))
            if eos_id is not None:
                done |= (out[-1] == eos_id)
                if done.all():
                    break
            key, sub = jax.random.split(key)
            token, caches = self._decode(self.params, caches, token, key=sub)
        jax.block_until_ready(token)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["tokens_out"] += int(np.size(out))
        return np.stack(out, axis=1)


def static_batches(prompts, budgets, capacity: int):
    """The static-batch baseline's serving plan: arrival-order chunks of
    ``capacity``, prompts left-padded to the batch max, each batch decoding
    to its largest budget.  Yields ``(padded_prompts, new_tokens, indices)``;
    shared by the traffic driver and the throughput benchmark so the
    baseline semantics exist exactly once."""
    for s in range(0, len(prompts), capacity):
        idxs = list(range(s, min(s + capacity, len(prompts))))
        plen = max(len(prompts[j]) for j in idxs)
        padded = np.zeros((len(idxs), plen), np.int32)
        for r, j in enumerate(idxs):
            padded[r, plen - len(prompts[j]):] = prompts[j]
        yield padded, max(budgets[j] for j in idxs), idxs


# ======================================================= continuous batching
@dataclasses.dataclass
class Request:
    """One generation request.  ``prompt`` is an unbatched (S,) token vector;
    ``extra`` holds unbatched per-request extra inputs (``enc_embeds`` for
    enc-dec archs, ``embeds`` for VLM embedding prompts) — the engine adds
    the batch axis."""

    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: int | None = None
    extra: dict[str, np.ndarray] | None = None
    # -- filled by the engine ------------------------------------------------
    tokens: list[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    admitted_at: float | None = None
    finished_at: float | None = None

    @property
    def done(self) -> bool:
        return self.finished_at is not None

    @property
    def output(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)


#: the engine's cumulative counters; ``stats`` assembles them in this order
_STAT_KEYS = ("prefill_s", "decode_s", "tokens_out", "prefill_tokens",
              "submitted", "admitted", "completed", "steps", "decode_steps",
              "occupancy_sum", "queue_depth_sum", "prefill_compiles")


def _ratio(num: float, den: float) -> float:
    """A derived rate that is well-defined 0.0 (never inf/NaN, never a
    division error) for zero-step/zero-token runs."""
    return num / den if den > 0 else 0.0


class ContinuousEngine:
    """Continuous-batching engine (see module docstring).

    One :meth:`step` = admit-from-queue (prefill each admitted request at its
    exact prompt length, splice into its slot, emit its first token) + one
    lockstep decode over the slot batch.  :meth:`run` steps until drained.
    Greedy decoding is token-identical to single-request
    ``Engine.generate`` for every request, whatever the arrival order —
    tests/test_serve_continuous.py holds the engine to that.

    Telemetry: every counter behind :attr:`stats` / :meth:`metrics` lives in
    a :class:`~repro.obs.metrics.MetricsRegistry` (``obs`` — engine-local by
    default so concurrent engines never share counters; pass one to fold a
    serve run into a wider snapshot), alongside TTFT / inter-token-latency /
    dispatch-time histograms and occupancy / queue-depth gauges.  Prefill
    and decode dispatches are traced as spans on the active
    ``repro.obs.trace`` tracer, and an optional :class:`WorkloadRecorder`
    logs the live (shape, dtype, occupancy) mix for offline tuning.
    """

    def __init__(self, params, cfg: ModelConfig,
                 scfg: ServeConfig | None = None,
                 example_extra: dict[str, np.ndarray] | None = None,
                 on_token: Callable[[Request, int], None] | None = None,
                 obs: obs_metrics.MetricsRegistry | None = None,
                 recorder: WorkloadRecorder | None = None):
        cfg.validate()
        self.params = params
        self.cfg = cfg
        self.scfg = scfg = ServeConfig() if scfg is None else scfg
        self.capacity = scfg.capacity
        self.on_token = on_token
        self.obs = obs if obs is not None else obs_metrics.MetricsRegistry()
        self.recorder = recorder
        self.pool = SlotPool(scfg.capacity)
        # conv-state shapes only stabilize once the prompt covers the conv
        # receptive field — shorter prompts would prefill a cache segment that
        # cannot be spliced into the fixed-shape slot batch
        self._min_prompt = (cfg.conv_width - 1
                            if cfg.family in ("ssm", "hybrid") else 1)
        s0 = min(max(8, self._min_prompt), scfg.max_len)
        example_inputs = {"tokens": np.zeros((1, s0), np.int32)}
        if example_extra:
            example_inputs.update(
                {k: np.asarray(v)[None] for k, v in example_extra.items()})
        self._example_extra_shapes = {
            k: tuple(np.asarray(v).shape) for k, v in (example_extra or {}).items()}
        self.caches, self._axes = M.alloc_slot_caches(
            params, cfg, scfg.capacity, scfg.max_len, example_inputs)
        self._prefill = jax.jit(functools.partial(
            M.prefill, cfg=cfg, max_len=scfg.max_len))
        # the slot batch is donated through decode and insert, so the steady
        # state mutates ONE cache allocation instead of copying the full
        # KV/SSM tree every step/admission
        self._decode = jax.jit(functools.partial(
            _decode_sample, cfg=cfg, temperature=scfg.temperature),
            donate_argnums=(1,))
        self._insert = jax.jit(
            lambda caches, grp, slots: M.insert_slots(caches, grp, slots,
                                                      self._axes),
            donate_argnums=(0,))
        self.tokens = np.zeros(scfg.capacity, np.int32)   # next decode inputs
        self._key = jax.random.PRNGKey(scfg.seed)
        self._uid = 0
        self._prefill_shapes_seen: set[tuple[int, int]] = set()
        self._c = {k: self.obs.counter(f"serve.{k}") for k in _STAT_KEYS}
        self._g_occupancy = self.obs.gauge("serve.occupancy")
        self._g_queue_depth = self.obs.gauge("serve.queue_depth")
        self._h_ttft = self.obs.histogram("serve.ttft_s")
        self._h_itl = self.obs.histogram("serve.inter_token_s")
        self._h_prefill = self.obs.histogram("serve.prefill_call_s")
        self._h_decode = self.obs.histogram("serve.decode_step_s")
        self._last_emit: dict[int, float] = {}   # uid -> last token time

    # -------------------------------------------------------------- ingress
    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               eos_id: int | None = None,
               extra: dict[str, np.ndarray] | None = None) -> Request:
        """Enqueue one request; returns its :class:`Request` handle."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        if len(prompt) < self._min_prompt:
            raise ValueError(
                f"{self.cfg.family} prompts need >= {self._min_prompt} "
                f"tokens (conv receptive field), got {len(prompt)}")
        if len(prompt) + max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len ({self.scfg.max_len})")
        got = {k: tuple(np.asarray(v).shape) for k, v in (extra or {}).items()}
        for k, shape in self._example_extra_shapes.items():
            # seq-varying extras (VLM embeds) follow the prompt; fixed-shape
            # extras (enc-dec context) must match the engine's allocation
            if k == "enc_embeds" and got.get(k) != shape:
                raise ValueError(f"extra {k!r} must have shape {shape}, "
                                 f"got {got.get(k)}")
        if "embeds" in got and got["embeds"][0] != len(prompt):
            # prefill advances the cache by the EMBEDS length, so a mismatch
            # would silently break the max_len/position accounting above
            raise ValueError(f"extra 'embeds' length {got['embeds'][0]} "
                             f"must match the prompt length {len(prompt)}")
        req = Request(uid=self._uid, prompt=prompt,
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      extra=extra, submitted_at=time.perf_counter())
        self._uid += 1
        self._c["submitted"].inc()
        if self.recorder is not None:
            self.recorder.record("submit", prompt_len=len(prompt),
                                 dtype=self.cfg.dtype,
                                 new_tokens=max_new_tokens,
                                 occupancy=self.pool.occupancy,
                                 queue_depth=self.pool.queue_depth)
        self.pool.submit(req)
        return req

    # ----------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """Admit + prefill waiting requests into free slots, then run one
        lockstep decode over the occupied batch.  Returns requests that
        finished during this step."""
        finished: list[Request] = []
        groups: dict[Any, list[tuple[int, Request]]] = {}
        for slot, req in self.pool.admit():
            # coalesce same-shape admissions into one batched prefill — the
            # per-row math is identical to batch-1, at one dispatch per group
            shape_key = (len(req.prompt),
                         tuple(sorted((k, np.asarray(v).shape)
                                      for k, v in (req.extra or {}).items())))
            groups.setdefault(shape_key, []).append((slot, req))
        for group in groups.values():
            self._admit_group(group, finished)
        if self.pool.occupancy:
            occ = self.pool.occupancy
            t0 = time.perf_counter()
            with obs_trace.span("serve.decode", occupancy=occ):
                self._key, sub = jax.random.split(self._key)
                tok, self.caches = self._decode(
                    self.params, self.caches, jnp.asarray(self.tokens),
                    key=sub)
                tok = np.asarray(tok)
            dt = time.perf_counter() - t0
            self._c["decode_s"].inc(dt)
            self._c["decode_steps"].inc()
            self._h_decode.record(dt)
            if self.recorder is not None:
                self.recorder.record("decode", batch=self.capacity,
                                     dtype=self.cfg.dtype, occupancy=occ,
                                     queue_depth=self.pool.queue_depth)
            for slot, req in list(self.pool.held()):
                self.tokens[slot] = int(tok[slot])
                self._emit(slot, req, int(tok[slot]), finished)
        self._c["steps"].inc()
        self._c["occupancy_sum"].inc(self.pool.occupancy)
        self._c["queue_depth_sum"].inc(self.pool.queue_depth)
        self._g_occupancy.set(self.pool.occupancy)
        self._g_queue_depth.set(self.pool.queue_depth)
        return finished

    def run(self, max_steps: int | None = None) -> dict[int, np.ndarray]:
        """Step until queue and slots drain; returns {uid: generated tokens}."""
        out: dict[int, np.ndarray] = {}
        steps = 0
        while not self.pool.idle:
            for req in self.step():
                out[req.uid] = req.output
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError(f"engine not drained after {max_steps} "
                                   f"steps ({self.pool!r})")
        return out

    # ------------------------------------------------------------ internals
    def _admit_group(self, group: list[tuple[int, Request]],
                     finished: list[Request]) -> None:
        t0 = time.perf_counter()
        slots = np.asarray([s for s, _ in group], np.int32)
        prompts = np.stack([r.prompt for _, r in group])
        inputs = {"tokens": jnp.asarray(prompts)}
        for k in (group[0][1].extra or {}):
            inputs[k] = jnp.asarray(
                np.stack([np.asarray(r.extra[k]) for _, r in group]))
        shape = (len(group), prompts.shape[1])
        if shape not in self._prefill_shapes_seen:
            self._prefill_shapes_seen.add(shape)
            self._c["prefill_compiles"].inc()
        with obs_trace.span("serve.prefill", batch=len(group),
                            prompt_len=int(prompts.shape[1])):
            logits, grp = self._prefill(self.params, inputs)
            self._key, sub = jax.random.split(self._key)
            toks = np.asarray(_pick(logits, self.scfg.temperature, sub))
            self.caches = self._insert(self.caches, grp, jnp.asarray(slots))
            jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self._c["prefill_s"].inc(dt)
        self._h_prefill.record(dt)
        self._c["prefill_tokens"].inc(int(prompts.size))
        self._c["admitted"].inc(len(group))
        if self.recorder is not None:
            self.recorder.record("prefill", prompt_len=int(prompts.shape[1]),
                                 batch=len(group), dtype=self.cfg.dtype,
                                 occupancy=self.pool.occupancy,
                                 queue_depth=self.pool.queue_depth)
        now = time.perf_counter()
        for (slot, req), tok in zip(group, toks):
            req.admitted_at = now
            self._h_ttft.record(now - req.submitted_at)
            self.tokens[slot] = int(tok)
            self._emit(slot, req, int(tok), finished)

    def _emit(self, slot: int, req: Request, tok: int,
              finished: list[Request]) -> None:
        req.tokens.append(tok)
        now = time.perf_counter()
        last = self._last_emit.get(req.uid)
        if last is not None:
            self._h_itl.record(now - last)
        self._last_emit[req.uid] = now
        self._c["tokens_out"].inc()
        if self.on_token is not None:
            self.on_token(req, tok)
        if (len(req.tokens) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            req.finished_at = time.perf_counter()
            self._last_emit.pop(req.uid, None)
            # eviction is lazy: a freed slot's stale state is confined to its
            # own batch row (per-slot masks/state), and the next admission's
            # insert overwrites the entire row — so completion costs no
            # cache-sized dispatch (models.evict_slot exists for callers that
            # want eager invalidation)
            self.pool.release(slot)
            self._c["completed"].inc()
            finished.append(req)

    # -------------------------------------------------------------- metrics
    @property
    def stats(self) -> dict[str, Any]:
        """Cumulative counters, assembled from the metrics registry (the
        registry instruments are the source of truth; this dict keeps the
        pre-registry read surface)."""
        return {k: c.value for k, c in self._c.items()}

    def reset_stats(self) -> None:
        """Zero the timing/gauge counters and latency histograms (e.g. after
        a warmup pass) while keeping compile bookkeeping, so metrics
        describe steady state."""
        keep = self._c["prefill_compiles"].value
        for c in self._c.values():
            c.reset()
        if keep:
            self._c["prefill_compiles"].inc(keep)
        for h in (self._h_ttft, self._h_itl, self._h_prefill, self._h_decode):
            h.reset()

    def metrics(self) -> dict[str, float]:
        """Derived serving metrics (gauge means are per engine step).

        Every ratio goes through :func:`_ratio`, so a never-stepped or
        zero-token engine reports well-defined 0.0 everywhere instead of
        raising or emitting inf/NaN."""
        s = self.stats
        busy = s["prefill_s"] + s["decode_s"]
        return {
            "queue_depth": float(self.pool.queue_depth),
            "slot_occupancy": float(self.pool.occupancy),
            "mean_occupancy": _ratio(s["occupancy_sum"], s["steps"]),
            "mean_queue_depth": _ratio(s["queue_depth_sum"], s["steps"]),
            "prefill_s": float(s["prefill_s"]),
            "decode_s": float(s["decode_s"]),
            "prefill_frac": _ratio(s["prefill_s"], busy),
            "tokens_per_s": _ratio(s["tokens_out"], busy),
            "decode_tokens_per_s": _ratio(s["tokens_out"] - s["admitted"],
                                          s["decode_s"]),
        }


def _decode_sample(params, caches, token, *, cfg: ModelConfig,
                   temperature: float, key):
    logits, caches = M.decode_step(params, caches, token, cfg)
    return _pick(logits, temperature, key), caches


def _pick(logits, temperature: float, key):
    if temperature and temperature > 0:
        return jax.random.categorical(key, logits / temperature, axis=-1)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
