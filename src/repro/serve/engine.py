"""Serving engines over the SIP-tuned model stack.

Two engines share the jitted prefill/decode step functions (models/model.py —
the same functions the dry-run lowers, so schedules cached by SIP benefit
serving directly):

* :class:`Engine` — static batch: one prefill over (B, S) prompts, lockstep
  decode until every row stops.  Kept as the differential-correctness
  reference (single-request generation) and the throughput baseline.
* :class:`ContinuousEngine` — continuous batching: a FIFO request queue with
  slot-based admission into a fixed-capacity decode batch.  Each arriving
  request is prefilled alone (exact prompt length, batch 1), its KV/SSM cache
  segment is spliced into a free slot (models/model.py per-slot helpers), and
  all occupied slots decode in lockstep — finished slots are evicted and
  refilled from the queue without stalling the batch.  Per-request stop
  (eos / max tokens), streaming emission via ``on_token``, and a stats
  surface (queue depth, slot occupancy, prefill/decode split, tokens/s)
  built on :mod:`repro.obs` — counters/gauges/latency histograms in a
  metrics registry, prefill/decode spans on the active tracer, and an
  optional live-workload recorder (see :class:`ContinuousEngine`).

  With ``ServeConfig(paged=True)`` the continuous engine swaps the per-slot
  contiguous cache segments for a paged KV store (``repro.serve.pages``):
  attention cache traffic goes through per-slot page tables over a shared
  page pool, admission reserves worst-case pages up front (decode never
  allocates), identical prompt prefixes share pages read-only through a
  content-hashed prefix cache, and long prompts optionally prefill in
  fixed-size chunks interleaved with decode (``prefill_chunk``).  Greedy
  outputs stay token-identical to the static reference engine —
  tests/test_serve_paged.py holds every paged mode to that.

  With a ``mesh`` the continuous engine serves tensor-parallel: params and
  every cache leaf (per-slot segments or the paged flat store, whose
  head axis is the natural mesh seam — the host-side page tables are
  shard-invariant page ids) carry NamedShardings, and prefill / chunked
  prefill / lockstep decode dispatch sharded.  TP-eligible attention configs
  (``dist.tp.tp_eligible``) run the manual shard_map path — the forward in
  one ``shard_map`` body with exactly two explicit psums per layer,
  optionally int8-compressed (``ServeConfig.compressed_collectives``) —
  and everything else falls back to GSPMD under ``partition.SERVE_RULES``.
  Greedy sharded output is token-identical to the 1-device engine
  (tests/test_sharding_multidevice.py::serve_sharded holds both cache
  layouts to that at two mesh shapes).

Kernel resolution happens at trace time, so wrap serving in
``repro.core.registry.schedule_cache(path)`` to serve SIP-tuned schedules on
the hot path (see launch/serve.py).  Registry handles are late-binding: a
scope entered before engine construction is honored, and tuning that bumps
``ScheduleCache.version`` mid-flight re-resolves on the next trace.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.registry import active_schedule_cache
from repro.dist import partition, tp
from repro.dist.compat import shard_map
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.recorder import WorkloadRecorder
from repro.serve.pages import PagePool, PagesExhausted, PrefixCache
from repro.serve.slots import SlotPool

#: paged serving supports the attention families; SSM/hybrid conv+state
#: caches and enc-dec cross context are dense per-slot state, and SWA ring
#: buffers already bound cache size by the window
PAGED_FAMILIES = ("dense", "moe", "vlm")


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256              # per-slot cache length (prompt + new)
    temperature: float = 0.0        # 0 = greedy
    seed: int = 0
    capacity: int = 8               # decode-batch slots (ContinuousEngine)
    # ---- paged KV cache (ContinuousEngine; see repro.serve.pages) --------
    paged: bool = False             # page the KV store instead of per-slot
                                    # contiguous max_len segments
    page_size: int = 16             # tokens per cache page
    num_pages: int | None = None    # page budget incl. the trash page;
                                    # None = capacity * ceil(max_len/page_size)
                                    # + 1 (contiguous-equivalent memory)
    prefill_chunk: int | None = None  # split prompts longer than this into
                                    # fixed-size chunks interleaved with
                                    # decode (bounds TTFT under long arrivals
                                    # AND prefill recompiles); None = whole-
                                    # prompt prefill dispatches
    prefix_cache: bool = True       # content-hashed prefix sharing (paged)
    admission: str = "queue"        # "queue": wait for pages/slots;
                                    # "reject": submit raises PagesExhausted
                                    # unless the request can start NOW
    # ---- tensor-parallel serving (ContinuousEngine(mesh=...)) ------------
    tp_mode: str = "auto"           # "auto": manual shard_map TP when the
                                    # config is eligible (dist.tp.tp_eligible)
                                    # else GSPMD; "shard_map"/"gspmd" force a
                                    # path (shard_map raises if ineligible)
    compressed_collectives: bool = False  # int8-compress the two per-layer
                                    # decode-seam psums (shard_map path only;
                                    # bounded error, NOT token-exact)
    compress_block: int = 64        # quantization block for compressed seams


class Engine:
    """Static-batch engine: one prefill, lockstep decode, whole batch stops
    together.  The B=1 case is the correctness reference for the
    continuous-batching engine."""

    def __init__(self, params, cfg: ModelConfig,
                 scfg: ServeConfig | None = None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg = ServeConfig() if scfg is None else scfg
        self._prefill = jax.jit(functools.partial(
            M.prefill, cfg=cfg, max_len=scfg.max_len))
        # donate the cache buffers: decode updates them in place instead of
        # copying the full KV tree every step
        self._decode = jax.jit(functools.partial(
            _decode_sample, cfg=cfg, temperature=scfg.temperature),
            donate_argnums=(1,))
        self.stats: dict[str, Any] = {"prefill_s": 0.0, "decode_s": 0.0,
                                      "tokens_out": 0}

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 extra_inputs: dict[str, Any] | None = None,
                 eos_id: int | None = None) -> np.ndarray:
        """prompts: (B, S) int32 -> (B, <=max_new_tokens) int32."""
        b = prompts.shape[0]
        inputs = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            inputs.update(extra_inputs)
        key = jax.random.PRNGKey(self.scfg.seed)

        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, inputs)
        jax.block_until_ready(logits)
        self.stats["prefill_s"] += time.perf_counter() - t0

        out = []
        token = _pick(logits, self.scfg.temperature, key)
        done = np.zeros(b, bool)
        t0 = time.perf_counter()
        for i in range(max_new_tokens):
            out.append(np.asarray(token))
            if eos_id is not None:
                done |= (out[-1] == eos_id)
                if done.all():
                    break
            key, sub = jax.random.split(key)
            token, caches = self._decode(self.params, caches, token, key=sub)
        jax.block_until_ready(token)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["tokens_out"] += int(np.size(out))
        return np.stack(out, axis=1)


def static_batches(prompts, budgets, capacity: int):
    """The static-batch baseline's serving plan: arrival-order chunks of
    ``capacity``, prompts left-padded to the batch max, each batch decoding
    to its largest budget.  Yields ``(padded_prompts, new_tokens, indices)``;
    shared by the traffic driver and the throughput benchmark so the
    baseline semantics exist exactly once."""
    for s in range(0, len(prompts), capacity):
        idxs = list(range(s, min(s + capacity, len(prompts))))
        plen = max(len(prompts[j]) for j in idxs)
        padded = np.zeros((len(idxs), plen), np.int32)
        for r, j in enumerate(idxs):
            padded[r, plen - len(prompts[j]):] = prompts[j]
        yield padded, max(budgets[j] for j in idxs), idxs


# ======================================================= continuous batching
@dataclasses.dataclass
class Request:
    """One generation request.  ``prompt`` is an unbatched (S,) token vector;
    ``extra`` holds unbatched per-request extra inputs (``enc_embeds`` for
    enc-dec archs, ``embeds`` for VLM embedding prompts) — the engine adds
    the batch axis."""

    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: int | None = None
    extra: dict[str, np.ndarray] | None = None
    # -- filled by the engine ------------------------------------------------
    tokens: list[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    admitted_at: float | None = None
    finished_at: float | None = None

    @property
    def done(self) -> bool:
        return self.finished_at is not None

    @property
    def output(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)


#: the engine's cumulative counters; ``stats`` assembles them in this order
_STAT_KEYS = ("prefill_s", "decode_s", "tokens_out", "prefill_tokens",
              "submitted", "admitted", "completed", "steps", "decode_steps",
              "occupancy_sum", "queue_depth_sum", "prefill_compiles",
              "prefix_hits", "prefix_tokens_saved", "chunk_steps",
              "schedule_swaps")


@dataclasses.dataclass
class _ChunkTask:
    """A slot mid chunked-prefill: the first ``pos`` prompt tokens are
    already in its pages (shared-prefix pages and/or completed chunks)."""

    req: Request
    slot: int
    pos: int


def _rep(tree):
    """Full-rank replicated PartitionSpecs for a pytree (shard_map in_specs
    for host-owned operands: tokens, page tables, masks, scalars)."""
    return jax.tree.map(lambda x: P(*([None] * jnp.ndim(x))), tree)


def _shape_key(req: Request) -> tuple:
    """Prefill-coalescing key: requests with equal keys compile and batch
    together."""
    return (len(req.prompt),
            tuple(sorted((k, np.asarray(v).shape)
                         for k, v in (req.extra or {}).items())))


def _ratio(num: float, den: float) -> float:
    """A derived rate that is well-defined 0.0 (never inf/NaN, never a
    division error) for zero-step/zero-token runs."""
    return num / den if den > 0 else 0.0


class ContinuousEngine:
    """Continuous-batching engine (see module docstring).

    One :meth:`step` = admit-from-queue (prefill each admitted request at its
    exact prompt length, splice into its slot, emit its first token) + one
    lockstep decode over the slot batch.  :meth:`run` steps until drained.
    Greedy decoding is token-identical to single-request
    ``Engine.generate`` for every request, whatever the arrival order —
    tests/test_serve_continuous.py holds the engine to that.

    Telemetry: every counter behind :attr:`stats` / :meth:`metrics` lives in
    a :class:`~repro.obs.metrics.MetricsRegistry` (``obs`` — engine-local by
    default so concurrent engines never share counters; pass one to fold a
    serve run into a wider snapshot), alongside TTFT / inter-token-latency /
    dispatch-time histograms and occupancy / queue-depth gauges.  Prefill
    and decode dispatches are traced as spans on the active
    ``repro.obs.trace`` tracer, and an optional :class:`WorkloadRecorder`
    logs the live (shape, dtype, occupancy) mix for offline tuning.
    """

    def __init__(self, params, cfg: ModelConfig,
                 scfg: ServeConfig | None = None,
                 example_extra: dict[str, np.ndarray] | None = None,
                 on_token: Callable[[Request, int], None] | None = None,
                 obs: obs_metrics.MetricsRegistry | None = None,
                 recorder: WorkloadRecorder | None = None,
                 mesh=None):
        cfg.validate()
        self.params = params
        self.cfg = cfg
        self.scfg = scfg = ServeConfig() if scfg is None else scfg
        self.capacity = scfg.capacity
        # tensor-parallel serving: with a mesh, params and every cache leaf
        # carry NamedShardings and the model dispatches run sharded — the
        # manual shard_map path when the config is TP-eligible (explicit
        # per-layer psums, optionally int8-compressed), GSPMD otherwise
        self.mesh = mesh
        self.tp_path: str | None = None
        self.tp_reason = ""
        if mesh is not None:
            self.tp_path, self.tp_reason = self._resolve_tp_path()
        elif scfg.compressed_collectives:
            raise ValueError("compressed_collectives requires a serving mesh "
                             "(the seams only exist on the shard_map path)")
        self.on_token = on_token
        self.obs = obs if obs is not None else obs_metrics.MetricsRegistry()
        self.recorder = recorder
        self.pool = SlotPool(scfg.capacity)
        # conv-state shapes only stabilize once the prompt covers the conv
        # receptive field — shorter prompts would prefill a cache segment that
        # cannot be spliced into the fixed-shape slot batch
        self._min_prompt = (cfg.conv_width - 1
                            if cfg.family in ("ssm", "hybrid") else 1)
        s0 = min(max(8, self._min_prompt), scfg.max_len)
        example_inputs = {"tokens": np.zeros((1, s0), np.int32)}
        if example_extra:
            example_inputs.update(
                {k: np.asarray(v)[None] for k, v in example_extra.items()})
        self._example_extra_shapes = {
            k: tuple(np.asarray(v).shape) for k, v in (example_extra or {}).items()}
        self.paged = scfg.paged
        if self.paged:
            if cfg.family not in PAGED_FAMILIES:
                raise ValueError(
                    f"paged serving supports {PAGED_FAMILIES}, not "
                    f"{cfg.family!r} (its decode state is dense per-slot)")
            if scfg.admission not in ("queue", "reject"):
                raise ValueError(f"admission must be 'queue' or 'reject', "
                                 f"got {scfg.admission!r}")
            if scfg.prefill_chunk is not None and scfg.prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{scfg.prefill_chunk}")
            ps = scfg.page_size
            self._n_slot_pages = -(-scfg.max_len // ps)
            num_pages = (scfg.num_pages if scfg.num_pages is not None
                         else scfg.capacity * self._n_slot_pages + 1)
            # page 0 is the trash page: a freed/idle slot's zeroed page-table
            # row makes its masked decode scatters land there harmlessly
            self.pages = PagePool(num_pages, ps, obs=self.obs)
            self.prefix = (PrefixCache(self.pages, obs=self.obs)
                           if scfg.prefix_cache else None)
            self.caches, self._axes = M.alloc_paged_caches(
                params, cfg, scfg.capacity, scfg.max_len, ps, num_pages,
                example_inputs)
            # host-side page tables, (capacity, n_slot_pages) int32 — passed
            # into every paged dispatch; a slot's row is zeroed while free
            self._pt = np.zeros((scfg.capacity, self._n_slot_pages), np.int32)
            self._slot_pages: dict[int, list[int]] = {}
            self._chunk_tasks: collections.deque[_ChunkTask] = \
                collections.deque()
            self._prefilling: set[int] = set()
        else:
            self.caches, self._axes = M.alloc_slot_caches(
                params, cfg, scfg.capacity, scfg.max_len, example_inputs)
        if mesh is not None:
            self._shard_state()
        self._make_dispatchers()
        # schedule hot-swap: kernel handles are late-binding, but jax.jit
        # memoizes traces by shape — a ScheduleCache version bump alone never
        # reaches an already-traced dispatch.  The engine snapshots the
        # active store's version here and _maybe_refresh_schedules() rebuilds
        # the jit wrappers when it moves, so the NEXT trace re-resolves every
        # kernel from the updated store (restart-free promotion; see
        # repro.autotune).
        self._sched_cache = active_schedule_cache()
        self._sched_version = (self._sched_cache.version
                               if self._sched_cache is not None else 0)
        self.tokens = np.zeros(scfg.capacity, np.int32)   # next decode inputs
        self._key = jax.random.PRNGKey(scfg.seed)
        self._uid = 0
        self._prefill_shapes_seen: set[tuple] = set()
        self._c = {k: self.obs.counter(f"serve.{k}") for k in _STAT_KEYS}
        self._g_occupancy = self.obs.gauge("serve.occupancy")
        self._g_queue_depth = self.obs.gauge("serve.queue_depth")
        if self.paged:
            self._g_page_occ = self.obs.gauge("serve.page_occupancy")
        self._h_ttft = self.obs.histogram("serve.ttft_s")
        self._h_itl = self.obs.histogram("serve.inter_token_s")
        self._h_prefill = self.obs.histogram("serve.prefill_call_s")
        self._h_decode = self.obs.histogram("serve.decode_step_s")
        self._last_emit: dict[int, float] = {}   # uid -> last token time

    # ------------------------------------------------------- sharded serving
    def _resolve_tp_path(self) -> tuple[str, str]:
        """Pick the sharded execution path for ``self.mesh`` per
        ``scfg.tp_mode`` (see :mod:`repro.dist.tp` for the eligibility
        rationale).  Returns ``(path, reason)``."""
        scfg, mesh = self.scfg, self.mesh
        if "model" not in mesh.axis_names:
            raise ValueError(f"serving mesh needs a 'model' axis, got "
                             f"{mesh.axis_names}")
        ok, reason = tp.tp_eligible(self.cfg, mesh.shape["model"])
        if scfg.tp_mode == "shard_map":
            if not ok:
                raise ValueError(f"tp_mode='shard_map' but {reason}")
            path = "shard_map"
        elif scfg.tp_mode == "gspmd":
            path = "gspmd"
        elif scfg.tp_mode == "auto":
            path = "shard_map" if ok else "gspmd"
        else:
            raise ValueError(f"tp_mode must be 'auto'/'shard_map'/'gspmd', "
                             f"got {scfg.tp_mode!r}")
        if scfg.compressed_collectives and path != "shard_map":
            raise ValueError(f"compressed_collectives needs the shard_map TP "
                             f"path ({reason})")
        return path, reason

    def _shard_state(self) -> None:
        """Move params and the freshly allocated slot/page caches onto the
        serving mesh.  Admission never materializes an unsharded cache after
        this: every dispatcher pins its cache outputs back to these
        shardings, and splicing (insert/evict/set_len) runs on the sharded
        buffers in place."""
        mesh, cfg = self.mesh, self.cfg
        paxes = M.param_logical_axes(cfg)
        caxes = M.serve_cache_axes(cfg, self._axes)
        self._grp_axes = M.cache_logical_axes(cfg)
        if self.tp_path == "shard_map":
            self._pspecs = tp.tp_specs(paxes)
            self._cspecs = tp.tp_specs(caxes)
            self._grp_specs = tp.tp_specs(self._grp_axes)
            pshard = tp.tp_shardings(paxes, mesh)
            cshard = tp.tp_shardings(caxes, mesh)
        else:
            rules = partition.SERVE_RULES
            pshard = partition.tree_shardings(paxes, mesh,
                                              sds_tree=self.params,
                                              rules=rules)
            cshard = partition.tree_shardings(caxes, mesh,
                                              sds_tree=self.caches,
                                              rules=rules)
        self.params = jax.device_put(self.params, pshard)
        self.caches = jax.device_put(self.caches, cshard)
        self._cache_shardings = cshard

    def _seams(self):
        """The manual-TP scope every shard_map body runs under."""
        return tp.tp_context("model",
                             compressed=self.scfg.compressed_collectives,
                             block=self.scfg.compress_block)

    def _pin_slot_caches(self, caches):
        """Constrain a slot/page cache tree back to the engine's shardings
        (inside a traced fn) so splice outputs keep the mesh layout and
        decode's donation reuses the same sharded buffers."""
        return jax.tree.map(jax.lax.with_sharding_constraint, caches,
                            self._cache_shardings)

    def _pin_group_caches(self, caches):
        """Same, for a group-sized prefill cache (GSPMD path; trace-time
        shapes drive the divisibility fallback per leaf)."""
        return jax.tree.map(
            lambda ax, x: jax.lax.with_sharding_constraint(
                x, partition.named_sharding(ax, self.mesh, shape=x.shape,
                                            rules=partition.SERVE_RULES)),
            self._grp_axes, caches, is_leaf=partition._is_axes_leaf)

    def _build_prefill(self, max_len: int):
        """One prefill dispatcher at ``max_len`` for the active path —
        single-device, GSPMD (traced under mesh_rules so the model's shard
        constraints activate), or manual shard_map TP (the whole forward in
        one shard_map body, seams reduced via tp_allreduce)."""
        cfg, mesh = self.cfg, self.mesh
        if mesh is None:
            return jax.jit(functools.partial(M.prefill, cfg=cfg,
                                             max_len=max_len))
        if self.tp_path == "shard_map":
            def tp_prefill(params, inputs):
                def body(p, i):
                    with self._seams():
                        return M.prefill(p, i, cfg, max_len=max_len)
                return shard_map(
                    body, mesh=mesh, in_specs=(self._pspecs, _rep(inputs)),
                    out_specs=(P(), self._grp_specs),
                    check_vma=False)(params, inputs)
            return jax.jit(tp_prefill)

        def gs_prefill(params, inputs):
            with partition.mesh_rules(mesh, partition.SERVE_RULES):
                logits, caches = M.prefill(params, inputs, cfg,
                                           max_len=max_len)
                return logits, self._pin_group_caches(caches)
        return jax.jit(gs_prefill)

    def _make_dispatchers(self) -> None:
        """(Re)create the jitted step functions.  Called at construction and
        again on schedule hot-swap: fresh jax.jit wrappers mean fresh trace
        caches, so every kernel re-resolves against the current
        ScheduleCache contents on its next dispatch."""
        cfg, scfg = self.cfg, self.scfg
        if self.mesh is not None and self.tp_path == "shard_map":
            self._make_tp_dispatchers()
            return
        if self.mesh is not None:
            self._make_gspmd_dispatchers()
            return
        if self.paged:
            # paged prefill compiles once per page-rounded prompt length (or
            # per chunk shape) — these jits are keyed by that rounded length
            self._prefill_by_len: dict[int, Any] = {}
            self._decode = jax.jit(functools.partial(
                _decode_sample_paged, cfg=cfg, temperature=scfg.temperature),
                donate_argnums=(1,))
            self._insert_pages = jax.jit(
                lambda caches, grp, slots, pages: M.insert_pages(
                    caches, grp, slots, pages, self._axes),
                donate_argnums=(0,))
            self._set_len = jax.jit(
                lambda caches, slot, value: M.set_slot_lens(
                    caches, slot, value, self._axes),
                donate_argnums=(0,))
            self._chunk = jax.jit(functools.partial(
                M.prefill_chunk, cfg=cfg, axes=self._axes),
                donate_argnums=(1,))
        else:
            self._prefill = jax.jit(functools.partial(
                M.prefill, cfg=cfg, max_len=scfg.max_len))
            # the slot batch is donated through decode and insert, so the
            # steady state mutates ONE cache allocation instead of copying
            # the full KV/SSM tree every step/admission
            self._decode = jax.jit(functools.partial(
                _decode_sample, cfg=cfg, temperature=scfg.temperature),
                donate_argnums=(1,))
            self._insert = jax.jit(
                lambda caches, grp, slots: M.insert_slots(caches, grp, slots,
                                                          self._axes),
                donate_argnums=(0,))

    def _make_gspmd_dispatchers(self) -> None:
        """Sharded dispatchers, GSPMD path: the existing step functions
        traced under ``mesh_rules(SERVE_RULES)`` (activating the model's
        ``shard`` constraints) with cache outputs pinned to the engine's
        shardings — the compiler places the collectives."""
        cfg, scfg, mesh = self.cfg, self.scfg, self.mesh
        rules = partition.SERVE_RULES
        if self.paged:
            self._prefill_by_len = {}

            def gs_decode(params, caches, token, pt, active, *, key):
                with partition.mesh_rules(mesh, rules):
                    tok, caches = _decode_sample_paged(
                        params, caches, token, pt, active, cfg=cfg,
                        temperature=scfg.temperature, key=key)
                    return tok, self._pin_slot_caches(caches)
            self._decode = jax.jit(gs_decode, donate_argnums=(1,))
            self._insert_pages = jax.jit(
                lambda caches, grp, slots, pages: self._pin_slot_caches(
                    M.insert_pages(caches, grp, slots, pages, self._axes)),
                donate_argnums=(0,))
            self._set_len = jax.jit(
                lambda caches, slot, value: self._pin_slot_caches(
                    M.set_slot_lens(caches, slot, value, self._axes)),
                donate_argnums=(0,))

            def gs_chunk(params, caches, tokens, pt_row, slot, n_valid,
                         embeds=None):
                with partition.mesh_rules(mesh, rules):
                    last, caches = M.prefill_chunk(
                        params, caches, tokens, pt_row, slot, n_valid,
                        cfg=cfg, axes=self._axes, embeds=embeds)
                    return last, self._pin_slot_caches(caches)
            self._chunk = jax.jit(gs_chunk, donate_argnums=(1,))
        else:
            self._prefill = self._build_prefill(scfg.max_len)

            def gs_decode(params, caches, token, *, key):
                with partition.mesh_rules(mesh, rules):
                    tok, caches = _decode_sample(
                        params, caches, token, cfg=cfg,
                        temperature=scfg.temperature, key=key)
                    return tok, self._pin_slot_caches(caches)
            self._decode = jax.jit(gs_decode, donate_argnums=(1,))
            self._insert = jax.jit(
                lambda caches, grp, slots: self._pin_slot_caches(
                    M.insert_slots(caches, grp, slots, self._axes)),
                donate_argnums=(0,))

    def _make_tp_dispatchers(self) -> None:
        """Sharded dispatchers, manual shard_map TP path: each model forward
        runs as one shard_map body under ``tp_context`` — heads/kv-heads and
        the MLP hidden dim are mesh-local, and the only collectives are the
        two explicit per-layer ``tp_allreduce`` seams (exact psum, or
        ``compressed_psum`` when ``scfg.compressed_collectives``).  Sampling
        stays outside the shard_map on the replicated logits.  Cache
        splicing has no seam dimension contraction, so it stays a plain
        GSPMD jit pinned to the slot-cache shardings."""
        cfg, scfg, mesh = self.cfg, self.scfg, self.mesh
        pspecs, cspecs = self._pspecs, self._cspecs
        if self.paged:
            self._prefill_by_len = {}

            def tp_decode(params, caches, token, pt, active, *, key):
                def body(p, c, t, ptt, act):
                    with self._seams():
                        return M.decode_step(p, c, t, cfg, pt=ptt, active=act)
                logits, caches = shard_map(
                    body, mesh=mesh,
                    in_specs=(pspecs, cspecs, _rep(token), _rep(pt),
                              _rep(active)),
                    out_specs=(P(), cspecs), check_vma=False)(
                        params, caches, token, pt, active)
                return _pick(logits, scfg.temperature, key), caches
            self._decode = jax.jit(tp_decode, donate_argnums=(1,))
            self._insert_pages = jax.jit(
                lambda caches, grp, slots, pages: self._pin_slot_caches(
                    M.insert_pages(caches, grp, slots, pages, self._axes)),
                donate_argnums=(0,))
            self._set_len = jax.jit(
                lambda caches, slot, value: self._pin_slot_caches(
                    M.set_slot_lens(caches, slot, value, self._axes)),
                donate_argnums=(0,))

            def tp_chunk(params, caches, tokens, pt_row, slot, n_valid,
                         embeds=None):
                args = (params, caches, tokens, pt_row, slot, n_valid)
                specs = (pspecs, cspecs, _rep(tokens), _rep(pt_row), P(), P())
                if embeds is not None:
                    args += (embeds,)
                    specs += (_rep(embeds),)

                def body(p, c, t, ptr, s, nv, *e):
                    with self._seams():
                        return M.prefill_chunk(
                            p, c, t, ptr, s, nv, cfg=cfg, axes=self._axes,
                            embeds=e[0] if e else None)
                return shard_map(body, mesh=mesh, in_specs=specs,
                                 out_specs=(P(), cspecs),
                                 check_vma=False)(*args)
            self._chunk = jax.jit(tp_chunk, donate_argnums=(1,))
        else:
            self._prefill = self._build_prefill(scfg.max_len)

            def tp_decode(params, caches, token, *, key):
                def body(p, c, t):
                    with self._seams():
                        return M.decode_step(p, c, t, cfg)
                logits, caches = shard_map(
                    body, mesh=mesh, in_specs=(pspecs, cspecs, _rep(token)),
                    out_specs=(P(), cspecs), check_vma=False)(
                        params, caches, token)
                return _pick(logits, scfg.temperature, key), caches
            self._decode = jax.jit(tp_decode, donate_argnums=(1,))
            self._insert = jax.jit(
                lambda caches, grp, slots: self._pin_slot_caches(
                    M.insert_slots(caches, grp, slots, self._axes)),
                donate_argnums=(0,))

    def _maybe_refresh_schedules(self) -> None:
        """Pick up ScheduleCache changes without a restart: when the store
        the engine was constructed under has a newer version (an autotune
        promotion, or a tuning session sharing the store), drop every traced
        dispatch and rebuild, so subsequent prefills/decodes trace against
        the new schedules.  KV caches, page tables, slots and in-flight
        requests are untouched — only the compiled functions turn over.

        Polled before EVERY dispatch (admission prefill, chunked prefill,
        decode), not just at the top of :meth:`step`: commits can land
        mid-step — an autotune thread promoting between the admission
        prefill and the decode dispatch, or an ``on_token`` callback
        committing during emission — and a top-of-step-only poll would serve
        the rest of that step (and any dispatch the step path skips) on
        stale schedules."""
        cache = self._sched_cache
        if cache is None or not cache.changed_since(self._sched_version):
            return
        self._sched_version = cache.version
        self._c["schedule_swaps"].inc()
        # compile accounting restarts with the trace caches
        self._prefill_shapes_seen.clear()
        self._make_dispatchers()
        obs_trace.instant("serve.schedule_swap", version=cache.version)

    # -------------------------------------------------------------- ingress
    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               eos_id: int | None = None,
               extra: dict[str, np.ndarray] | None = None) -> Request:
        """Enqueue one request; returns its :class:`Request` handle."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        if len(prompt) < self._min_prompt:
            raise ValueError(
                f"{self.cfg.family} prompts need >= {self._min_prompt} "
                f"tokens (conv receptive field), got {len(prompt)}")
        total = len(prompt) + max_new_tokens
        if not self.paged:
            if total > self.scfg.max_len:
                raise ValueError(
                    f"prompt ({len(prompt)}) + max_new_tokens "
                    f"({max_new_tokens}) exceeds max_len "
                    f"({self.scfg.max_len})")
        else:
            # paged admission is a CAPACITY check, not a length check: the
            # hard bound is the per-slot page table (page-rounded, so a few
            # tokens past max_len that still fit the last page are fine);
            # whether the request can start is a question about free pages,
            # answered per the admission policy
            ps = self.pages.page_size
            bound = self._n_slot_pages * ps
            if total > bound:
                raise ValueError(
                    f"prompt ({len(prompt)}) + max_new_tokens "
                    f"({max_new_tokens}) exceeds the per-slot page table "
                    f"({self._n_slot_pages} pages x {ps} = {bound} tokens)")
            worst = -(-total // ps)
            if worst > self.pages.usable_pages:
                raise ValueError(
                    f"request needs {worst} pages but the pool has only "
                    f"{self.pages.usable_pages} usable — it could never be "
                    f"admitted; raise num_pages")
            if self.scfg.admission == "reject" and not self._admissible(worst):
                raise PagesExhausted(
                    f"request needs {worst} pages now but "
                    f"free={self.pages.free_pages} + evictable="
                    f"{self.prefix.evictable_pages if self.prefix else 0}, "
                    f"free_slots={self.pool.free_slots}, "
                    f"queued={self.pool.queue_depth} — resubmit later or "
                    f"serve with admission='queue'")
        got = {k: tuple(np.asarray(v).shape) for k, v in (extra or {}).items()}
        for k, shape in self._example_extra_shapes.items():
            # seq-varying extras (VLM embeds) follow the prompt; fixed-shape
            # extras (enc-dec context) must match the engine's allocation
            if k == "enc_embeds" and got.get(k) != shape:
                raise ValueError(f"extra {k!r} must have shape {shape}, "
                                 f"got {got.get(k)}")
        if "embeds" in got and got["embeds"][0] != len(prompt):
            # prefill advances the cache by the EMBEDS length, so a mismatch
            # would silently break the max_len/position accounting above
            raise ValueError(f"extra 'embeds' length {got['embeds'][0]} "
                             f"must match the prompt length {len(prompt)}")
        req = Request(uid=self._uid, prompt=prompt,
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      extra=extra, submitted_at=time.perf_counter())
        self._uid += 1
        self._c["submitted"].inc()
        if self.recorder is not None:
            self.recorder.record("submit", prompt_len=len(prompt),
                                 dtype=self.cfg.dtype,
                                 new_tokens=max_new_tokens,
                                 occupancy=self.pool.occupancy,
                                 queue_depth=self.pool.queue_depth)
        self.pool.submit(req)
        return req

    # ----------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """Admit + prefill waiting requests into free slots, then run one
        lockstep decode over the occupied batch.  Returns requests that
        finished during this step."""
        self._maybe_refresh_schedules()
        finished: list[Request] = []
        if self.paged:
            self._admit_paged(finished)
            if self._chunk_tasks:
                self._chunk_step(finished)
            self._decode_paged(finished)
            self._g_page_occ.set(_ratio(self.pages.used_pages,
                                        self.pages.usable_pages))
        else:
            groups: dict[Any, list[tuple[int, Request]]] = {}
            for slot, req in self.pool.admit():
                # coalesce same-shape admissions into one batched prefill —
                # the per-row math is identical to batch-1, at one dispatch
                # per group
                groups.setdefault(_shape_key(req), []).append((slot, req))
            for group in groups.values():
                self._admit_group(group, finished)
            if self.pool.occupancy:
                self._maybe_refresh_schedules()
                occ = self.pool.occupancy
                t0 = time.perf_counter()
                with obs_trace.span("serve.decode", occupancy=occ):
                    self._key, sub = jax.random.split(self._key)
                    tok, self.caches = self._decode(
                        self.params, self.caches, jnp.asarray(self.tokens),
                        key=sub)
                    tok = np.asarray(tok)
                dt = time.perf_counter() - t0
                self._c["decode_s"].inc(dt)
                self._c["decode_steps"].inc()
                self._h_decode.record(dt)
                if self.recorder is not None:
                    self.recorder.record("decode", batch=self.capacity,
                                         dtype=self.cfg.dtype, occupancy=occ,
                                         queue_depth=self.pool.queue_depth)
                for slot, req in list(self.pool.held()):
                    self.tokens[slot] = int(tok[slot])
                    self._emit(slot, req, int(tok[slot]), finished)
        self._c["steps"].inc()
        self._c["occupancy_sum"].inc(self.pool.occupancy)
        self._c["queue_depth_sum"].inc(self.pool.queue_depth)
        self._g_occupancy.set(self.pool.occupancy)
        self._g_queue_depth.set(self.pool.queue_depth)
        return finished

    def run(self, max_steps: int | None = None) -> dict[int, np.ndarray]:
        """Step until queue and slots drain; returns {uid: generated tokens}."""
        out: dict[int, np.ndarray] = {}
        steps = 0
        while not self.pool.idle:
            for req in self.step():
                out[req.uid] = req.output
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError(f"engine not drained after {max_steps} "
                                   f"steps ({self.pool!r})")
        return out

    # ------------------------------------------------------------ internals
    def _admit_group(self, group: list[tuple[int, Request]],
                     finished: list[Request]) -> None:
        self._maybe_refresh_schedules()
        t0 = time.perf_counter()
        slots = np.asarray([s for s, _ in group], np.int32)
        prompts = np.stack([r.prompt for _, r in group])
        inputs = {"tokens": jnp.asarray(prompts)}
        for k in (group[0][1].extra or {}):
            inputs[k] = jnp.asarray(
                np.stack([np.asarray(r.extra[k]) for _, r in group]))
        if self.paged:
            # the paged jit is keyed on the page-rounded length, so the
            # compile counter must be too — exact prompt lengths would
            # overcount
            ps = self.pages.page_size
            n_pg = -(-int(prompts.shape[1]) // ps)
            shape = (len(group), n_pg * ps)
        else:
            shape = (len(group), prompts.shape[1])
        if shape not in self._prefill_shapes_seen:
            self._prefill_shapes_seen.add(shape)
            self._c["prefill_compiles"].inc()
        with obs_trace.span("serve.prefill", batch=len(group),
                            prompt_len=int(prompts.shape[1])):
            if self.paged:
                # prefill at the prompt length rounded up to a page multiple
                # — the group cache then splits exactly into pages, and the
                # per-rounded-length jit keeps compile count page-granular
                logits, grp = self._prefill_fn(n_pg * ps)(self.params, inputs)
                page_rows = np.asarray(
                    [self._slot_pages[s][:n_pg] for s in slots], np.int32)
                self._key, sub = jax.random.split(self._key)
                toks = np.asarray(_pick(logits, self.scfg.temperature, sub))
                self.caches = self._insert_pages(
                    self.caches, grp, jnp.asarray(slots),
                    jnp.asarray(page_rows))
            else:
                logits, grp = self._prefill(self.params, inputs)
                self._key, sub = jax.random.split(self._key)
                toks = np.asarray(_pick(logits, self.scfg.temperature, sub))
                self.caches = self._insert(self.caches, grp,
                                           jnp.asarray(slots))
            jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self._c["prefill_s"].inc(dt)
        self._h_prefill.record(dt)
        self._c["prefill_tokens"].inc(int(prompts.size))
        self._c["admitted"].inc(len(group))
        if self.recorder is not None:
            self.recorder.record("prefill", prompt_len=int(prompts.shape[1]),
                                 batch=len(group), dtype=self.cfg.dtype,
                                 occupancy=self.pool.occupancy,
                                 queue_depth=self.pool.queue_depth)
        now = time.perf_counter()
        for (slot, req), tok in zip(group, toks):
            req.admitted_at = now
            self._h_ttft.record(now - req.submitted_at)
            if self.paged:
                # register BEFORE _emit: a 1-token request releases its slot
                # (and pages) inside _emit, and the prefix cache must take
                # its references first
                self._register_prefix(req, slot)
            self.tokens[slot] = int(tok)
            self._emit(slot, req, int(tok), finished)

    # ------------------------------------------------------ paged internals
    def _admissible(self, worst: int) -> bool:
        """Could a ``worst``-page request start right NOW (the 'reject'
        admission policy's test)?  Conservative: prefix-cache hits it might
        get are not counted, reclaimable cache pages are."""
        evictable = self.prefix.evictable_pages if self.prefix else 0
        return (self.pool.free_slots > 0 and self.pool.queue_depth == 0
                and worst <= self.pages.free_pages + evictable)

    def _admit_paged(self, finished: list[Request]) -> None:
        """FIFO admission gated on pages: admit head-of-line requests while
        a slot AND their worst-case pages are available; the first request
        that does not fit blocks the line (no lookahead — smaller requests
        behind it cannot starve it)."""
        groups: dict[Any, list[tuple[int, Request]]] = {}
        while self.pool.free_slots:
            req = self.pool.peek()
            if req is None:
                break
            plan = self._plan_pages(req)
            if plan is None:
                break
            slot, _ = self.pool.admit_one()
            self._install(slot, req, plan, groups)
        for group in groups.values():
            self._admit_group(group, finished)

    def _plan_pages(self, req: Request) -> tuple[list[int], list[int]] | None:
        """Reserve every page ``req`` could ever need — shared prefix pages
        first (one pool ref each via lookup), the rest allocated fresh, so
        decode NEVER allocates and can never deadlock mid-generation.
        Returns ``(shared, fresh)`` or None (caller waits); on failure any
        retained shared pages are released."""
        ps = self.pages.page_size
        worst = -(-(len(req.prompt) + req.max_new_tokens) // ps)
        shared: list[int] = []
        if self.prefix is not None and not (req.extra and "embeds" in req.extra):
            # embedding prompts carry content outside the token ids, which
            # is all the prefix hash sees — never share those
            shared = self.prefix.lookup(req.prompt)
        need = worst - len(shared)
        fresh = self.pages.alloc(need)
        if fresh is None and self.prefix is not None:
            # squeeze idle prefix entries before making the line wait
            self.prefix.evict(need - self.pages.free_pages)
            fresh = self.pages.alloc(need)
        if fresh is None:
            if shared:
                self.pages.release(shared)
            return None
        return shared, fresh

    def _install(self, slot: int, req: Request,
                 plan: tuple[list[int], list[int]],
                 groups: dict[Any, list[tuple[int, Request]]]) -> None:
        """Wire an admitted request's page table and route it to a prefill
        path: chunked (prefix hit — only the tail needs compute — or prompt
        longer than ``prefill_chunk``) or the same-shape batched group."""
        shared, fresh = plan
        ps = self.pages.page_size
        pages = shared + fresh
        self._slot_pages[slot] = pages
        self._pt[slot] = 0
        self._pt[slot, :len(pages)] = pages
        m_tok = len(shared) * ps
        cs = self.scfg.prefill_chunk
        if m_tok or (cs is not None and len(req.prompt) - m_tok > cs):
            if m_tok:
                self._c["prefix_hits"].inc()
                self._c["prefix_tokens_saved"].inc(m_tok)
            # the slot's cache position starts at the shared-prefix length
            # (0 when none) — eviction is lazy, so the leaf holds the
            # previous occupant's value until set here
            self.caches = self._set_len(self.caches, jnp.int32(slot),
                                        jnp.int32(m_tok))
            self._prefilling.add(slot)
            self._chunk_tasks.append(_ChunkTask(req=req, slot=slot,
                                                pos=m_tok))
        else:
            groups.setdefault(_shape_key(req), []).append((slot, req))

    def _prefill_fn(self, r: int):
        fn = self._prefill_by_len.get(r)
        if fn is None:
            fn = self._build_prefill(r)
            self._prefill_by_len[r] = fn
        return fn

    def _chunk_step(self, finished: list[Request]) -> None:
        """Advance the head chunk task by ONE chunk — chunked prefill
        interleaves with decode at chunk granularity, so a long prompt
        cannot stall the decode batch for its whole length.  The final
        (short) chunk runs zero-padded at the fixed chunk shape with a
        traced valid-length, so compiles scale with chunk SHAPES, not
        prompt lengths."""
        self._maybe_refresh_schedules()
        task = self._chunk_tasks[0]
        req, slot = task.req, task.slot
        remaining = len(req.prompt) - task.pos
        cs = self.scfg.prefill_chunk or remaining
        n = min(cs, remaining)
        buf = np.zeros((1, cs), np.int32)
        buf[0, :n] = req.prompt[task.pos:task.pos + n]
        embeds = None
        eshape = None
        if req.extra and "embeds" in req.extra:
            e = np.asarray(req.extra["embeds"])
            ebuf = np.zeros((1, cs) + e.shape[1:], e.dtype)
            ebuf[0, :n] = e[task.pos:task.pos + n]
            embeds = jnp.asarray(ebuf)
            eshape = tuple(e.shape[1:])
        shape = ("chunk", cs, eshape)
        if shape not in self._prefill_shapes_seen:
            self._prefill_shapes_seen.add(shape)
            self._c["prefill_compiles"].inc()
        t0 = time.perf_counter()
        with obs_trace.span("serve.prefill_chunk", slot=slot, chunk=int(cs),
                            valid=int(n)):
            last, self.caches = self._chunk(
                self.params, self.caches, jnp.asarray(buf),
                jnp.asarray(self._pt[slot:slot + 1]), jnp.int32(slot),
                jnp.int32(n), embeds=embeds)
            jax.block_until_ready(last)
        dt = time.perf_counter() - t0
        self._c["prefill_s"].inc(dt)
        self._h_prefill.record(dt)
        self._c["prefill_tokens"].inc(int(n))
        self._c["chunk_steps"].inc()
        if self.recorder is not None:
            self.recorder.record("prefill", prompt_len=int(cs), batch=1,
                                 dtype=self.cfg.dtype,
                                 occupancy=self.pool.occupancy,
                                 queue_depth=self.pool.queue_depth)
        task.pos += n
        if task.pos < len(req.prompt):
            return
        self._chunk_tasks.popleft()
        self._prefilling.discard(slot)
        self._key, sub = jax.random.split(self._key)
        tok = int(np.asarray(_pick(last, self.scfg.temperature, sub))[0])
        now = time.perf_counter()
        req.admitted_at = now
        self._h_ttft.record(now - req.submitted_at)
        self._c["admitted"].inc()
        self._register_prefix(req, slot)
        self.tokens[slot] = tok
        self._emit(slot, req, tok, finished)

    def _register_prefix(self, req: Request, slot: int) -> None:
        """Offer a freshly prefilled prompt's full pages to the prefix cache
        (idempotent for already-known blocks)."""
        if self.prefix is None or (req.extra and "embeds" in req.extra):
            return
        n_full = (len(req.prompt) - 1) // self.pages.page_size
        if n_full:
            # the FULL prompt goes to insert — its key chain already stops
            # at the last shareable block; truncating first would shift that
            # bound and silently drop the final block
            self.prefix.insert(req.prompt, self._slot_pages[slot][:n_full])

    def _decode_paged(self, finished: list[Request]) -> None:
        """One lockstep decode over slots NOT mid chunked-prefill: the
        ``active`` mask keeps inactive rows from writing real pages or
        advancing their cache position."""
        decoding = [s for s, _ in self.pool.held()
                    if s not in self._prefilling]
        if not decoding:
            return
        self._maybe_refresh_schedules()
        occ = len(decoding)
        active = np.zeros(self.capacity, bool)
        active[decoding] = True
        t0 = time.perf_counter()
        with obs_trace.span("serve.decode", occupancy=occ):
            self._key, sub = jax.random.split(self._key)
            tok, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(self.tokens),
                jnp.asarray(self._pt), jnp.asarray(active), key=sub)
            tok = np.asarray(tok)
        dt = time.perf_counter() - t0
        self._c["decode_s"].inc(dt)
        self._c["decode_steps"].inc()
        self._h_decode.record(dt)
        if self.recorder is not None:
            self.recorder.record("decode", batch=self.capacity,
                                 dtype=self.cfg.dtype, occupancy=occ,
                                 queue_depth=self.pool.queue_depth)
        for slot, req in list(self.pool.held()):
            if slot in self._prefilling:
                continue
            self.tokens[slot] = int(tok[slot])
            self._emit(slot, req, int(tok[slot]), finished)

    def _emit(self, slot: int, req: Request, tok: int,
              finished: list[Request]) -> None:
        req.tokens.append(tok)
        now = time.perf_counter()
        last = self._last_emit.get(req.uid)
        if last is not None:
            self._h_itl.record(now - last)
        self._last_emit[req.uid] = now
        self._c["tokens_out"].inc()
        if self.on_token is not None:
            self.on_token(req, tok)
        if (len(req.tokens) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            req.finished_at = time.perf_counter()
            self._last_emit.pop(req.uid, None)
            # eviction is lazy: a freed slot's stale state is confined to its
            # own batch row (per-slot masks/state), and the next admission's
            # insert overwrites the entire row — so completion costs no
            # cache-sized dispatch (models.evict_slot exists for callers that
            # want eager invalidation)
            self.pool.release(slot)
            if self.paged:
                # drop the slot's page references (prefix-shared pages stay
                # alive through the cache's own ref) and zero its page-table
                # row so stale decode scatters land in the trash page
                pages = self._slot_pages.pop(slot, None)
                if pages:
                    self.pages.release(pages)
                self._pt[slot] = 0
            self._c["completed"].inc()
            finished.append(req)

    # -------------------------------------------------------------- metrics
    @property
    def stats(self) -> dict[str, Any]:
        """Cumulative counters, assembled from the metrics registry (the
        registry instruments are the source of truth; this dict keeps the
        pre-registry read surface)."""
        return {k: c.value for k, c in self._c.items()}

    def reset_stats(self) -> None:
        """Zero the timing/gauge counters and latency histograms (e.g. after
        a warmup pass) while keeping compile bookkeeping, so metrics
        describe steady state."""
        keep = self._c["prefill_compiles"].value
        for c in self._c.values():
            c.reset()
        if keep:
            self._c["prefill_compiles"].inc(keep)
        for h in (self._h_ttft, self._h_itl, self._h_prefill, self._h_decode):
            h.reset()

    def metrics(self) -> dict[str, float]:
        """Derived serving metrics (gauge means are per engine step).

        Every ratio goes through :func:`_ratio`, so a never-stepped or
        zero-token engine reports well-defined 0.0 everywhere instead of
        raising or emitting inf/NaN."""
        s = self.stats
        busy = s["prefill_s"] + s["decode_s"]
        out = {
            "queue_depth": float(self.pool.queue_depth),
            "slot_occupancy": float(self.pool.occupancy),
            "mean_occupancy": _ratio(s["occupancy_sum"], s["steps"]),
            "mean_queue_depth": _ratio(s["queue_depth_sum"], s["steps"]),
            "prefill_s": float(s["prefill_s"]),
            "decode_s": float(s["decode_s"]),
            "prefill_frac": _ratio(s["prefill_s"], busy),
            "tokens_per_s": _ratio(s["tokens_out"], busy),
            "decode_tokens_per_s": _ratio(s["tokens_out"] - s["admitted"],
                                          s["decode_s"]),
        }
        if self.paged:
            out.update({
                "page_occupancy": _ratio(self.pages.used_pages,
                                         self.pages.usable_pages),
                "free_pages": float(self.pages.free_pages),
                "prefix_hits": float(s["prefix_hits"]),
                "prefix_tokens_saved": float(s["prefix_tokens_saved"]),
                "prefix_entries": float(len(self.prefix)
                                        if self.prefix else 0),
                "chunk_steps": float(s["chunk_steps"]),
            })
        return out


def _decode_sample(params, caches, token, *, cfg: ModelConfig,
                   temperature: float, key):
    logits, caches = M.decode_step(params, caches, token, cfg)
    return _pick(logits, temperature, key), caches


def _decode_sample_paged(params, caches, token, pt, active, *,
                         cfg: ModelConfig, temperature: float, key):
    logits, caches = M.decode_step(params, caches, token, cfg, pt=pt,
                                   active=active)
    return _pick(logits, temperature, key), caches


def _pick(logits, temperature: float, key):
    if temperature and temperature > 0:
        return jax.random.categorical(key, logits / temperature, axis=-1)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
