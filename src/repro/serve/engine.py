"""Batched serving engine: continuous prefill + decode over a KV/SSM cache.

A minimal-but-real production shape: fixed-capacity batch slots, greedy or
temperature sampling, per-slot stop handling, and stats.  prefill/decode are
the same jitted step functions the dry-run lowers (launch/steps.py), so a
schedule cached by SIP benefits serving directly.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0        # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig = ServeConfig()):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self._prefill = jax.jit(functools.partial(
            M.prefill, cfg=cfg, max_len=scfg.max_len))
        self._decode = jax.jit(functools.partial(
            _decode_sample, cfg=cfg, temperature=scfg.temperature))
        self.stats: dict[str, Any] = {"prefill_s": 0.0, "decode_s": 0.0,
                                      "tokens_out": 0}

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 extra_inputs: dict[str, Any] | None = None,
                 eos_id: int | None = None) -> np.ndarray:
        """prompts: (B, S) int32 -> (B, <=max_new_tokens) int32."""
        b = prompts.shape[0]
        inputs = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            inputs.update(extra_inputs)
        key = jax.random.PRNGKey(self.scfg.seed)

        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, inputs)
        jax.block_until_ready(logits)
        self.stats["prefill_s"] += time.perf_counter() - t0

        out = []
        token = _pick(logits, self.scfg.temperature, key)
        done = np.zeros(b, bool)
        t0 = time.perf_counter()
        for i in range(max_new_tokens):
            out.append(np.asarray(token))
            if eos_id is not None:
                done |= (out[-1] == eos_id)
                if done.all():
                    break
            key, sub = jax.random.split(key)
            token, caches = self._decode(self.params, caches, token, key=sub)
        jax.block_until_ready(token)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["tokens_out"] += int(np.size(out))
        return np.stack(out, axis=1)


def _decode_sample(params, caches, token, *, cfg: ModelConfig,
                   temperature: float, key):
    logits, caches = M.decode_step(params, caches, token, cfg)
    return _pick(logits, temperature, key), caches


def _pick(logits, temperature: float, key):
    if temperature and temperature > 0:
        return jax.random.categorical(key, logits / temperature, axis=-1)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
