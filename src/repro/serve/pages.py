"""Fixed-size KV-cache pages: a refcounted page pool + content-hashed
prefix cache — the bookkeeping core of paged serving memory.

The continuous-batching engine stops giving every slot a contiguous
``max_len`` cache segment; instead the KV store is one flat array of
``num_pages`` pages of ``page_size`` token positions each, and every slot
owns a *page table* (a row of page ids).  This module is the host-side
allocator for that store, kept model-free — like :class:`~repro.serve.slots.
SlotPool` — so its invariants are property-testable in isolation
(tests/test_page_pool.py):

* **no writer aliasing** — a page handed out by :meth:`PagePool.alloc` has
  refcount 1 and is never simultaneously live in another allocation; pages
  only become shared through explicit :meth:`retain` (prefix sharing), and
  shared pages are read-only by convention (:meth:`writable` is the check,
  :meth:`cow` the escape hatch);
* **exact lifetimes** — a page's refcount hits zero exactly when its last
  holder releases it, at which point it re-enters the free list;
* **no double-free** — releasing a free page raises instead of corrupting
  the free list.

Page 0 (more generally ``reserved``) is never allocated: the engine keeps it
as the *trash page* — idle/inactive batch rows carry an all-zero page-table
row, so their lockstep decode writes land harmlessly in page 0 instead of
needing a per-row dispatch guard.

:class:`PrefixCache` maps chain-hashed page-aligned token blocks to pages so
requests sharing a system-prompt prefix prefill once and alias the pages
read-only.  The cache holds one reference per registered page; LRU eviction
(:meth:`PrefixCache.evict`) returns pages to the pool under memory pressure.

**Mesh seam.**  Under tensor-parallel serving (``ContinuousEngine(...,
mesh=...)``) the flat page store shards on its *kv-head* axis and stays
whole along the page-id axis (``partition.SERVE_RULES`` maps "batch" —
the page-id dim here — to ``None``).  Everything in this module is
therefore shard-invariant: page ids, refcounts, page tables and prefix
hashes are host-side integers naming the same page on every device, so
alloc/retain/release and prefix hits need no collective and no
per-device variant.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterable, Sequence

import numpy as np

from repro.obs import metrics as obs_metrics


class PagesExhausted(RuntimeError):
    """Raised under the ``reject`` admission policy when a request cannot be
    granted its worst-case page reservation right now."""


class PagePool:
    """``num_pages`` fixed-size pages with refcounted lifetimes.

    ``alloc(n)`` hands out ``n`` pages at refcount 1 (lowest ids first, so
    placement is deterministic), ``retain`` adds a reference (prefix
    sharing), ``release`` drops one and returns the page to the free list at
    zero.  ``reserved`` pages (default: page 0, the trash page) are never
    allocated or released.
    """

    def __init__(self, num_pages: int, page_size: int,
                 reserved: Sequence[int] = (0,),
                 obs: obs_metrics.MetricsRegistry | None = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self._reserved = frozenset(reserved)
        if num_pages <= len(self._reserved):
            raise ValueError(f"num_pages must exceed the {len(self._reserved)}"
                             f" reserved page(s), got {num_pages}")
        self.num_pages = num_pages
        self.page_size = page_size
        self._ref = np.zeros(num_pages, np.int32)
        # pop() -> lowest id; kept sorted descending like SlotPool's free list
        self._free = sorted((i for i in range(num_pages)
                             if i not in self._reserved), reverse=True)
        # optional telemetry (repro.obs): the engine passes its registry so
        # pool occupancy shares one surface with the serve.* metrics.
        # `is not None`, not truthiness: an empty registry is falsy (len 0)
        has_obs = obs is not None
        self._g_occ = (obs.gauge("serve.page_pool.occupancy")
                       if has_obs else None)
        self._c_alloc = (obs.counter("serve.page_pool.alloc_pages")
                         if has_obs else None)
        self._c_freed = (obs.counter("serve.page_pool.freed_pages")
                         if has_obs else None)

    def _observe(self) -> None:
        if self._g_occ is not None:
            self._g_occ.set(self.used_pages / self.usable_pages)

    # ---------------------------------------------------------- allocation
    def alloc(self, n: int) -> list[int] | None:
        """``n`` fresh pages at refcount 1, or None if the pool cannot
        satisfy the whole request (all-or-nothing: a partial grant would
        leak pages on the caller's retry path)."""
        if n < 0:
            raise ValueError(f"cannot alloc {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        if self._c_alloc is not None and pages:
            self._c_alloc.inc(len(pages))
        self._observe()
        return pages

    def retain(self, pages: int | Iterable[int]) -> None:
        """Add one reference to each live page (prefix sharing)."""
        for p in self._as_pages(pages):
            if self._ref[p] <= 0:
                raise ValueError(f"retain of free page {p}")
            self._ref[p] += 1

    def release(self, pages: int | Iterable[int]) -> int:
        """Drop one reference per page; pages hitting zero return to the
        free list.  Releasing an already-free (or reserved) page raises —
        the double-free guard.  Returns how many pages were actually freed."""
        freed = 0
        try:
            for p in self._as_pages(pages):
                if self._ref[p] <= 0:
                    raise ValueError(f"double free of page {p}")
                self._ref[p] -= 1
                if self._ref[p] == 0:
                    self._free.append(p)
                    freed += 1
        finally:
            # one sort per call, not per page; finally keeps the descending
            # free-list invariant even when a double-free raises mid-batch
            if freed:
                self._free.sort(reverse=True)
                if self._c_freed is not None:
                    self._c_freed.inc(freed)
                self._observe()
        return freed

    def cow(self, page: int) -> int | None:
        """Copy-on-write: break this holder's share of ``page``.

        Allocates a fresh page (refcount 1), moves one reference off
        ``page``, and returns the new page id — the caller owns copying the
        page *contents* (a device-side scatter) and repointing its page
        table.  Returns None when the pool is exhausted; a no-op escape for
        already-exclusive pages is :meth:`writable`.

        The engine's whole-page-aligned prefix sharing never needs this
        (shared pages are full and frozen; the first written position always
        lands on a fresh page), but sub-page sharing policies do — and the
        pool-level invariant (a writer never aliases a shared page) is
        property-tested either way.
        """
        if self._ref[page] <= 0:
            raise ValueError(f"cow of free page {page}")
        got = self.alloc(1)
        if got is None:
            return None
        self.release(page)
        return got[0]

    # -------------------------------------------------------------- queries
    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def writable(self, page: int) -> bool:
        """True when exactly one holder references ``page`` — the only state
        in which in-place writes cannot corrupt another request's cache."""
        return self._ref[page] == 1

    @property
    def usable_pages(self) -> int:
        """Allocatable pages (total minus reserved) — the capacity bound."""
        return self.num_pages - len(self._reserved)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.usable_pages - len(self._free)

    def _as_pages(self, pages: int | Iterable[int]) -> list[int]:
        out = [int(pages)] if isinstance(pages, (int, np.integer)) \
            else [int(p) for p in pages]
        for p in out:
            if not 0 <= p < self.num_pages:
                raise ValueError(f"page {p} out of range [0, {self.num_pages})")
            if p in self._reserved:
                raise ValueError(f"page {p} is reserved (trash page)")
        return out

    def __repr__(self) -> str:
        return (f"PagePool(num_pages={self.num_pages}, "
                f"page_size={self.page_size}, free={self.free_pages}, "
                f"used={self.used_pages})")


# ========================================================== prefix sharing
@dataclasses.dataclass
class _PrefixEntry:
    page: int
    last_used: int


class PrefixCache:
    """Content-hashed full-page token blocks -> cache pages.

    Keys are *chain* hashes — block ``i``'s key folds in block ``i-1``'s key
    — so a hit on block ``i`` guarantees the whole prefix up to and including
    block ``i`` matches, not just that one block's tokens.  Only pages whose
    ``page_size`` tokens are fully covered by the prompt minus its last
    token are ever registered/matched: the tail token must always prefill so
    the admitting request gets its first-token logits, and partially-filled
    pages are writable (sharing them would alias a writer).

    The cache holds ONE pool reference per registered page.  ``lookup``
    retains matched pages on behalf of the caller (who must release them on
    any failure path); ``evict`` releases LRU entries until enough pages
    actually returned to the free list.
    """

    def __init__(self, pool: PagePool,
                 obs: obs_metrics.MetricsRegistry | None = None):
        self.pool = pool
        self._entries: dict[bytes, _PrefixEntry] = {}
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # optional telemetry (repro.obs), same registry surface as serve.*
        # (`is not None`: an empty registry is falsy)
        has_obs = obs is not None
        self._c_hits = (obs.counter("serve.prefix_cache.hits")
                        if has_obs else None)
        self._c_misses = (obs.counter("serve.prefix_cache.misses")
                          if has_obs else None)
        self._c_evictions = (obs.counter("serve.prefix_cache.evictions")
                             if has_obs else None)
        self._c_evicted_pages = (obs.counter("serve.prefix_cache."
                                             "evicted_pages")
                                 if has_obs else None)
        self._g_entries = (obs.gauge("serve.prefix_cache.entries")
                           if has_obs else None)

    def _keys(self, tokens: np.ndarray) -> list[bytes]:
        """Chain-hash keys for every *shareable* full block of ``tokens``."""
        ps = self.pool.page_size
        n_share = max(0, (len(tokens) - 1) // ps)
        keys, prev = [], b""
        arr = np.asarray(tokens, np.int32)
        for i in range(n_share):
            h = hashlib.sha256(prev)
            h.update(arr[i * ps:(i + 1) * ps].tobytes())
            prev = h.digest()
            keys.append(prev)
        return keys

    def lookup(self, tokens: np.ndarray) -> list[int]:
        """Longest already-cached page run covering a prefix of ``tokens``.

        Returns the page ids (possibly empty); each is retained for the
        caller.  Counts one hit when any pages matched, else one miss
        (prompts too short to span a full block count as neither)."""
        keys = self._keys(tokens)
        pages: list[int] = []
        self._clock += 1
        for key in keys:
            ent = self._entries.get(key)
            if ent is None:
                break
            ent.last_used = self._clock
            self.pool.retain(ent.page)
            pages.append(ent.page)
        if keys:
            if pages:
                self.hits += 1
                if self._c_hits is not None:
                    self._c_hits.inc()
            else:
                self.misses += 1
                if self._c_misses is not None:
                    self._c_misses.inc()
        return pages

    def insert(self, tokens: np.ndarray, pages: Sequence[int]) -> int:
        """Register the full-page blocks of ``tokens`` (one page id per
        block, in order).  Already-known blocks are skipped; newly
        registered pages gain one cache-held reference.  Returns how many
        blocks were newly registered."""
        keys = self._keys(tokens)
        if len(pages) < len(keys):
            raise ValueError(f"{len(keys)} shareable blocks but only "
                             f"{len(pages)} pages")
        added = 0
        self._clock += 1
        for key, page in zip(keys, pages):
            ent = self._entries.get(key)
            if ent is not None:
                ent.last_used = self._clock
                continue
            self.pool.retain(page)
            self._entries[key] = _PrefixEntry(int(page), self._clock)
            added += 1
        if self._g_entries is not None:
            self._g_entries.set(len(self._entries))
        return added

    def evict(self, want_freed: int) -> int:
        """Release LRU *exclusively-held* entries until ``want_freed`` pages
        returned to the free list or none remain.  Entries whose page is
        still shared with a live slot are kept: evicting them frees nothing
        (the slot's reference pins the page) and only forfeits future
        sharing — they become evictable when their last slot releases.
        Returns the number of pages freed."""
        freed = 0
        while freed < want_freed and self._entries:
            key = min(self._entries,
                      key=lambda k: (not self.pool.writable(
                          self._entries[k].page),
                          self._entries[k].last_used))
            if not self.pool.writable(self._entries[key].page):
                break  # best candidate still shared -> nothing reclaimable
            ent = self._entries.pop(key)
            self.evictions += 1
            if self._c_evictions is not None:
                self._c_evictions.inc()
            n = self.pool.release(ent.page)
            freed += n
            if self._c_evicted_pages is not None and n:
                self._c_evicted_pages.inc(n)
        if self._g_entries is not None:
            self._g_entries.set(len(self._entries))
        return freed

    @property
    def evictable_pages(self) -> int:
        """Pages eviction could actually return to the free list right now
        (entries whose page the cache holds exclusively) — the admission
        check's honest view of reclaimable capacity."""
        return sum(1 for e in self._entries.values()
                   if self.pool.writable(e.page))

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        return (f"PrefixCache(entries={len(self._entries)}, "
                f"hits={self.hits}, misses={self.misses})")
