"""Serving subsystem: static-batch reference engine, continuous-batching
engine, and the slot allocator they share."""

from repro.serve.engine import (ContinuousEngine, Engine, Request,
                                ServeConfig)
from repro.serve.slots import SlotPool

__all__ = ["ContinuousEngine", "Engine", "Request", "ServeConfig",
           "SlotPool"]
