"""Pallas SSD intra-chunk kernel: allclose sweeps vs the jnp oracle and
end-to-end parity of the Pallas-backed chunked SSD."""

import numpy as np
import pytest

from repro.core.schedule import Schedule
from repro.kernels.ssd import ops as jops
from repro.kernels.ssd import pallas_ops, ref

RNG = np.random.default_rng(5)


def _mk(bt=2, s=64, h=4, p=8, n=16):
    x = RNG.standard_normal((bt, s, h, p)).astype(np.float32)
    dt = (np.abs(RNG.standard_normal((bt, s, h))) * 0.1 + 0.01).astype(np.float32)
    A = -np.abs(RNG.standard_normal(h)).astype(np.float32)
    B = (RNG.standard_normal((bt, s, n)) * 0.3).astype(np.float32)
    C = (RNG.standard_normal((bt, s, n)) * 0.3).astype(np.float32)
    D = RNG.standard_normal(h).astype(np.float32)
    return x, dt, A, B, C, D


class TestPallasSSD:
    @pytest.mark.parametrize("chunk", [8, 16, 32])
    def test_matches_naive_oracle(self, chunk):
        args = _mk()
        got = np.asarray(pallas_ops.ssd_chunked_pallas(*args, chunk=chunk))
        want = np.asarray(ref.ssd(*args))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_matches_jnp_chunked_with_state(self):
        args = _mk()
        yp, sp = pallas_ops.ssd_chunked_pallas(*args, chunk=16,
                                               return_state=True)
        yj, sj = jops.ssd_chunked(*args, chunk=16, return_state=True)
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yj),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(sp), np.asarray(sj),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("h,p,n", [(1, 4, 8), (8, 16, 32), (2, 32, 8)])
    def test_shape_sweep(self, h, p, n):
        x, dt, A, B, C, D = _mk(h=h, p=p, n=n)
        got = np.asarray(pallas_ops.ssd_chunked_pallas(x, dt, A, B, C, D,
                                                       chunk=16))
        want = np.asarray(ref.ssd(x, dt, A, B, C, D))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_all_single_moves_preserve_semantics(self):
        x, dt, A, B, C, D = _mk(s=32)
        nc = 32 // 16
        xb = (x * dt[..., None]).reshape(2 * nc, 16, 4, 8)
        la = (dt * A[None, None, :]).reshape(2 * nc, 16, 4)
        Br = B.reshape(2 * nc, 16, 16)
        Cr = C.reshape(2 * nc, 16, 16)
        static = pallas_ops.signature_fn(xb, la, Br, Cr)
        sched = Schedule()
        program = pallas_ops.program_for(sched, **static)
        base = np.asarray(pallas_ops.build(sched, **static)(xb, la, Br, Cr))
        order = program.default_order()
        moves = program.legal_moves(order)
        assert moves
        for idx, d in moves:
            new = program.move(order, idx, d)
            fn = pallas_ops.build(sched.with_order(new), **static)
            np.testing.assert_array_equal(
                np.asarray(fn(xb, la, Br, Cr)), base)
