"""Hypothesis property tests on higher-level system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig

MOE = ModelConfig(name="m", family="moe", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, n_experts=4, top_k=2,
                  capacity_factor=4.0, dtype="float32").validate()
DENSE = ModelConfig(name="d", family="dense", n_layers=2, d_model=32,
                    n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                    dtype="float32").validate()

_P = {}


def params_for(cfg):
    if cfg.name not in _P:
        _P[cfg.name] = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
    return _P[cfg.name]


class TestBatchInvariance:
    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_dense_batch_permutation_equivariance(self, seed):
        """Permuting the batch permutes the logits — no cross-example
        leakage anywhere in the stack."""
        rng = np.random.default_rng(seed)
        toks = jnp.asarray(rng.integers(0, 64, (4, 12)), jnp.int32)
        perm = rng.permutation(4)
        p = params_for(DENSE)
        l1, _ = M.forward(p, {"tokens": toks}, DENSE)
        l2, _ = M.forward(p, {"tokens": toks[perm]}, DENSE)
        np.testing.assert_allclose(np.asarray(l1)[perm], np.asarray(l2),
                                   rtol=1e-4, atol=1e-5)

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=6, deadline=None)
    def test_moe_dropless_token_determinism(self, seed):
        """With dropless capacity, duplicating a sequence in the batch
        yields identical logits for the duplicates (routing is per-token)."""
        rng = np.random.default_rng(seed)
        row = rng.integers(0, 64, (1, 12))
        toks = jnp.asarray(np.concatenate([row, row], 0), jnp.int32)
        p = params_for(MOE)
        l, _ = M.forward(p, {"tokens": toks}, MOE)
        np.testing.assert_allclose(np.asarray(l[0]), np.asarray(l[1]),
                                   rtol=1e-4, atol=1e-5)


class TestLossProperties:
    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_loss_positive_and_bounded_by_uniform(self, seed):
        """0 < loss and at init loss ≈≤ log(vocab) + slack (sane init)."""
        rng = np.random.default_rng(seed)
        b = {"tokens": jnp.asarray(rng.integers(0, 64, (2, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 64, (2, 16)), jnp.int32)}
        loss, _ = M.loss_fn(params_for(DENSE), b, DENSE)
        assert 0 < float(loss) < np.log(64) + 3.0

    @given(st.floats(0.1, 10.0))
    @settings(max_examples=8, deadline=None)
    def test_mask_scaling_invariance(self, scale):
        """Scaling a uniform mask leaves the mean loss unchanged."""
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, 64, (2, 16)), jnp.int32)
        labels = jnp.asarray(rng.integers(0, 64, (2, 16)), jnp.int32)
        p = params_for(DENSE)
        l1, _ = M.loss_fn(p, {"tokens": toks, "labels": labels,
                              "mask": jnp.ones((2, 16))}, DENSE)
        l2, _ = M.loss_fn(p, {"tokens": toks, "labels": labels,
                              "mask": jnp.full((2, 16), scale)}, DENSE)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


class TestSSDProperties:
    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_ssd_linearity_in_x(self, seed):
        """SSD is linear in x for fixed (dt, A, B, C, D=0)."""
        from repro.kernels.ssd import ops
        rng = np.random.default_rng(seed)
        bt, s, h, p, n = 1, 32, 2, 4, 8
        x = rng.standard_normal((bt, s, h, p)).astype(np.float32)
        dt = (np.abs(rng.standard_normal((bt, s, h))) * 0.1 + 0.01).astype(np.float32)
        A = -np.abs(rng.standard_normal(h)).astype(np.float32)
        B = (rng.standard_normal((bt, s, n)) * 0.3).astype(np.float32)
        C = (rng.standard_normal((bt, s, n)) * 0.3).astype(np.float32)
        D = np.zeros(h, np.float32)
        y1 = np.asarray(ops.ssd_chunked(x, dt, A, B, C, D, chunk=16))
        y2 = np.asarray(ops.ssd_chunked(2.0 * x, dt, A, B, C, D, chunk=16))
        np.testing.assert_allclose(y2, 2.0 * y1, rtol=1e-4, atol=1e-5)
