"""Pipeline-parallelism building block: parity + bubble accounting.

The multi-device parity test runs in an 8-device subprocess (same pattern
as test_sharding_multidevice)."""

import json
import os
import subprocess
import sys

import pytest

from repro.dist.pipeline import bubble_fraction

SUBPROC = os.path.join(os.path.dirname(__file__), "pipeline_subprocess.py")


class TestBubble:
    def test_bubble_fraction(self):
        assert bubble_fraction(1, 4) == 0.0
        assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
        assert bubble_fraction(4, 28) == pytest.approx(3 / 31)

    def test_more_microbatches_shrink_bubble(self):
        assert bubble_fraction(8, 64) < bubble_fraction(8, 8)


@pytest.mark.slow
class TestPipelineParity:
    def _run(self, mode):
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        out = subprocess.run([sys.executable, SUBPROC, mode], env=env,
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    def test_forward_parity(self):
        r = self._run("forward")
        assert r["max_err"] < 1e-5, r

    def test_grad_parity(self):
        r = self._run("grad")
        assert r["max_rel_err"] < 1e-4, r
