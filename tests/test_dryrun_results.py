"""Self-check of the shipped dry-run results (deliverables e/g).

Validates dryrun_results.json: every one of the 40 cells x 2 meshes is
present as 'ok' or policy-'skipped', roofline terms are positive and
consistent, and the §Perf hillclimb variants exist with their claimed
improvements.  Skipped gracefully if the sweep has not been run."""

import json
import os

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


@pytest.fixture(scope="module")
def results():
    if not os.path.exists(RESULTS):
        pytest.skip("dryrun_results.json not present — run "
                    "python -m repro.launch.dryrun --all --mesh both")
    with open(RESULTS) as f:
        return json.load(f)


def _base(results):
    return {k: v for k, v in results.items() if "#" not in k}


class TestSweepCompleteness:
    def test_all_80_cells_present(self, results):
        from repro import configs
        base = _base(results)
        missing = []
        for name, _, shape, _, _ in configs.cells():
            for mesh in ("single", "multi"):
                if f"{name}|{shape.name}|{mesh}" not in base:
                    missing.append((name, shape.name, mesh))
        assert not missing, missing

    def test_no_errors(self, results):
        bad = {k: v.get("error") for k, v in _base(results).items()
               if v["status"] == "error"}
        assert not bad, bad

    def test_skips_match_policy(self, results):
        from repro import configs
        base = _base(results)
        expected_skips = {(n, s.name) for n, _, s, ok, _ in configs.cells()
                          if not ok}
        actual = {(v["arch"], v["shape"]) for v in base.values()
                  if v["status"] == "skipped"}
        assert actual == expected_skips

    def test_ok_count_is_66(self, results):
        base = _base(results)
        assert sum(1 for v in base.values() if v["status"] == "ok") == 66


class TestRooflineConsistency:
    def test_terms_positive_and_dominant_valid(self, results):
        for k, v in _base(results).items():
            if v["status"] != "ok":
                continue
            t = v["roofline"]
            assert t["compute_s"] >= 0 and t["memory_s"] > 0, k
            assert t["dominant"] in ("compute_s", "memory_s", "collective_s"), k
            assert t[t["dominant"]] == max(
                t["compute_s"], t["memory_s"], t["collective_s"]), k

    def test_useful_flops_in_range(self, results):
        for k, v in _base(results).items():
            if v["status"] != "ok":
                continue
            uf = v.get("useful_flops_ratio")
            assert uf is not None and 0 < uf < 1.5, (k, uf)

    def test_param_counts_match_scale(self, results):
        base = _base(results)
        r = base.get("dbrx-132b|train_4k|single")
        assert 120e9 < r["params_total"] < 145e9     # ~132B
        assert r["params_active"] < r["params_total"] / 2   # top-4 of 16
        r = base.get("qwen3-1.7b|train_4k|single")
        assert 1.5e9 < r["params_total"] < 2.5e9

    def test_chips(self, results):
        for k, v in _base(results).items():
            if v["status"] != "ok":
                continue
            assert v["chips"] == (512 if v["mesh"] == "multi" else 256), k


class TestPerfVariants:
    def test_cell_a_ladder(self, results):
        base = results["llama4-scout-17b-16e|train_4k|single"]
        best = results.get("llama4-scout-17b-16e|train_4k|single#pad48_dots_v2")
        assert best and best["status"] == "ok"
        assert best["roofline"]["memory_s"] < 0.30 * base["roofline"]["memory_s"]
        assert best["useful_flops_ratio"] > 5 * base["useful_flops_ratio"]

    def test_cell_b_collective_drop(self, results):
        base = results["dbrx-132b|train_4k|multi"]
        best = results.get("dbrx-132b|train_4k|multi#dots")
        assert best and best["status"] == "ok"
        assert best["roofline"]["collective_s"] < \
            0.2 * base["roofline"]["collective_s"]

    def test_cell_c_sp(self, results):
        base = results["qwen3-4b|prefill_32k|single"]
        sp = results.get("qwen3-4b|prefill_32k|single#sp")
        assert sp and sp["status"] == "ok"
        assert sp["roofline"]["compute_s"] < base["roofline"]["compute_s"]
        assert sp["roofline"]["collective_s"] < base["roofline"]["collective_s"]
