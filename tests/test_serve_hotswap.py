"""Mid-stream schedule hot-swap: the restart-free deployment acceptance.

A running ContinuousEngine polls its schedule store's version each step; a
commit (an autotune promotion) makes it rebuild its jit dispatchers so the
next trace resolves the new schedule.  These tests promote a legal
non-default schedule for the exact serving shape WHILE requests are in
flight and assert greedy outputs stay token-identical to single-request
generation — in contiguous and paged modes — plus the paged obs wiring
(pool occupancy gauge, prefix-cache counters).
"""

import json

import numpy as np
import pytest

import jax

from repro import obs
from repro.core.cache import PendingPut, ScheduleCache
from repro.core.registry import registry, schedule_cache
from repro.core.schedule import Schedule
from repro.kernels.flash_attention import ops as fa_ops
from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig

# n_kv_heads == n_heads so the serving SDPA path dispatches the pallas
# kernel directly (no grouped-head remap); use_pallas routes prefill
# through the SIP flash-attention kernel — the thing being hot-swapped
CFG = ModelConfig(name="hs", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                  dtype="float32", use_pallas=True).validate()
MAX_LEN = 32
PLEN = 16


@pytest.fixture(scope="module")
def params():
    return nn.unwrap(M.init_lm(jax.random.PRNGKey(0), CFG))


@pytest.fixture(scope="module")
def reference(params):
    """Default-schedule single-request generation — outputs must be
    identical before AND after the swap."""
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, CFG.vocab, PLEN).astype(np.int32),
             int(rng.integers(4, 9))) for _ in range(4)]
    ref = Engine(params, CFG, ServeConfig(max_len=MAX_LEN))
    want = [ref.generate(p[None], n)[0] for p, n in reqs]
    return reqs, want


def _promote_prefill_schedule(store: ScheduleCache) -> Schedule:
    """Commit a legal NON-default schedule for the exact prefill shape the
    engine dispatches ((1, H, PLEN, hd) causal) — an autotune promotion."""
    name = fa_ops.ensure_registered(causal=True, window=None)
    kern = registry.get(name)
    hd = CFG.d_model // CFG.n_heads
    ex = [np.zeros((1, CFG.n_heads, PLEN, hd), np.float32)] * 3
    static = kern.static_of(*ex)
    space = registry.spec(name).space_for(**static)
    knobs = {k.name: k.choices[-1] for k in space.knobs}
    sched = Schedule(knobs=knobs)
    assert knobs != space.default_knobs(), "swap must change the schedule"
    store.commit([PendingPut(kernel_name=name, signature=kern.sig_str(static),
                             schedule=sched, energy=1e-9, tests_passed=True,
                             meta={"autotune": True})])
    return sched


def _run_with_midstream_swap(params, reqs, scfg):
    store = ScheduleCache()
    with schedule_cache(store):
        eng = ContinuousEngine(params, CFG, scfg)
        handles = [eng.submit(*reqs[j]) for j in (0, 1)]
        for _ in range(3):                   # first two requests in flight
            eng.step()
        v0 = store.version
        _promote_prefill_schedule(store)     # the hot-swap commit
        assert store.changed_since(v0)
        handles += [eng.submit(*reqs[j]) for j in (2, 3)]
        out = eng.run(max_steps=10_000)
    return eng, [out[h.uid] for h in handles]


class TestHotSwapDifferential:
    def test_contiguous_token_identical_across_swap(self, params, reference):
        reqs, want = reference
        eng, got = _run_with_midstream_swap(
            params, reqs, ServeConfig(max_len=MAX_LEN, capacity=2))
        assert eng.stats["schedule_swaps"] == 1
        for j in range(len(reqs)):
            np.testing.assert_array_equal(got[j], want[j],
                                          err_msg=f"request {j}")

    def test_paged_token_identical_across_swap(self, params, reference):
        reqs, want = reference
        eng, got = _run_with_midstream_swap(
            params, reqs, ServeConfig(max_len=MAX_LEN, capacity=2,
                                      paged=True, page_size=8))
        assert eng.stats["schedule_swaps"] == 1
        for j in range(len(reqs)):
            np.testing.assert_array_equal(got[j], want[j],
                                          err_msg=f"request {j} (paged)")

    def test_swapped_schedule_actually_serves(self, params):
        """The post-swap trace resolves the promoted schedule (not a stale
        memo): the kernel's resolution version tracks the store's."""
        store = ScheduleCache()
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, CFG.vocab, PLEN).astype(np.int32)
        with schedule_cache(store):
            eng = ContinuousEngine(params, CFG,
                                   ServeConfig(max_len=MAX_LEN, capacity=1))
            h1 = eng.submit(prompt, 4)
            out1 = eng.run(max_steps=10_000)[h1.uid]
            sched = _promote_prefill_schedule(store)
            h2 = eng.submit(prompt, 4)       # re-prefills through the swap
            out2 = eng.run(max_steps=10_000)[h2.uid]
            np.testing.assert_array_equal(out1, out2)
            name = fa_ops.ensure_registered(causal=True, window=None)
            kern = registry.get(name)
            assert kern._resolved_version == store.version
            hd = CFG.d_model // CFG.n_heads
            ex = [np.zeros((1, CFG.n_heads, PLEN, hd), np.float32)] * 3
            best = store.best(name, kern.sig_str(kern.static_of(*ex)))
            assert best is not None and best.knobs == sched.knobs
        assert eng.stats["schedule_swaps"] == 1

    def test_no_swap_without_commit(self, params, reference):
        reqs, _ = reference
        with schedule_cache(ScheduleCache()):
            eng = ContinuousEngine(params, CFG,
                                   ServeConfig(max_len=MAX_LEN, capacity=2))
            for j in range(2):
                eng.submit(*reqs[j])
            eng.run(max_steps=10_000)
        assert eng.stats["schedule_swaps"] == 0


class TestMidStepPromotion:
    def test_commit_during_emission_swaps_same_step(self, params, reference):
        """Regression: the store version is polled at EVERY dispatch site,
        not just the top of step().  A commit landing from an on_token
        callback during the admission prefill's emission must be picked up
        by the SAME step's decode dispatch — a top-of-step-only poll would
        leave the swap uncounted (and the decode traced against stale
        schedules) until the next step began."""
        reqs, want = reference
        store = ScheduleCache()
        committed = []

        def promote_once(req, tok):
            if not committed:
                committed.append(tok)
                _promote_prefill_schedule(store)

        with schedule_cache(store):
            eng = ContinuousEngine(params, CFG,
                                   ServeConfig(max_len=MAX_LEN, capacity=2),
                                   on_token=promote_once)
            h = eng.submit(*reqs[0])
            eng.step()   # prefill emits -> callback commits -> decode polls
            assert committed, "first token never emitted"
            assert eng.stats["schedule_swaps"] == 1, \
                "mid-step commit not picked up within the same step"
            out = eng.run(max_steps=10_000)
        np.testing.assert_array_equal(out[h.uid], want[0])


class TestPagedObsWiring:
    def test_pool_and_prefix_metrics_registered(self, params, reference):
        reqs, _ = reference
        reg = obs.MetricsRegistry()
        eng = ContinuousEngine(params, CFG,
                               ServeConfig(max_len=MAX_LEN, capacity=2,
                                           paged=True, page_size=8),
                               obs=reg)
        # shared prefix: the same prompt resubmitted AFTER its first prefill
        # landed in the cache -> a hit on the second pass
        eng.submit(*reqs[0])
        for _ in range(2):
            eng.step()
        eng.submit(*reqs[0])
        eng.submit(*reqs[1])
        eng.run(max_steps=10_000)
        snap = reg.snapshot()
        for name in ("serve.page_pool.occupancy", "serve.page_pool.alloc_pages",
                     "serve.page_pool.freed_pages", "serve.prefix_cache.hits",
                     "serve.prefix_cache.misses", "serve.prefix_cache.entries",
                     "serve.prefix_cache.evictions"):
            assert name in snap, f"missing metric {name}"
        assert snap["serve.page_pool.alloc_pages"]["value"] > 0
        assert snap["serve.prefix_cache.hits"]["value"] >= 1
        assert snap["serve.prefix_cache.misses"]["value"] >= 1
        # all requests done -> pool drained back to the prefix-cache pages
        assert snap["serve.page_pool.occupancy"]["value"] < 1.0
