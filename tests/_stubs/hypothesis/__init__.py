"""Minimal, deterministic stand-in for the real ``hypothesis`` package.

Activated by the repo-root ``conftest.py`` ONLY when hypothesis is not
installed (this container has no network access).  It implements just the
surface our property tests use — ``given``/``settings``, scalar strategies,
``st.composite``, and ``hypothesis.extra.numpy`` arrays — with numpy-backed
uniform sampling seeded from the test's qualified name, so runs are
repeatable.  It does no shrinking and no edge-case database; with the real
package on the path this module is never imported.
"""

from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np


class SearchStrategy:
    """Base: ``example(rng)`` draws one value."""

    def example(self, rng: np.random.Generator):
        raise NotImplementedError

    def example_array(self, rng: np.random.Generator, shape, dtype):
        """Vectorized fallback used by ``extra.numpy.arrays``."""
        n = int(np.prod(shape)) if shape else 1
        flat = np.asarray([self.example(rng) for _ in range(n)], dtype=dtype)
        return flat.reshape(shape)

    def map(self, f):
        return _Mapped(self, f)


class _Mapped(SearchStrategy):
    def __init__(self, inner, f):
        self.inner, self.f = inner, f

    def example(self, rng):
        return self.f(self.inner.example(rng))


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strategies: SearchStrategy):
    """Run the test once per example with values drawn from ``strategies``.

    Supports the positional style used in this repo: the last
    ``len(strategies)`` parameters of the test function are filled.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 20))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                vals = [s.example(rng) for s in strategies]
                try:
                    fn(*args, *vals, **kwargs)
                except _Unsatisfied:
                    continue

        # hide the strategy-filled parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())[:-len(strategies)]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__
        wrapper._stub_max_examples = getattr(fn, "_stub_max_examples", 20)
        return wrapper

    return deco


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass


from hypothesis import strategies  # noqa: E402  (re-export for star users)
