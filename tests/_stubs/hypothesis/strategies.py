"""Scalar/collection strategies for the hypothesis stub (see __init__.py)."""

from __future__ import annotations

import functools

import numpy as np

from hypothesis import SearchStrategy


class floats(SearchStrategy):
    def __init__(self, min_value=None, max_value=None, *, width=64,
                 allow_nan=False, allow_infinity=False, **_ignored):
        self.lo = -1e9 if min_value is None else float(min_value)
        self.hi = 1e9 if max_value is None else float(max_value)
        self.width = width

    def example(self, rng):
        r = rng.random()
        if r < 0.05:
            v = self.lo
        elif r < 0.10:
            v = self.hi
        elif r < 0.15 and self.lo <= 0.0 <= self.hi:
            v = 0.0
        else:
            v = rng.uniform(self.lo, self.hi)
        if self.width == 32:
            v = float(np.float32(v))
        return float(v)

    def example_array(self, rng, shape, dtype):
        a = rng.uniform(self.lo, self.hi, size=shape)
        return a.astype(dtype)


class integers(SearchStrategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2 ** 31) if min_value is None else int(min_value)
        self.hi = 2 ** 31 - 1 if max_value is None else int(max_value)

    def example(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))

    def example_array(self, rng, shape, dtype):
        return rng.integers(self.lo, self.hi + 1, size=shape).astype(dtype)


class lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, *, min_size=0, max_size=10,
                 **_ignored):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def example(self, rng):
        n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elements.example(rng) for _ in range(n)]


class sampled_from(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, rng):
        return self.elements[int(rng.integers(len(self.elements)))]


class booleans(SearchStrategy):
    def example(self, rng):
        return bool(rng.integers(2))


class just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng):
        return self.value


class tuples(SearchStrategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def example(self, rng):
        return tuple(s.example(rng) for s in self.strategies)


class _Composite(SearchStrategy):
    def __init__(self, fn, args, kwargs):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def example(self, rng):
        draw = lambda strat: strat.example(rng)
        return self.fn(draw, *self.args, **self.kwargs)


def composite(fn):
    """``@st.composite`` — the decorated fn's first arg becomes ``draw``."""

    @functools.wraps(fn)
    def builder(*args, **kwargs):
        return _Composite(fn, args, kwargs)

    return builder
