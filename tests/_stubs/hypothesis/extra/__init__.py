"""hypothesis.extra namespace for the stub (see hypothesis/__init__.py)."""
