"""numpy array strategies for the hypothesis stub (see hypothesis/__init__.py)."""

from __future__ import annotations

import numpy as np

from hypothesis import SearchStrategy


class array_shapes(SearchStrategy):
    def __init__(self, *, min_dims=1, max_dims=None, min_side=1, max_side=None):
        self.min_dims = min_dims
        self.max_dims = max_dims if max_dims is not None else min_dims + 2
        self.min_side = min_side
        self.max_side = max_side if max_side is not None else min_side + 5

    def example(self, rng):
        ndims = int(rng.integers(self.min_dims, self.max_dims + 1))
        return tuple(int(rng.integers(self.min_side, self.max_side + 1))
                     for _ in range(ndims))


class arrays(SearchStrategy):
    def __init__(self, dtype, shape, *, elements=None, fill=None,
                 unique=False, **_ignored):
        self.dtype = np.dtype(dtype)
        self.shape = shape
        self.elements = elements

    def example(self, rng):
        shape = (self.shape.example(rng)
                 if isinstance(self.shape, SearchStrategy) else
                 tuple(self.shape))
        if self.elements is not None:
            return self.elements.example_array(rng, shape, self.dtype)
        return rng.standard_normal(shape).astype(self.dtype)
