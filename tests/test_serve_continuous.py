"""Continuous-batching engine: differential correctness vs single-request
generation, per-slot cache helpers, streaming/stats surface, and the
schedule_cache regression (scope before construction + version bump)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                  dtype="float32").validate()
MAX_LEN = 48


@pytest.fixture(scope="module")
def params():
    return nn.unwrap(M.init_lm(jax.random.PRNGKey(0), CFG))


def _requests(rng, n, lens=(4, 7, 11, 16), new=(3, 9)):
    """Mixed-length prompts + decode budgets."""
    return [(rng.integers(0, CFG.vocab, int(rng.choice(lens))).astype(np.int32),
             int(rng.integers(new[0], new[1]))) for _ in range(n)]


@pytest.fixture(scope="module")
def reference(params):
    """Single-request Engine.generate — the paper-style correctness oracle."""
    rng = np.random.default_rng(0)
    reqs = _requests(rng, 6)
    ref = Engine(params, CFG, ServeConfig(max_len=MAX_LEN))
    want = [ref.generate(p[None], n)[0] for p, n in reqs]
    return reqs, want


def _serve(params, reqs, order, capacity, **eng_kw):
    eng = ContinuousEngine(params, CFG,
                           ServeConfig(max_len=MAX_LEN, capacity=capacity),
                           **eng_kw)
    handles = {j: eng.submit(*reqs[j]) for j in order}
    out = eng.run(max_steps=10_000)
    return eng, {j: out[h.uid] for j, h in handles.items()}


class TestDifferential:
    """Greedy continuous batching must be token-identical to single-request
    generation for EVERY request — across arrival orders, batch capacities,
    and mixed prompt lengths (acceptance: >= 3 arrival orderings)."""

    @pytest.mark.parametrize("ordering", ["submit", "reversed", "shuffled"])
    def test_arrival_orders(self, params, reference, ordering):
        reqs, want = reference
        order = {"submit": list(range(len(reqs))),
                 "reversed": list(range(len(reqs)))[::-1],
                 "shuffled": list(np.random.default_rng(3)
                                  .permutation(len(reqs)))}[ordering]
        _, got = _serve(params, reqs, order, capacity=2)
        for j in range(len(reqs)):
            np.testing.assert_array_equal(got[j], want[j],
                                          err_msg=f"request {j} ({ordering})")

    @pytest.mark.parametrize("capacity", [1, 3, 8])
    def test_batch_capacities(self, params, reference, capacity):
        """capacity=1 serializes, capacity=3 churns slots, capacity=8 admits
        everything at once — all token-identical."""
        reqs, want = reference
        _, got = _serve(params, reqs, list(range(len(reqs))), capacity)
        for j in range(len(reqs)):
            np.testing.assert_array_equal(got[j], want[j])

    def test_grouped_prefill_admissions(self, params):
        """Same-length arrivals coalesce into one batched prefill and stay
        identical to batch-1 generation."""
        rng = np.random.default_rng(5)
        reqs = [(rng.integers(0, CFG.vocab, ln).astype(np.int32), 4)
                for ln in (8, 8, 8, 8, 12, 12)]
        ref = Engine(params, CFG, ServeConfig(max_len=MAX_LEN))
        want = [ref.generate(p[None], n)[0] for p, n in reqs]
        eng, got = _serve(params, reqs, list(range(len(reqs))), capacity=6)
        for j in range(len(reqs)):
            np.testing.assert_array_equal(got[j], want[j])
        # 6 admissions, but only 2 distinct prefill shapes -> 2 compiles
        assert eng.stats["admitted"] == 6
        assert eng.stats["prefill_compiles"] == 2

    def test_eos_truncation_matches(self, params, reference):
        reqs, want = reference
        # an eos id every reference output contains early keeps the test
        # meaningful; each request stops at ITS first occurrence
        eos = int(want[0][1])
        ref = Engine(params, CFG, ServeConfig(max_len=MAX_LEN))
        want_eos = [ref.generate(p[None], n, eos_id=eos)[0]
                    for p, n in reqs]
        eng = ContinuousEngine(params, CFG,
                               ServeConfig(max_len=MAX_LEN, capacity=3))
        hs = [eng.submit(p, n, eos_id=eos) for p, n in reqs]
        out = eng.run(max_steps=10_000)
        for j, h in enumerate(hs):
            np.testing.assert_array_equal(out[h.uid], want_eos[j])

    def test_ssm_family(self):
        """Per-slot state splicing for a recurrent (cacheless-attention)
        family."""
        cfg = ModelConfig(name="s", family="ssm", n_layers=2, d_model=64,
                          n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
                          ssm_state=16, ssm_headdim=32, ssm_chunk=8,
                          dtype="float32").validate()
        p = nn.unwrap(M.init_lm(jax.random.PRNGKey(1), cfg))
        rng = np.random.default_rng(6)
        reqs = [(rng.integers(0, 128, int(rng.choice([6, 10]))).astype(np.int32),
                 int(rng.integers(3, 6))) for _ in range(4)]
        ref = Engine(p, cfg, ServeConfig(max_len=24))
        want = [ref.generate(pr[None], n)[0] for pr, n in reqs]
        eng = ContinuousEngine(p, cfg, ServeConfig(max_len=24, capacity=2))
        hs = [eng.submit(pr, n) for pr, n in reqs]
        out = eng.run(max_steps=10_000)
        for j, h in enumerate(hs):
            np.testing.assert_array_equal(out[h.uid], want[j])


class TestEngineSurface:
    def test_streaming_and_stats(self, params, reference):
        reqs, _ = reference
        streamed: dict[int, list[int]] = {}
        eng = ContinuousEngine(
            params, CFG, ServeConfig(max_len=MAX_LEN, capacity=2),
            on_token=lambda r, t: streamed.setdefault(r.uid, []).append(t))
        hs = [eng.submit(p, n) for p, n in reqs]
        out = eng.run(max_steps=10_000)
        for h in hs:
            assert streamed[h.uid] == list(out[h.uid])   # stream == final
            assert h.done and h.admitted_at is not None
        s = eng.stats
        assert s["completed"] == s["submitted"] == len(reqs)
        assert s["tokens_out"] == sum(len(o) for o in out.values())
        assert 0 < s["occupancy_sum"] <= 2 * s["steps"]
        m = eng.metrics()
        assert m["queue_depth"] == 0 and m["slot_occupancy"] == 0
        assert m["tokens_per_s"] > 0 and 0 < m["prefill_frac"] < 1

    def test_submit_validation(self, params):
        eng = ContinuousEngine(params, CFG,
                               ServeConfig(max_len=16, capacity=2))
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(np.zeros(4, np.int32), 0)
        with pytest.raises(ValueError, match="exceeds max_len"):
            eng.submit(np.zeros(10, np.int32), 8)

    def test_min_prompt_for_conv_families(self):
        cfg = ModelConfig(name="s", family="ssm", n_layers=1, d_model=64,
                          n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
                          ssm_state=16, ssm_headdim=32, ssm_chunk=8,
                          dtype="float32").validate()
        p = nn.unwrap(M.init_lm(jax.random.PRNGKey(2), cfg))
        eng = ContinuousEngine(p, cfg, ServeConfig(max_len=16, capacity=1))
        with pytest.raises(ValueError, match="conv receptive field"):
            eng.submit(np.zeros(1, np.int32), 2)


class TestSlotCacheHelpers:
    """models/model.py per-slot insert/evict on the raw cache pytree."""

    def test_axes_discovery_and_roundtrip(self, params):
        ex = {"tokens": np.zeros((1, 8), np.int32)}
        caches, axes = M.alloc_slot_caches(params, CFG, 3, MAX_LEN, ex)
        assert axes["k"] == 1 and axes["v"] == 1
        assert axes["len"] == M.SLOT_AXIS_SHARED
        assert caches["k"].shape[1] == 3 and caches["len"].shape == (2, 3)

        rng = np.random.default_rng(7)
        toks = jnp.asarray(rng.integers(0, CFG.vocab, (1, 8)), jnp.int32)
        _, one = M.prefill(params, {"tokens": toks}, CFG, MAX_LEN)
        caches = M.insert_slot(caches, one, 1, axes)
        np.testing.assert_array_equal(np.asarray(caches["k"][:, 1]),
                                      np.asarray(one["k"][:, 0]))
        np.testing.assert_array_equal(np.asarray(caches["len"][:, 1]),
                                      np.asarray(one["len"]))
        assert int(caches["len"][:, 0].max()) == 0    # other slots untouched

        caches = M.evict_slot(caches, 1, axes)
        assert int(caches["len"][:, 1].max()) == 0    # masked empty
        # KV payload is left in place; the length reset is what invalidates

    def test_grouped_insert_matches_sequential(self, params):
        ex = {"tokens": np.zeros((1, 8), np.int32)}
        caches, axes = M.alloc_slot_caches(params, CFG, 4, MAX_LEN, ex)
        rng = np.random.default_rng(8)
        toks = jnp.asarray(rng.integers(0, CFG.vocab, (2, 8)), jnp.int32)
        _, grp = M.prefill(params, {"tokens": toks}, CFG, MAX_LEN)
        got = M.insert_slots(caches, grp, jnp.asarray([3, 0]), axes)
        for g, slot in enumerate([3, 0]):
            np.testing.assert_array_equal(np.asarray(got["k"][:, slot]),
                                          np.asarray(grp["k"][:, g]))
            np.testing.assert_array_equal(np.asarray(got["len"][:, slot]),
                                          np.asarray(grp["len"]))


class TestScheduleCacheRegression:
    def test_scope_before_construction_survives_version_bump(self, params):
        """A schedule_cache scope entered BEFORE engine construction must be
        honored by kernel resolution inside the serve loop, including after
        tuning bumps ScheduleCache.version mid-flight (late-binding handles +
        version-synced resolution memos)."""
        from repro.core.cache import ScheduleCache
        from repro.core.jit import TuneConfig
        from repro.core.registry import registry, schedule_cache
        from repro.kernels.flash_attention import ops as fa_ops

        store = ScheduleCache()
        cfg_p = dataclasses.replace(CFG, use_pallas=True)
        rng = np.random.default_rng(9)
        prompt = rng.integers(0, CFG.vocab, 16).astype(np.int32)
        with schedule_cache(store):
            eng = ContinuousEngine(params, cfg_p,
                                   ServeConfig(max_len=MAX_LEN, capacity=2))
            h1 = eng.submit(prompt, 4)
            out1 = eng.run(max_steps=10_000)[h1.uid]

            name = fa_ops.variant_name(True, None)
            kern = registry.get(name)
            assert kern.cache is store      # scope bound the serving instance
            v0 = store.version

            # tune the serving shape (prefill: B=1, H=4/KV=2, S=16, D=16)
            example = [rng.standard_normal((1, 4, 16, 16)).astype(np.float32),
                       rng.standard_normal((1, 2, 16, 16)).astype(np.float32),
                       rng.standard_normal((1, 2, 16, 16)).astype(np.float32)]
            kern.tune(example, TuneConfig(rounds=1, t_min=0.3, cooling=1.3,
                                          step_samples=1, final_samples=2))
            assert store.version > v0

            # deployment path now serves the TUNED schedule from the store
            static = kern.static_of(*example)
            tuned = store.best(name, kern.sig_str(static))
            assert tuned is not None
            kern(*example)                  # resolve post-bump
            assert kern._resolved_version == store.version

            # the engine keeps serving correctly after the bump: a repeat of
            # the same request (semantics-preserving schedule swap) and a new
            # prompt length (fresh trace resolves through the same store)
            h2 = eng.submit(prompt, 4)
            out2 = eng.run(max_steps=10_000)[h2.uid]
            np.testing.assert_array_equal(out2, out1)
            h3 = eng.submit(prompt[:12], 4)
            eng.run(max_steps=10_000)
            assert registry.get(name) is kern   # still the scope's instance
            assert kern._resolved_version == store.version
