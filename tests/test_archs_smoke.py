"""Per-assigned-architecture smoke tests (deliverable f).

Each of the 10 archs is instantiated at a REDUCED same-family config
(models/config.smoke_variant) and runs one forward + one train step on CPU,
asserting output shapes and no NaNs.  The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation) — tests/test_dryrun_smoke.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import steps
from repro.models import model as M
from repro.models import modules as nn
from repro.optim import adamw

B, S = 2, 32
RNG = np.random.default_rng(7)


def smoke_inputs(cfg):
    out = {"labels": jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "enc_dec":
        out["tokens"] = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
        out["enc_embeds"] = jnp.asarray(
            RNG.standard_normal((B, cfg.enc_len, cfg.d_model)), jnp.float32)
    elif cfg.input_mode == "embeddings":
        out["embeds"] = jnp.asarray(
            RNG.standard_normal((B, S, cfg.d_model)), jnp.float32)
    else:
        out["tokens"] = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return out


@pytest.mark.parametrize("arch", configs.arch_names())
class TestArchSmoke:
    def test_full_config_exact(self, arch):
        """The registered config carries the assignment's exact numbers."""
        cfg = configs.get(arch)
        expected = {
            "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
            "llama4-scout-17b-16e": (48, 5120, 40, 8, 8192, 202048),
            "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
            "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
            "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
            "seamless-m4t-large-v2": (48, 1024, 16, 16, 8192, 256206),
            "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
            "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
            "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
            "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        }[arch]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == expected, (arch, got, expected)

    def test_smoke_forward(self, arch):
        cfg = configs.get_smoke(arch)
        params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
        logits, _ = M.forward(params, smoke_inputs(cfg), cfg)
        assert logits.shape == (B, S, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch

    def test_smoke_train_step(self, arch):
        cfg = configs.get_smoke(arch)
        params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
        opt = adamw.init_opt_state(params)
        p2, o2, metrics = jax.jit(
            lambda p, o, b: steps.train_step(
                p, o, b, cfg=cfg, opt_cfg=adamw.OptConfig(warmup_steps=1)),
        )(params, opt, smoke_inputs(cfg))
        assert np.isfinite(metrics["loss"]), arch
        assert int(o2["step"]) == 1
        # parameters actually moved
        delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) -
                                          b.astype(jnp.float32))))
                    for a, b in zip(jax.tree.leaves(params),
                                    jax.tree.leaves(p2)))
        assert delta > 0, arch


class TestExtraArchProperties:
    def test_swa_arch_has_window(self):
        assert configs.get("h2o-danube-1.8b").window == 4096

    def test_qwen_qk_norm(self):
        assert configs.get("qwen3-4b").qk_norm
        assert configs.get("qwen3-1.7b").qk_norm

    def test_moe_expert_counts(self):
        dbrx = configs.get("dbrx-132b")
        assert (dbrx.n_experts, dbrx.top_k) == (16, 4)
        scout = configs.get("llama4-scout-17b-16e")
        assert (scout.n_experts, scout.top_k) == (16, 1)

    def test_long_context_applicability(self):
        runnable = {n for n, _, s, ok, _ in configs.cells()
                    if s.name == "long_500k" and ok}
        assert runnable == {"mamba2-2.7b", "zamba2-7b", "h2o-danube-1.8b"}

    def test_40_cells_enumerated(self):
        assert len(list(configs.cells())) == 40
