"""Unit + property tests for the SIP instruction IR and schedule legality."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ir import Instr, Kind, Program


def _ld(name, out, buf="A", nbytes=1024):
    return Instr(name=name, kind=Kind.MEM, inputs=(), outputs=(out,),
                 fn=lambda env, o=out: {o: env.get("_seed", 1.0)},
                 buffer=buf, bytes=nbytes)


def _st(name, src, buf="O", nbytes=1024):
    return Instr(name=name, kind=Kind.MEM, inputs=(src,), outputs=(),
                 fn=lambda env, s=src: {"_stored": env[s]},
                 buffer=buf, is_store=True, bytes=nbytes)


def _add(name, a, b, out):
    return Instr(name=name, kind=Kind.COMPUTE, inputs=(a, b), outputs=(out,),
                 fn=lambda env, a=a, b=b, o=out: {o: env[a] + env[b]},
                 flops=1)


def chain_program():
    return Program([
        _ld("ld_a", "a"),
        _ld("ld_b", "b", buf="B"),
        _add("add0", "a", "b", "c"),
        _ld("ld_d", "d", buf="D"),
        _add("add1", "c", "d", "e"),
        _st("st_e", "e"),
    ])


class TestDependencies:
    def test_raw_edges(self):
        p = chain_program()
        # add0 depends on both loads
        assert {0, 1} <= p.deps[2]
        # add1 depends on add0 and ld_d
        assert {2, 3} <= p.deps[4]
        # store depends on add1
        assert 4 in p.deps[5]

    def test_default_order_legal(self):
        p = chain_program()
        assert p.is_legal(p.default_order())

    def test_illegal_order_detected(self):
        p = chain_program()
        order = list(p.default_order())
        order[0], order[2] = order[2], order[0]  # add before its loads
        assert not p.is_legal(order)

    def test_war_edge(self):
        # i0 reads x, i1 overwrites x -> i1 must stay after i0
        i0 = _add("use_x", "x", "x", "y")
        i1 = _ld("clobber_x", "x")
        p = Program([i0, i1])
        assert 0 in p.deps[1]
        assert p.move(p.default_order(), 1, -1) is None

    def test_store_orders_against_buffer_accesses(self):
        p = Program([
            _ld("ld1", "a", buf="BUF"),
            _st("st1", "a", buf="BUF"),
            _ld("ld2", "b", buf="BUF"),
        ])
        assert 0 in p.deps[1]   # store after load of same buffer
        assert 1 in p.deps[2]   # later load after store

    def test_duplicate_names_rejected(self):
        with pytest.raises(ValueError):
            Program([_ld("x", "a"), _ld("x", "b", buf="B")])


class TestMoves:
    def test_move_up_is_paper_action(self):
        p = chain_program()
        # ld_d (idx 3) can move up past add0 (no dependency)
        order = p.move(p.default_order(), 3, -1)
        assert order is not None and p.is_legal(order)
        assert order.index(3) == 2

    def test_move_blocked_by_dependency(self):
        p = chain_program()
        # store cannot move above add1
        assert p.move(p.default_order(), 5, -1) is None

    def test_out_of_range(self):
        p = chain_program()
        assert p.move(p.default_order(), 0, -1) is None

    def test_legal_moves_only_mem(self):
        p = chain_program()
        moved = {idx for idx, _ in p.legal_moves(p.default_order())}
        assert moved <= set(p.mem_indices())

    def test_execute_respects_order_and_value(self):
        p = chain_program()
        env = p.execute({"_seed": 2.0})
        assert env["_stored"] == 2.0 + 2.0 + 2.0  # a+b+d

    def test_execute_rejects_illegal(self):
        p = chain_program()
        order = list(p.default_order())
        order[0], order[2] = order[2], order[0]
        with pytest.raises(ValueError):
            p.execute({}, order)


@st.composite
def random_walks(draw):
    n_moves = draw(st.integers(min_value=0, max_value=40))
    seeds = draw(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=1))
    return n_moves, seeds[0]


class TestProperties:
    @given(random_walks())
    @settings(max_examples=50, deadline=None)
    def test_random_legal_walk_stays_legal_and_correct(self, walk):
        """Invariant: any sequence of paper-actions keeps the schedule legal
        and the executed result identical (dependency-legal reorders are
        semantics-preserving)."""
        n_moves, seed = walk
        rng = np.random.default_rng(seed)
        p = chain_program()
        order = p.default_order()
        base = p.execute({"_seed": 3.0})["_stored"]
        for _ in range(n_moves):
            moves = p.legal_moves(order)
            if not moves:
                break
            idx, d = moves[int(rng.integers(len(moves)))]
            new = p.move(order, idx, d)
            assert new is not None
            order = new
            assert p.is_legal(order)
        assert p.execute({"_seed": 3.0}, order)["_stored"] == base
