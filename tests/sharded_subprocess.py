"""Subprocess body for multi-device sharding tests (8 host devices).

Run as:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
         python sharded_subprocess.py <mode>
Prints a single JSON line with the result."""

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np


def train_parity():
    """Sharded train step on a (4, 2) mesh == single-device step."""
    from repro.dist import partition
    from repro.launch import steps
    from repro.models import model as M
    from repro.models import modules as nn
    from repro.models.config import ModelConfig
    from repro.optim import adamw

    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      n_experts=2, top_k=1, capacity_factor=2.0,
                      dtype="float32")
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 128, (8, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 128, (8, 16)), jnp.int32)}
    ptree = M.init_lm(jax.random.PRNGKey(0), cfg)
    params = nn.unwrap(ptree)
    opt = adamw.init_opt_state(params)
    ocfg = adamw.OptConfig()

    p_ref, _, m_ref = steps.train_step(params, opt, batch, cfg=cfg,
                                       opt_cfg=ocfg)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with partition.mesh_rules(mesh):
        pshard = steps.param_shardings(ptree, mesh)
        oshard = steps.opt_shardings(pshard, mesh)
        bshard = steps.batch_shardings(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         batch), mesh)
        params_s = jax.device_put(params, pshard)
        opt_s = jax.device_put(opt, oshard)
        batch_s = jax.device_put(batch, bshard)
        jfn = jax.jit(lambda p, o, b: steps.train_step(p, o, b, cfg=cfg,
                                                       opt_cfg=ocfg),
                      in_shardings=(pshard, oshard, bshard),
                      out_shardings=(pshard, oshard, None))
        p_sh, _, m_sh = jfn(params_s, opt_s, batch_s)

    errs = [float(np.max(np.abs(np.asarray(a, np.float64) -
                                np.asarray(b, np.float64))) /
                  (np.max(np.abs(np.asarray(a, np.float64))) + 1e-9))
            for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh))]
    print(json.dumps({"max_rel_err": max(errs),
                      "loss_ref": float(m_ref["loss"]),
                      "loss_sh": float(m_sh["loss"])}))


def compressed_psum_test():
    from jax.sharding import PartitionSpec as P
    from repro.dist import collectives
    from repro.dist.compat import shard_map

    mesh = jax.make_mesh((8,), ("pod",))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 64, 32)), jnp.float32)

    exact = shard_map(
        lambda v: jax.lax.psum(v[0], "pod"), mesh=mesh,
        in_specs=P("pod", None, None), out_specs=P(None, None))(x)
    # check_vma=False: the compressed reduction is value-replicated (sum of
    # all-gathered blocks) but shard_map cannot prove it
    comp = shard_map(
        lambda v: collectives.compressed_psum(v[0], "pod"), mesh=mesh,
        in_specs=P("pod", None, None), out_specs=P(None, None),
        check_vma=False)(x)
    want = np.sum(np.asarray(x), axis=0)
    rel = float(np.max(np.abs(np.asarray(comp) - want)) /
                np.max(np.abs(want)))
    exact_err = float(np.max(np.abs(np.asarray(exact) - want)))
    print(json.dumps({"rel_err": rel, "exact_is_exact": exact_err}))


def tp_parity():
    """Manual shard_map TP (dist.tp): prefill + greedy decode over the
    model fns must produce the single-device tokens at every eligible mesh
    width, and the compressed seams must stay within int8 tolerance."""
    import functools

    from jax.sharding import PartitionSpec as P
    from repro.dist import tp
    from repro.dist.compat import shard_map
    from repro.launch import mesh as mesh_lib
    from repro.models import model as M
    from repro.models import modules as nn
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=8, n_kv_heads=4, d_ff=256, vocab=128,
                      dtype="float32")
    params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    inputs = {"tokens": jnp.asarray(rng.integers(0, 128, (2, 16)), jnp.int32)}
    max_len = 24

    def greedy(prefill_fn, decode_fn, p):
        logits, caches = prefill_fn(p, inputs)
        toks = [np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))]
        for _ in range(4):
            logits, caches = decode_fn(p, caches, jnp.asarray(toks[-1]))
            toks.append(np.asarray(jnp.argmax(logits, -1).astype(jnp.int32)))
        return np.stack(toks, 1), np.asarray(logits)

    ref_toks, ref_logits = greedy(
        jax.jit(functools.partial(M.prefill, cfg=cfg, max_len=max_len)),
        jax.jit(functools.partial(M.decode_step, cfg=cfg)), params)

    paxes = M.param_logical_axes(cfg)
    pspecs = tp.tp_specs(paxes)
    cspecs = tp.tp_specs(M.cache_logical_axes(cfg))
    out = {}
    for n in (2, 4):
        ok, why = tp.tp_eligible(cfg, n)
        assert ok, why
        mesh = mesh_lib.mesh_for((n,), ("model",))
        params_s = jax.device_put(params, tp.tp_shardings(paxes, mesh))

        def rep(tree):
            return jax.tree.map(lambda x: P(*[None] * jnp.ndim(x)), tree)

        def sm_prefill(p, i, *, compressed=False):
            def body(pp, ii):
                with tp.tp_context("model", compressed=compressed):
                    return M.prefill(pp, ii, cfg, max_len=max_len)
            return shard_map(body, mesh=mesh, in_specs=(pspecs, rep(i)),
                             out_specs=(P(), cspecs),
                             check_vma=False)(p, i)

        def sm_decode(p, c, t, *, compressed=False):
            def body(pp, cc, tt):
                with tp.tp_context("model", compressed=compressed):
                    return M.decode_step(pp, cc, tt, cfg)
            return shard_map(body, mesh=mesh, in_specs=(pspecs, cspecs,
                                                        rep(t)),
                             out_specs=(P(), cspecs),
                             check_vma=False)(p, c, t)

        tp_toks, tp_logits = greedy(jax.jit(sm_prefill), jax.jit(sm_decode),
                                    params_s)
        # compressed seams: bounded error vs the exact-psum prefill logits,
        # not bit parity
        logits_x, _ = jax.jit(sm_prefill)(params_s, inputs)
        logits_c, _ = jax.jit(
            functools.partial(sm_prefill, compressed=True))(params_s, inputs)
        out[f"mesh{n}_tokens_equal"] = bool(np.array_equal(ref_toks, tp_toks))
        out[f"mesh{n}_logit_err"] = float(np.max(np.abs(tp_logits -
                                                        ref_logits)))
        out[f"mesh{n}_compressed_rel"] = float(
            np.max(np.abs(np.asarray(logits_c) - np.asarray(logits_x))) /
            (np.max(np.abs(np.asarray(logits_x))) + 1e-9))
    print(json.dumps(out))


def serve_sharded():
    """Tensor-parallel ContinuousEngine == 1-device ContinuousEngine, token
    for token: contiguous + paged layouts, shard_map + GSPMD paths, two mesh
    shapes, two arrival orderings; compressed seams must at least serve."""
    from repro.launch import mesh as mesh_lib
    from repro.models import model as M
    from repro.models import modules as nn
    from repro.models.config import ModelConfig
    from repro.serve.engine import ContinuousEngine, ServeConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=8, n_kv_heads=4, d_ff=256, vocab=128,
                      dtype="float32")
    params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(7)
    # few distinct lengths -> few prefill compiles; > capacity requests so
    # ordering changes the batching/splicing pattern
    reqs = [(rng.integers(1, 128, n).astype(np.int32), b)
            for n, b in ((6, 5), (12, 4), (6, 6), (18, 3), (12, 5))]

    def run(mesh=None, paged=False, reverse=False, **kw):
        scfg = ServeConfig(max_len=48, capacity=3, paged=paged, page_size=8,
                           prefill_chunk=8 if paged else None, **kw)
        eng = ContinuousEngine(params, cfg, scfg, mesh=mesh)
        order = reqs[::-1] if reverse else reqs
        for p, b in order:
            eng.submit(p, b)
        done = eng.run(max_steps=2000)
        return {tuple(p.tolist()): done[uid].tolist()
                for uid, (p, _) in enumerate(order)}

    ref = run()
    out = {"ref_paged_equal": run(paged=True) == ref}
    for n in (2, 4):
        mesh = mesh_lib.mesh_for((n,), ("model",))
        for paged in (False, True):
            for reverse in (False, True):
                got = run(mesh=mesh, paged=paged, reverse=reverse)
                key = (f"mesh{n}_{'paged' if paged else 'contig'}"
                       f"_{'rev' if reverse else 'fwd'}")
                out[key] = got == ref
        out[f"mesh{n}_gspmd"] = run(mesh=mesh, tp_mode="gspmd") == ref
        comp = run(mesh=mesh, compressed_collectives=True)
        out[f"mesh{n}_compressed_served"] = sorted(
            len(v) for v in comp.values()) == sorted(b for _, b in reqs)
    print(json.dumps(out))


def elastic():
    """Save params sharded on (4,2), restore onto (2,4) and (8,1) —
    values must be identical (mesh-independent checkpoints)."""
    import tempfile

    from repro.checkpoint.ckpt import CheckpointManager
    from repro.launch import steps
    from repro.models import model as M
    from repro.models import modules as nn
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      dtype="float32")
    ptree = M.init_lm(jax.random.PRNGKey(3), cfg)
    params = nn.unwrap(ptree)

    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    shard_a = steps.param_shardings(ptree, mesh_a)
    params_a = jax.device_put(params, shard_a)

    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(1, params_a)
        ok = True
        for shape in ((2, 4), (8, 1), (1, 8)):
            mesh_b = jax.make_mesh(shape, ("data", "model"))
            shard_b = steps.param_shardings(ptree, mesh_b)
            restored = cm.restore(1, params, shard_b)
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
                if not np.array_equal(np.asarray(a), np.asarray(b)):
                    ok = False
            # restored arrays actually carry the new shardings
            leaf = jax.tree.leaves(restored)[0]
            if leaf.sharding.mesh.shape != mesh_b.shape:
                ok = False
        print(json.dumps({"identical": ok}))


def elastic_supervised():
    """Supervised train on a (4,2) mesh; two workers die permanently mid-run
    → FTManager orders ELASTIC_RESHAPE onto the (2,2) ladder rung; the
    supervisor rebuilds the mesh from the surviving devices and the restore
    reshards every leaf.  Final loss must match the uninterrupted (4,2)
    baseline (restarted arithmetic on a different mesh: tolerance, not
    bit-equality)."""
    import functools
    import tempfile

    from repro.data.pipeline import DataConfig
    from repro.ft import (ChaosEngine, FaultPlan, FTConfig, FTManager,
                          Supervisor)
    from repro.launch import mesh as mesh_lib
    from repro.models.config import ModelConfig
    from repro.optim import adamw
    from repro.train.loop import TrainConfig, train

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      dtype="float32")
    dcfg = DataConfig(global_batch=8, seq_len=16, vocab=128)
    ocfg = adamw.OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=12)
    axes = ("data", "model")
    ladder = (((4, 2), axes), ((2, 2), axes), ((1, 2), axes))

    with tempfile.TemporaryDirectory() as d_base, \
            tempfile.TemporaryDirectory() as d_chaos:
        tcfg_b = TrainConfig(total_steps=12, ckpt_every=4, ckpt_dir=d_base,
                             log_every=1000)
        base = train(cfg, dcfg, tcfg_b, ocfg,
                     mesh=mesh_lib.mesh_for((4, 2), axes))

        # 4 logical workers x 2 chips; clock ticks per heartbeat so the
        # suppressed workers time out deterministically fast
        t = [0.0]
        ft = FTManager(n_workers=4,
                       cfg=FTConfig(heartbeat_timeout_s=1.0,
                                    chips_per_worker=2, mesh_ladder=ladder),
                       clock=lambda: t[0])
        orig_hb = ft.heartbeat

        def ticking_hb(w, lat):
            t[0] += 0.1
            orig_hb(w, lat)

        ft.heartbeat = ticking_hb
        chaos = ChaosEngine(FaultPlan.parse("kill@4:w2:perm,kill@4:w3:perm",
                                            n_workers=4))
        tcfg = TrainConfig(total_steps=12, ckpt_every=4, ckpt_dir=d_chaos,
                           log_every=1000)
        sup = Supervisor(
            functools.partial(train, cfg, dcfg, tcfg, ocfg, ft=ft,
                              chaos=chaos),
            ft=ft, chaos=chaos, mesh=mesh_lib.mesh_for((4, 2), axes),
            mesh_factory=lambda target: mesh_lib.mesh_for(*target),
            sleep=lambda s: None)
        res = sup.run()
        s = res["supervisor"]
        print(json.dumps({
            "step": res["step"],
            "final_loss": res["final_loss"],
            "base_loss": base["final_loss"],
            "events": [e["kind"] for e in s["events"]],
            "final_mesh": list(s["final_mesh"][0]) if s["final_mesh"] else None,
        }))


if __name__ == "__main__":
    mode = sys.argv[1]
    assert len(jax.devices()) == 8, jax.devices()
    {"train_parity": train_parity,
     "compressed_psum": compressed_psum_test,
     "tp_parity": tp_parity,
     "serve_sharded": serve_sharded,
     "elastic": elastic,
     "elastic_supervised": elastic_supervised}[mode]()
