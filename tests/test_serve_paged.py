"""Paged-KV serving: differential correctness vs the static reference
engine (greedy token identity across arrival orderings, with prefix sharing
and chunked prefill on), page accounting (no leaks, reservation-at-admission),
the relaxed page-capacity admission bound, and the compile-count guarantee of
fixed chunk shapes."""

import numpy as np
import pytest

import jax

from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig
from repro.serve.pages import PagesExhausted

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                  dtype="float32").validate()
MAX_LEN = 48


@pytest.fixture(scope="module")
def params():
    return nn.unwrap(M.init_lm(jax.random.PRNGKey(0), CFG))


def _requests(rng, n, lo=3, hi=28, new=(2, 8)):
    reqs = [(rng.integers(1, CFG.vocab, int(rng.integers(lo, hi)))
             .astype(np.int32), int(rng.integers(*new))) for _ in range(n)]
    # force one shared >1-page prefix pair into every mix
    p, b = reqs[0]
    reqs.append((np.concatenate([p[:len(p) - 1], [7, 9, 11]])
                 .astype(np.int32), b))
    return reqs


@pytest.fixture(scope="module")
def reference(params):
    rng = np.random.default_rng(0)
    reqs = _requests(rng, 5)
    ref = Engine(params, CFG, ServeConfig(max_len=MAX_LEN))
    outs = [ref.generate(p[None], b)[0] for p, b in reqs]
    return reqs, outs


def _paged_cfg(**kw):
    base = dict(max_len=MAX_LEN, capacity=3, paged=True, page_size=8,
                prefill_chunk=8)
    base.update(kw)
    return ServeConfig(**base)


class TestDifferential:
    """Greedy paged output == static Engine output, token for token."""

    @pytest.mark.parametrize("order", ["fifo", "reversed", "staggered"])
    def test_arrival_orderings(self, params, reference, order):
        reqs, outs = reference
        eng = ContinuousEngine(params, CFG, _paged_cfg())
        idxs = list(range(len(reqs)))
        if order == "reversed":
            idxs = idxs[::-1]
        uid_to_idx = {}
        if order == "staggered":
            # half up front, the rest arriving mid-flight
            for i in idxs[:2]:
                uid_to_idx[eng.submit(*reqs[i]).uid] = i
            got = {}
            for _ in range(3):
                for r in eng.step():
                    got[r.uid] = r.output
            for i in idxs[2:]:
                uid_to_idx[eng.submit(*reqs[i]).uid] = i
            got.update(eng.run(max_steps=500))
        else:
            for i in idxs:
                uid_to_idx[eng.submit(*reqs[i]).uid] = i
            got = eng.run(max_steps=500)
        for uid, i in uid_to_idx.items():
            assert np.array_equal(got[uid], outs[i]), \
                f"req {i} diverged under {order} arrival"
        # all pages back except the prefix cache's own references
        assert eng.pages.used_pages == (len(eng.prefix)
                                        if eng.prefix else 0)

    def test_no_prefix_no_chunk_matches_too(self, params, reference):
        reqs, outs = reference
        eng = ContinuousEngine(params, CFG, _paged_cfg(
            prefix_cache=False, prefill_chunk=None))
        uids = [eng.submit(p, b).uid for p, b in reqs]
        got = eng.run(max_steps=500)
        for uid, out in zip(uids, outs):
            assert np.array_equal(got[uid], out)
        assert eng.pages.used_pages == 0          # nothing may leak

    def test_tight_pool_queues_and_completes(self, params, reference):
        """Fewer pages than the workload's worst case: admission must make
        the head of line wait (never deadlock, never corrupt) and still
        reproduce the reference stream."""
        reqs, outs = reference
        eng = ContinuousEngine(params, CFG, _paged_cfg(
            capacity=2, num_pages=11))
        uids = [eng.submit(p, b).uid for p, b in reqs]
        got = eng.run(max_steps=1000)
        for uid, out in zip(uids, outs):
            assert np.array_equal(got[uid], out)


class TestPrefixSharing:
    def test_sequential_identical_prefixes_hit(self, params):
        rng = np.random.default_rng(3)
        base = rng.integers(1, CFG.vocab, 21).astype(np.int32)
        tail = np.concatenate([base[:20],
                               rng.integers(1, CFG.vocab, 6)]).astype(np.int32)
        ref = Engine(params, CFG, ServeConfig(max_len=MAX_LEN))
        want = [ref.generate(p[None], 5)[0] for p in (base, tail)]

        eng = ContinuousEngine(params, CFG, _paged_cfg(capacity=2))
        r1 = eng.submit(base, 5)
        out = eng.run(max_steps=200)
        assert np.array_equal(out[r1.uid], want[0])
        r2 = eng.submit(tail, 5)
        out = eng.run(max_steps=200)
        assert np.array_equal(out[r2.uid], want[1])
        # 20 shared tokens / 8-token pages -> 2 full pages skipped
        assert eng.stats["prefix_hits"] == 1
        assert eng.stats["prefix_tokens_saved"] == 16
        assert eng.metrics()["prefix_hits"] == 1.0

    def test_shared_pages_survive_owner_eviction(self, params):
        """The cache's reference keeps a registered page alive after the
        registering request finishes — a later identical prompt still hits."""
        rng = np.random.default_rng(4)
        p = rng.integers(1, CFG.vocab, 17).astype(np.int32)
        eng = ContinuousEngine(params, CFG, _paged_cfg(capacity=1))
        eng.submit(p, 3)
        eng.run(max_steps=200)
        assert len(eng.prefix) == 2               # (17-1)//8 blocks
        held = eng.pages.used_pages
        assert held == 2                          # only cache refs remain
        eng.submit(p, 3)
        eng.run(max_steps=200)
        assert eng.stats["prefix_hits"] == 1


class TestChunkedPrefill:
    def test_compiles_bounded_by_chunk_shapes(self, params):
        """Prompt LENGTHS must not drive prefill compiles: every chunked
        prompt reuses the one (1, chunk) trace, padded tail included."""
        rng = np.random.default_rng(5)
        eng = ContinuousEngine(params, CFG, _paged_cfg(
            prefix_cache=False, capacity=2))
        lens = [12, 17, 23, 27, 40]               # all > chunk, all distinct
        refs = []
        for n in lens:
            p = rng.integers(1, CFG.vocab, n).astype(np.int32)
            refs.append((eng.submit(p, 3), p))
        eng.run(max_steps=500)
        assert eng.stats["chunk_steps"] == sum(-(-n // 8) for n in lens)
        assert eng.stats["prefill_compiles"] == 1
        # and decode emitted everything it owed
        assert eng.stats["completed"] == len(lens)

    def test_group_prefill_compiles_counted_page_granular(self, params):
        """Un-chunked paged prefills jit at the page-rounded length, so the
        compile counter must key on that too: distinct exact prompt lengths
        rounding to the same page count are ONE compile, not one each."""
        eng = ContinuousEngine(params, CFG, _paged_cfg(
            prefix_cache=False, prefill_chunk=None, capacity=1))
        rng = np.random.default_rng(7)
        for n in (3, 5, 7):               # all round up to one 8-token page
            eng.submit(rng.integers(1, CFG.vocab, n).astype(np.int32), 2)
            eng.run(max_steps=100)
        assert eng.stats["prefill_compiles"] == 1

    def test_padded_final_chunk_overflowing_full_table(self, params):
        """A final zero-padded chunk can overrun the slot's page table when
        the worst-case reservation fills it entirely; those positions must
        scatter to the trash page, not wrap onto the table's LAST real page
        and overwrite live prompt/decode KV (regression: the overflow was
        clamped to the last page-table column)."""
        # worst = ceil((45+3)/8) = 6 pages == the full ceil(48/8) table, and
        # the final chunk covers positions [40, 60) — 48..59 overflow
        scfg = ServeConfig(max_len=48, capacity=1, paged=True, page_size=8,
                           prefill_chunk=20, prefix_cache=False)
        eng = ContinuousEngine(params, CFG, scfg)
        prompt = np.arange(1, 46, dtype=np.int32)
        r = eng.submit(prompt, 3)
        out = eng.run(max_steps=200)
        ref = Engine(params, CFG, ServeConfig(max_len=48))
        assert np.array_equal(out[r.uid], ref.generate(prompt[None], 3)[0])

    def test_long_prompt_interleaves_with_decode(self, params):
        """A long chunked prompt must not stall an in-flight decode: the
        short request keeps emitting tokens while the long one prefills."""
        rng = np.random.default_rng(6)
        short = rng.integers(1, CFG.vocab, 4).astype(np.int32)
        long = rng.integers(1, CFG.vocab, 40).astype(np.int32)
        eng = ContinuousEngine(params, CFG, _paged_cfg(
            capacity=2, prefix_cache=False, prefill_chunk=8))
        rs = eng.submit(short, 8)
        rl = eng.submit(long, 3)
        steps = 0
        while not rl.tokens:                       # long still chunking
            eng.step()
            steps += 1
            assert steps < 50
        # 40 tokens / 8-token chunks = 5 prefill steps, and the short
        # request emitted a token through every one of them
        assert len(rs.tokens) >= 4
        eng.run(max_steps=200)
        ref = Engine(params, CFG, ServeConfig(max_len=MAX_LEN))
        assert np.array_equal(rl.output, ref.generate(long[None], 3)[0])
        assert np.array_equal(rs.output, ref.generate(short[None], 8)[0])


class TestAdmissionBounds:
    def test_dense_engine_still_rejects_past_max_len(self, params):
        eng = ContinuousEngine(params, CFG, ServeConfig(max_len=MAX_LEN))
        eng.submit(np.arange(1, 41, dtype=np.int32), 8)     # == max_len: ok
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(np.arange(1, 41, dtype=np.int32), 9)  # one past: no

    def test_paged_accepts_up_to_page_rounded_bound(self, params):
        """The old hard ``> max_len`` rejection is gone in paged mode: the
        bound is the page table (max_len rounded UP to pages), and a request
        in the formerly rejected gap completes correctly."""
        scfg = ServeConfig(max_len=40, capacity=2, paged=True, page_size=16,
                           prefill_chunk=8, prefix_cache=False)
        eng = ContinuousEngine(params, CFG, scfg)
        prompt = np.arange(1, 41, dtype=np.int32)            # 40 + 4 > max_len
        r = eng.submit(prompt, 4)                            # but <= 3*16
        out = eng.run(max_steps=300)
        ref = Engine(params, CFG, ServeConfig(max_len=48))
        assert np.array_equal(out[r.uid], ref.generate(prompt[None], 4)[0])
        with pytest.raises(ValueError, match="page table"):
            eng.submit(prompt, 9)                            # 49 > 48: never

    def test_never_fits_raises_even_with_queue_policy(self, params):
        eng = ContinuousEngine(params, CFG, ServeConfig(
            max_len=MAX_LEN, capacity=2, paged=True, page_size=8,
            num_pages=4))                                    # 3 usable pages
        with pytest.raises(ValueError, match="never"):
            eng.submit(np.arange(1, 30, dtype=np.int32), 4)  # needs 5 pages

    def test_reject_policy_raises_when_it_cannot_start_now(self, params):
        eng = ContinuousEngine(params, CFG, ServeConfig(
            max_len=MAX_LEN, capacity=1, paged=True, page_size=8,
            admission="reject", prefix_cache=False))
        p = np.arange(1, 10, dtype=np.int32)
        r1 = eng.submit(p, 3)                    # queue empty: accepted
        with pytest.raises(PagesExhausted):
            eng.submit(p, 3)                     # r1 is ahead of it
        eng.run(max_steps=200)
        assert r1.done
        r2 = eng.submit(p, 3)                    # capacity is back: accepted
        eng.run(max_steps=200)
        assert r2.done

    def test_queue_policy_waits_instead(self, params):
        eng = ContinuousEngine(params, CFG, ServeConfig(
            max_len=MAX_LEN, capacity=1, paged=True, page_size=8,
            prefix_cache=False))
        p = np.arange(1, 10, dtype=np.int32)
        rs = [eng.submit(p, 3) for _ in range(3)]
        eng.run(max_steps=500)
        assert all(r.done for r in rs)


class TestGating:
    def test_paged_rejects_non_attention_families(self):
        ssm = ModelConfig(name="s", family="ssm", n_layers=2, d_model=64,
                          n_heads=0, n_kv_heads=0, d_ff=0, vocab=64,
                          ssm_state=16, ssm_headdim=32, ssm_chunk=8,
                          dtype="float32").validate()
        params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), ssm))
        with pytest.raises(ValueError, match="paged"):
            ContinuousEngine(params, ssm, _paged_cfg())

    def test_bad_admission_policy_rejected(self, params):
        with pytest.raises(ValueError, match="admission"):
            ContinuousEngine(params, CFG, _paged_cfg(admission="drop"))

    def test_paged_decode_step_guard(self, params):
        ssm = ModelConfig(name="s", family="ssm", n_layers=2, d_model=64,
                          n_heads=0, n_kv_heads=0, d_ff=0, vocab=64,
                          ssm_state=16, ssm_headdim=32, ssm_chunk=8,
                          dtype="float32").validate()
        sp = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), ssm))
        eng = ContinuousEngine(sp, ssm, ServeConfig(max_len=16))
        with pytest.raises(ValueError, match="attention"):
            M.decode_step(sp, eng.caches, np.zeros(8, np.int32), ssm,
                          pt=np.zeros((8, 2), np.int32))
