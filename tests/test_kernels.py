"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode on CPU).

Every Pallas kernel is swept over shapes / dtypes / schedule knobs and every
legal instruction-order perturbation class we care about, asserting
equivalence with ref.py — the same contract SIP's probabilistic testing
enforces at search time.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.schedule import Schedule
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.gemm_fused import ops as gemm_ops
from repro.kernels.gemm_fused import ref as gemm_ref
from repro.kernels.rmsnorm import ops as rms_ops
from repro.kernels.rmsnorm import ref as rms_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == np.float16 or dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)


class TestGemmFused:
    @pytest.mark.parametrize("m,n,k", [(32, 32, 32), (64, 128, 96),
                                       (128, 64, 256), (8, 8, 8)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, m, n, k, dtype):
        x = RNG.standard_normal((m, k)).astype(dtype)
        w = RNG.standard_normal((k, n)).astype(dtype)
        got = np.asarray(gemm_ops.gemm_leaky_relu(x, w), np.float32)
        want = np.asarray(gemm_ref.gemm_leaky_relu(x, w), np.float32)
        np.testing.assert_allclose(got, want, **_tol(dtype))

    @pytest.mark.parametrize("bm,bn,bk", [(16, 16, 16), (32, 16, 8), (8, 32, 32)])
    def test_knob_grid(self, bm, bn, bk):
        m, n, k = 64, 64, 64
        x = RNG.standard_normal((m, k)).astype(np.float32)
        w = RNG.standard_normal((k, n)).astype(np.float32)
        sched = Schedule(knobs={"bm": bm, "bn": bn, "bk": bk})
        fn = gemm_ops.build(sched, m=m, n=n, k=k)
        np.testing.assert_allclose(np.asarray(fn(x, w)),
                                   np.asarray(gemm_ref.gemm_leaky_relu(x, w)),
                                   rtol=1e-4, atol=1e-4)

    def test_all_single_moves_preserve_semantics(self):
        """Every legal paper-action applied to the default order must leave
        the kernel's output bit-identical on the same inputs."""
        m = n = k = 32
        x = RNG.standard_normal((m, k)).astype(np.float32)
        w = RNG.standard_normal((k, n)).astype(np.float32)
        sched = Schedule(knobs={"bm": 16, "bn": 16, "bk": 8})
        program = gemm_ops.program_for(sched, m=m, n=n, k=k)
        base = np.asarray(gemm_ops.build(sched, m=m, n=n, k=k)(x, w))
        order = program.default_order()
        for idx, d in program.legal_moves(order):
            new = program.move(order, idx, d)
            fn = gemm_ops.build(sched.with_order(new), m=m, n=n, k=k)
            np.testing.assert_array_equal(np.asarray(fn(x, w)), base)

    def test_prefetched_schedule_matches(self):
        """The fully software-pipelined schedule (all loads hoisted) is legal
        and numerically identical — the schedule SIP converges to."""
        m = n = 32; k = 64
        sched = Schedule(knobs={"bm": 16, "bn": 16, "bk": 16})
        program = gemm_ops.program_for(sched, m=m, n=n, k=k)
        loads = [i for i in program.mem_indices()
                 if not program.instrs[i].is_store]
        rest = [i for i in range(len(program)) if i not in loads]
        # init_acc first, then all loads, then compute chain
        order = tuple([rest[0]] + loads + rest[1:])
        assert program.is_legal(order)
        x = RNG.standard_normal((m, k)).astype(np.float32)
        w = RNG.standard_normal((k, n)).astype(np.float32)
        fn = gemm_ops.build(sched.with_order(order), m=m, n=n, k=k)
        want = gemm_ops.build(sched, m=m, n=n, k=k)(x, w)
        np.testing.assert_array_equal(np.asarray(fn(x, w)), np.asarray(want))


class TestFlashAttention:
    def _mk(self, b, hq, hkv, sq, skv, d, dtype=np.float32):
        q = RNG.standard_normal((b, hq, sq, d)).astype(dtype)
        k = RNG.standard_normal((b, hkv, skv, d)).astype(dtype)
        v = RNG.standard_normal((b, hkv, skv, d)).astype(dtype)
        return q, k, v

    @pytest.mark.parametrize("b,hq,hkv,s,d", [
        (1, 1, 1, 32, 16), (2, 4, 2, 64, 16), (1, 8, 1, 128, 32),
        (2, 2, 2, 64, 64)])
    def test_causal_gqa_shapes(self, b, hq, hkv, s, d):
        q, k, v = self._mk(b, hq, hkv, s, s, d)
        got = np.asarray(fa_ops.flash_attention(q, k, v))
        want = np.asarray(fa_ref.attention(q, k, v, causal=True))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, k, v = self._mk(1, 2, 1, 64, 64, 16, dtype)
        got = np.asarray(fa_ops.flash_attention(q, k, v), np.float32)
        want = np.asarray(fa_ref.attention(q, k, v, causal=True), np.float32)
        np.testing.assert_allclose(got, want, **_tol(dtype))

    def test_bidirectional(self):
        q, k, v = self._mk(1, 2, 2, 64, 64, 16)
        got = np.asarray(fa_ops.flash_attention_bidir(q, k, v))
        want = np.asarray(fa_ref.attention(q, k, v, causal=False))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("window", [8, 24, 64])
    def test_sliding_window(self, window):
        q, k, v = self._mk(1, 2, 1, 64, 64, 16)
        swa = fa_ops.make(causal=True, window=window)
        got = np.asarray(swa(q, k, v))
        want = np.asarray(fa_ref.attention(q, k, v, causal=True, window=window))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_decode_right_aligned(self):
        q, k, v = self._mk(2, 4, 2, 64, 64, 16)
        q1 = q[:, :, :1]
        got = np.asarray(fa_ops.flash_attention(q1, k, v))
        want = np.asarray(fa_ref.attention(q1, k, v, causal=True))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("n_chunks", [1, 2, 4])
    def test_kv_chunking_knob(self, n_chunks):
        q, k, v = self._mk(1, 2, 1, 64, 64, 16)
        static = dict(b=1, hq=2, hkv=1, sq=64, skv=64, d=16, causal=True,
                      window=None, dtype="float32")
        sched = Schedule(knobs={"bq": 32, "bk": 32, "n_chunks": n_chunks})
        fn = fa_ops.build(sched, **static)
        got = np.asarray(fn(q, k, v))
        want = np.asarray(fa_ref.attention(q, k, v, causal=True))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_all_single_moves_preserve_semantics(self):
        q, k, v = self._mk(1, 2, 1, 32, 32, 16)
        static = dict(b=1, hq=2, hkv=1, sq=32, skv=32, d=16, causal=True,
                      window=None, dtype="float32")
        sched = Schedule(knobs={"bq": 16, "bk": 16, "n_chunks": 2})
        program = fa_ops.program_for(sched, **static)
        base = np.asarray(fa_ops.build(sched, **static)(q, k, v))
        order = program.default_order()
        moves = program.legal_moves(order)
        assert moves, "attention body must expose movable mem instructions"
        for idx, d in moves:
            new = program.move(order, idx, d)
            fn = fa_ops.build(sched.with_order(new), **static)
            np.testing.assert_array_equal(np.asarray(fn(q, k, v)), base)


class TestRmsnorm:
    @pytest.mark.parametrize("rows,d", [(8, 64), (32, 128), (64, 32)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, rows, d, dtype):
        x = RNG.standard_normal((rows, d)).astype(dtype)
        g = RNG.standard_normal((d,)).astype(dtype)
        got = np.asarray(rms_ops.rmsnorm(x, g), np.float32)
        want = np.asarray(rms_ref.rmsnorm(x, g), np.float32)
        np.testing.assert_allclose(got, want, **_tol(dtype))

    @pytest.mark.parametrize("n_chunks", [1, 2, 4])
    def test_chunking(self, n_chunks):
        x = RNG.standard_normal((16, 64)).astype(np.float32)
        g = RNG.standard_normal((64,)).astype(np.float32)
        sched = Schedule(knobs={"br": 8, "n_chunks": n_chunks})
        fn = rms_ops.build(sched, rows=16, d=64, dtype="float32")
        np.testing.assert_allclose(np.asarray(fn(x, g)),
                                   np.asarray(rms_ref.rmsnorm(x, g)),
                                   rtol=1e-4, atol=1e-4)


class TestSSD:
    def _mk(self, bt=2, s=64, h=4, p=8, n=16):
        x = RNG.standard_normal((bt, s, h, p)).astype(np.float32)
        dt = (np.abs(RNG.standard_normal((bt, s, h))) * 0.1 + 0.01).astype(np.float32)
        A = -np.abs(RNG.standard_normal(h)).astype(np.float32)
        B = (RNG.standard_normal((bt, s, n)) * 0.3).astype(np.float32)
        C = (RNG.standard_normal((bt, s, n)) * 0.3).astype(np.float32)
        D = RNG.standard_normal(h).astype(np.float32)
        return x, dt, A, B, C, D

    @pytest.mark.parametrize("chunk", [16, 32, 64])
    def test_chunked_matches_naive(self, chunk):
        args = self._mk()
        got = np.asarray(ssd_ops.ssd_chunked(*args, chunk=chunk))
        want = np.asarray(ssd_ref.ssd(*args))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_decode_step_parity(self):
        x, dt, A, B, C, D = self._mk(s=64)
        y_full, st_full = ssd_ops.ssd_chunked(x, dt, A, B, C, D, chunk=16,
                                              return_state=True)
        _, st = ssd_ops.ssd_chunked(x[:, :48], dt[:, :48], A, B[:, :48],
                                    C[:, :48], D, chunk=16, return_state=True)
        outs = []
        for t in range(48, 64):
            st, y = ssd_ops.ssd_step(st, x[:, t], dt[:, t], A, B[:, t],
                                     C[:, t], D)
            outs.append(np.asarray(y))
        np.testing.assert_allclose(np.stack(outs, 1), np.asarray(y_full[:, 48:]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st_full),
                                   rtol=1e-4, atol=1e-4)

    def test_init_state_continuation(self):
        x, dt, A, B, C, D = self._mk(s=64)
        y_full = np.asarray(ssd_ops.ssd_chunked(x, dt, A, B, C, D, chunk=16))
        _, st = ssd_ops.ssd_chunked(x[:, :32], dt[:, :32], A, B[:, :32],
                                    C[:, :32], D, chunk=16, return_state=True)
        y_tail = np.asarray(ssd_ops.ssd_chunked(
            x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:], D, chunk=16,
            init_state=st))
        np.testing.assert_allclose(y_tail, y_full[:, 32:], rtol=1e-4, atol=1e-4)
