"""Exactness tests for the §Perf hillclimbing levers — every optimization
must be a semantics-preserving transformation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig

RNG = np.random.default_rng(11)
BASE = ModelConfig(name="p", family="dense", n_layers=2, d_model=64,
                   n_heads=5, n_kv_heads=1, head_dim=16, d_ff=128, vocab=128,
                   dtype="float32")


def _toks(cfg, b=2, s=16):
    return {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)),
                                  jnp.int32),
            "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)),
                                  jnp.int32)}


class TestPaddedHeads:
    def test_padded_heads_exact(self):
        """Zero-padded heads (for model-axis divisibility) contribute
        nothing: slicing them away reproduces the same logits."""
        cfg_pad = dataclasses.replace(BASE, padded_heads=8)
        p_pad = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg_pad))
        toks = _toks(BASE)
        l_pad, _ = M.forward(p_pad, toks, cfg_pad)
        p_sliced = dict(p_pad)
        p_sliced["blocks"] = dict(p_pad["blocks"])
        p_sliced["blocks"]["attn"] = dict(p_pad["blocks"]["attn"])
        p_sliced["blocks"]["attn"]["wq"] = p_pad["blocks"]["attn"]["wq"][:, :, :5, :]
        p_sliced["blocks"]["attn"]["wo"] = p_pad["blocks"]["attn"]["wo"][:, :5, :, :]
        l_ref, _ = M.forward(p_sliced, toks, BASE)
        np.testing.assert_allclose(np.asarray(l_pad), np.asarray(l_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_padded_decode_parity(self):
        cfg = dataclasses.replace(BASE, padded_heads=8)
        p = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
        t = _toks(cfg)["tokens"]
        full, _ = M.forward(p, {"tokens": t}, cfg)
        _, caches = M.prefill(p, {"tokens": t[:, :-1]}, cfg, max_len=20)
        got, _ = M.decode_step(p, caches, t[:, -1], cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, -1]),
                                   rtol=2e-4, atol=2e-4)

    def test_padded_heads_stay_zero_under_training(self):
        """Grads through zeroed wo rows are zero, so padding survives SGD."""
        cfg = dataclasses.replace(BASE, padded_heads=8)
        p = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
        g = jax.grad(lambda q: M.loss_fn(q, _toks(cfg), cfg)[0])(p)
        np.testing.assert_array_equal(
            np.asarray(g["blocks"]["attn"]["wq"][:, :, 5:, :]), 0.0)
        np.testing.assert_array_equal(
            np.asarray(g["blocks"]["attn"]["wo"][:, 5:, :, :]), 0.0)

    def test_gqa_mapping_preserved(self):
        """The explicit kv map keeps the ORIGINAL i//group assignment for
        real heads (padding must not silently re-group GQA)."""
        from repro.models.attention import kv_head_map
        cfg = ModelConfig(name="g", family="dense", n_layers=1, d_model=64,
                          n_heads=40, n_kv_heads=8, head_dim=16, d_ff=64,
                          vocab=64, padded_heads=48)
        idx = np.asarray(kv_head_map(cfg))
        assert idx.shape == (48,)
        np.testing.assert_array_equal(idx[:40], np.arange(40) // 5)


class TestRematPolicies:
    @pytest.mark.parametrize("policy", ["full", "dots", "none"])
    def test_policies_identical_logits(self, policy):
        cfg = dataclasses.replace(BASE, remat_policy=policy)
        p = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
        toks = _toks(cfg)
        l, _ = M.forward(p, toks, cfg)
        l0, _ = M.forward(p, toks, BASE)
        np.testing.assert_allclose(np.asarray(l), np.asarray(l0),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("policy", ["dots", "none"])
    def test_policies_same_grads(self, policy):
        cfg = dataclasses.replace(BASE, remat_policy=policy)
        p = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
        toks = _toks(cfg)
        g0 = jax.grad(lambda q: M.loss_fn(q, toks, BASE)[0])(p)
        g1 = jax.grad(lambda q: M.loss_fn(q, toks, cfg)[0])(p)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


class TestParamDtype:
    def test_bf16_params_init_and_run(self):
        cfg = dataclasses.replace(BASE, param_dtype="bfloat16")
        p = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg))
        assert p["embed"].dtype == jnp.bfloat16
        l, _ = M.forward(p, _toks(cfg), cfg)
        assert np.all(np.isfinite(np.asarray(l, np.float32)))
