"""Model-family behaviour tests: forward shapes, grads, decode parity.

Decode parity is the strongest invariant we have: prefill(prompt[:-1]) +
decode_step(prompt[-1]) must reproduce forward(prompt)[:, -1] exactly (the
caches are an algebraic rearrangement, not an approximation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig

B, S, V = 2, 32, 256
RNG = np.random.default_rng(0)


def toks():
    return {"tokens": jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32),
            "labels": jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32)}


CFGS = {
    "dense_qknorm": ModelConfig(name="d", family="dense", n_layers=2,
                                d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                                vocab=V, qk_norm=True, dtype="float32"),
    "dense_swa": ModelConfig(name="swa", family="dense", n_layers=2,
                             d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                             vocab=V, window=16, dtype="float32"),
    "moe": ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=V,
                       n_experts=4, top_k=2, capacity_factor=4.0,
                       dtype="float32"),
    "ssm": ModelConfig(name="s", family="ssm", n_layers=2, d_model=64,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab=V,
                       ssm_state=16, ssm_headdim=32, ssm_chunk=16,
                       dtype="float32"),
    "hybrid": ModelConfig(name="h", family="hybrid", n_layers=5, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab=V,
                          ssm_state=16, ssm_headdim=32, ssm_chunk=16,
                          hybrid_group=2, hybrid_attn_every=2,
                          dtype="float32"),
}


def _params(cfg):
    return nn.unwrap(M.init_lm(jax.random.PRNGKey(0), cfg.validate()))


class TestForward:
    @pytest.mark.parametrize("name", list(CFGS))
    def test_logits_shape_and_finite(self, name):
        cfg = CFGS[name]
        params = _params(cfg)
        logits, aux = M.forward(params, toks(), cfg)
        assert logits.shape == (B, S, V)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        assert set(aux) == {"load_balance", "router_z"}

    @pytest.mark.parametrize("name", list(CFGS))
    def test_grads_finite_nonzero(self, name):
        cfg = CFGS[name]
        params = _params(cfg)
        g = jax.grad(lambda p: M.loss_fn(p, toks(), cfg)[0])(params)
        gn = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
                 for x in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0

    def test_causality(self):
        """Future tokens must not influence past logits."""
        cfg = CFGS["dense_qknorm"]
        params = _params(cfg)
        t1 = toks()
        t2 = {**t1, "tokens": t1["tokens"].at[:, -1].set(0)}
        l1, _ = M.forward(params, t1, cfg)
        l2, _ = M.forward(params, t2, cfg)
        np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                                   np.asarray(l2[:, :-1]), rtol=1e-5)

    def test_ssm_causality(self):
        cfg = CFGS["ssm"]
        params = _params(cfg)
        t1 = toks()
        t2 = {**t1, "tokens": t1["tokens"].at[:, -1].set(0)}
        l1, _ = M.forward(params, t1, cfg)
        l2, _ = M.forward(params, t2, cfg)
        np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                                   np.asarray(l2[:, :-1]), rtol=1e-5)

    def test_scan_vs_unrolled_identical(self):
        import dataclasses
        cfg = CFGS["dense_qknorm"]
        params = _params(cfg)
        b = toks()
        l1, _ = M.forward(params, b, cfg)
        cfg2 = dataclasses.replace(cfg, scan_layers=False)
        l2, _ = M.forward(params, b, cfg2)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-5)

    def test_logits_microbatch_identical(self):
        import dataclasses
        cfg = CFGS["dense_qknorm"]
        params = _params(cfg)
        b = toks()
        l1, _ = M.loss_fn(params, b, cfg)
        l2, _ = M.loss_fn(params, b,
                          dataclasses.replace(cfg, logits_microbatch=4))
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


class TestDecodeParity:
    @pytest.mark.parametrize("name", list(CFGS))
    def test_one_step(self, name):
        cfg = CFGS[name]
        params = _params(cfg)
        t = toks()["tokens"]
        full, _ = M.forward(params, {"tokens": t}, cfg)
        _, caches = M.prefill(params, {"tokens": t[:, :-1]}, cfg,
                              max_len=S + 4)
        got, _ = M.decode_step(params, caches, t[:, -1], cfg)
        want = np.asarray(full[:, -1], np.float32)
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("name", ["dense_swa", "ssm", "hybrid"])
    def test_multi_step(self, name):
        """Teacher-forced decode over the last 8 tokens matches forward."""
        cfg = CFGS[name]
        params = _params(cfg)
        t = toks()["tokens"]
        full, _ = M.forward(params, {"tokens": t}, cfg)
        k = 8
        _, caches = M.prefill(params, {"tokens": t[:, :-k]}, cfg,
                              max_len=S + 4)
        for i in range(k):
            got, caches = M.decode_step(params, caches, t[:, S - k + i], cfg)
            want = np.asarray(full[:, S - k + i], np.float32)
            np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                       rtol=5e-4, atol=5e-4)

    def test_swa_rolling_cache(self):
        """A window-sized ring-buffer cache must match the full-cache result."""
        cfg = CFGS["dense_swa"]          # window 16 < S
        params = _params(cfg)
        t = toks()["tokens"]
        full, _ = M.forward(params, {"tokens": t}, cfg)
        # max_len == window -> rolling cache path
        _, caches = M.prefill(params, {"tokens": t[:, :-1]}, cfg,
                              max_len=cfg.window)
        assert caches["k"].shape[2] == cfg.window
        got, _ = M.decode_step(params, caches, t[:, -1], cfg)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(full[:, -1], np.float32),
                                   rtol=2e-4, atol=2e-4)


class TestEncDec:
    CFG = ModelConfig(name="e", family="enc_dec", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=V,
                      enc_layers=2, dec_layers=2, enc_len=16,
                      input_mode="embeddings", dtype="float32")

    def _inputs(self):
        return {"enc_embeds": jnp.asarray(
                    RNG.standard_normal((B, 16, 64)), jnp.float32),
                "tokens": jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32),
                "labels": jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32)}

    def test_forward_and_grad(self):
        cfg = self.CFG.validate()
        params = _params(cfg)
        logits, _ = M.forward(params, self._inputs(), cfg)
        assert logits.shape == (B, S, V)
        g = jax.grad(lambda p: M.loss_fn(p, self._inputs(), cfg)[0])(params)
        assert all(np.all(np.isfinite(np.asarray(x, np.float32)))
                   for x in jax.tree.leaves(g))

    def test_decode_parity(self):
        cfg = self.CFG.validate()
        params = _params(cfg)
        inp = self._inputs()
        full, _ = M.forward(params, inp, cfg)
        _, caches = M.prefill(params, {"enc_embeds": inp["enc_embeds"],
                                       "tokens": inp["tokens"][:, :-1]},
                              cfg, max_len=S + 4)
        got, _ = M.decode_step(params, caches, inp["tokens"][:, -1], cfg)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(full[:, -1], np.float32),
                                   rtol=2e-4, atol=2e-4)

    def test_encoder_is_bidirectional(self):
        """Changing a late encoder frame must change early decoder logits."""
        cfg = self.CFG.validate()
        params = _params(cfg)
        inp = self._inputs()
        l1, _ = M.forward(params, inp, cfg)
        inp2 = dict(inp)
        inp2["enc_embeds"] = inp["enc_embeds"].at[:, -1].add(1.0)
        l2, _ = M.forward(params, inp2, cfg)
        assert not np.allclose(np.asarray(l1[:, 0]), np.asarray(l2[:, 0]))
