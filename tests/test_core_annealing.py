"""Tests for Algorithm 1 (simulated annealing), energy, testing, and cache."""

import math
import os

import numpy as np
import pytest

from repro.core import (CostModelEnergy, FaultInjector, GuardedEnergy, Instr,
                        InputSpec, Kind, KnobSpec, MutationPolicy, Program,
                        Schedule, ScheduleCache, SearchSpace, anneal,
                        multi_round, probabilistic_test, reward)
from repro.core import costmodel


def make_latency_program(n_steps=6):
    """A GEMM-like body: per step a load (async) feeding a compute op.

    The default (compiler-like) order is load0,comp0,load1,comp1,... which
    serializes; the optimum prefetches loads ahead — exactly the paper's
    latency-hiding pattern (§2.3)."""
    instrs = []
    for s in range(n_steps):
        instrs.append(Instr(name=f"ld{s}", kind=Kind.MEM, inputs=(),
                            outputs=(f"x{s}",), fn=lambda env: {},
                            buffer=f"B{s}", bytes=1 << 16))
        instrs.append(Instr(name=f"mm{s}", kind=Kind.COMPUTE, inputs=(f"x{s}",),
                            outputs=(f"y{s}",), fn=lambda env: {},
                            flops=1 << 18))
    return Program(instrs)


class TestCostModelSimulator:
    def test_prefetch_is_faster(self):
        p = make_latency_program()
        t_base = costmodel.simulate(p)
        # hand-build a software-pipelined order: all loads first
        loads = [i for i in range(len(p)) if p.instrs[i].kind is Kind.MEM]
        comps = [i for i in range(len(p)) if p.instrs[i].kind is Kind.COMPUTE]
        t_pipe = costmodel.simulate(p, tuple(loads + comps))
        assert t_pipe < t_base

    def test_illegal_order_raises(self):
        p = make_latency_program(2)
        with pytest.raises(ValueError):
            costmodel.simulate(p, (1, 0, 2, 3))  # compute before its load

    def test_roofline_terms(self):
        t = costmodel.roofline_time(flops=197e12, hbm_bytes=819e9,
                                    collective_bytes=50e9, chips=1)
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["memory_s"] == pytest.approx(1.0)
        assert t["collective_s"] == pytest.approx(1.0)
        assert costmodel.dominant_term({"compute_s": 2, "memory_s": 1,
                                        "collective_s": 0}) == "compute_s"


class TestAnnealing:
    def _setup(self, n_steps=6):
        p = make_latency_program(n_steps)
        space = SearchSpace()
        policy = MutationPolicy(space=space, program_for=lambda s: p)
        energy = CostModelEnergy(program_for=lambda s: p)
        return p, policy, energy

    def test_anneal_improves_latency_hiding(self):
        p, policy, energy = self._setup()
        res = anneal(Schedule(), energy, policy.propose,
                     t_max=1.0, t_min=1e-3, cooling=1.02, seed=0)
        assert res.improvement > 0.10          # finds real overlap
        assert res.best_raw <= res.initial_raw
        assert p.is_legal(res.best.order)

    def test_history_rewards_match_paper_formula(self):
        _, policy, energy = self._setup(3)
        res = anneal(Schedule(), energy, policy.propose,
                     t_max=1.0, t_min=0.05, cooling=1.1, seed=1)
        # rewards are -(dE) in normalized units; reward() reproduces them
        assert len(res.history) > 0
        assert all(math.isfinite(h.reward) for h in res.history)

    def test_deterministic_given_seed(self):
        _, policy, energy = self._setup()
        r1 = anneal(Schedule(), energy, policy.propose, seed=7, cooling=1.05)
        r2 = anneal(Schedule(), energy, policy.propose, seed=7, cooling=1.05)
        assert r1.best_raw == r2.best_raw
        assert r1.best.order == r2.best.order

    def test_multi_round_restarts(self):
        _, policy, energy = self._setup()
        results = multi_round(Schedule(), energy, policy.propose, rounds=3,
                              cooling=1.1)
        assert len(results) == 3

    def test_failed_candidates_never_accepted(self):
        p, policy, _ = self._setup(4)
        base = costmodel.simulate(p)

        def energy(s: Schedule) -> float:
            if s.order is not None and s.order != p.default_order():
                return float("inf")        # every mutation "fails tests"
            return base

        res = anneal(Schedule(), energy, policy.propose, cooling=1.1)
        assert res.best.order in (None, p.default_order())
        assert res.improvement == 0.0

    def test_reward_formula(self):
        assert reward(2.0, 1.0, 4.0) == pytest.approx(0.25)
        assert reward(1.0, float("inf"), 4.0) == 0.0   # failed test => 0

    def test_non_cooling_schedule_rejected(self):
        """cooling <= 1 would never cross t_min — must raise, not hang."""
        _, policy, energy = self._setup(2)
        with pytest.raises(ValueError, match="cooling"):
            anneal(Schedule(), energy, policy.propose, cooling=1.0)

    def test_perturb_with_no_legal_actions_terminates(self):
        """perturb == None on every step (no legal move anywhere) must still
        cool to t_min and return the initial schedule, not spin forever."""
        p = make_latency_program(2)
        energy = CostModelEnergy(program_for=lambda s: p)
        calls = {"n": 0}

        def dead_perturb(s, rng):
            calls["n"] += 1
            return None

        res = anneal(Schedule(), energy, dead_perturb,
                     t_max=1.0, t_min=1e-2, cooling=1.1, seed=0)
        assert res.best == Schedule()
        assert res.best_raw == res.initial_raw
        assert res.improvement == 0.0
        assert res.evals == 1                  # only the initial energy
        assert res.history == []               # no candidates ever evaluated
        assert calls["n"] > 0                  # ...but the loop did run


class TestMutationPolicy:
    def test_knob_mutation_beyond_paper(self):
        p = make_latency_program(2)
        space = SearchSpace(knobs=(KnobSpec("bm", (128, 256)),))
        policy = MutationPolicy(space=space, program_for=lambda s: p,
                                knob_prob=1.0)
        s = Schedule(knobs={"bm": 128})
        rng = np.random.default_rng(0)
        s2 = policy.propose(s, rng)
        assert s2.knobs["bm"] == 256
        assert s2.order is None            # knob change invalidates order

    def test_faithful_mode_never_touches_knobs(self):
        p = make_latency_program(4)
        space = SearchSpace(knobs=(KnobSpec("bm", (128, 256)),))
        policy = MutationPolicy(space=space, program_for=lambda s: p,
                                knob_prob=0.0)
        s = Schedule(knobs={"bm": 128})
        rng = np.random.default_rng(0)
        for _ in range(20):
            s2 = policy.propose(s, rng)
            assert s2 is None or s2.knobs["bm"] == 128


class TestProbabilisticTesting:
    def test_correct_kernel_passes(self):
        f = lambda x: np.asarray(x) * 2.0
        rep = probabilistic_test(f, f, [InputSpec((8,))], 32,
                                 np.random.default_rng(0))
        assert rep.passed and rep.samples_run == 32

    def test_fault_detected_with_enough_samples(self):
        oracle = lambda x: np.asarray(x) * 2.0
        # fault fires when max|x| > 3.0 — rare for size-8 standard normals
        bad = FaultInjector(oracle, threshold=3.0, corruption=0.5)
        rng = np.random.default_rng(0)
        small = probabilistic_test(bad, oracle, [InputSpec((8,))], 5, rng,
                                   rtol=1e-3, atol=1e-3)
        rng = np.random.default_rng(0)
        big = probabilistic_test(bad, oracle, [InputSpec((8,))], 2000, rng,
                                 rtol=1e-3, atol=1e-3)
        assert small.passed            # false positive at low sample counts
        assert not big.passed          # caught with enough samples (Fig. 2)


class TestScheduleCache:
    def test_greedy_rank_filters_failures(self, tmp_path):
        cache = ScheduleCache(str(tmp_path / "cache.json"))
        s_fast_broken = Schedule(knobs={"bm": 1})
        s_slow_ok = Schedule(knobs={"bm": 2})
        s_fast_ok = Schedule(knobs={"bm": 3})
        cache.put("k", "sig", s_fast_broken, energy=0.5, tests_passed=False)
        cache.put("k", "sig", s_slow_ok, energy=2.0, tests_passed=True)
        cache.put("k", "sig", s_fast_ok, energy=1.0, tests_passed=True)
        best = cache.best("k", "sig")
        assert best.knobs["bm"] == 3

    def test_persistence_roundtrip(self, tmp_path):
        path = str(tmp_path / "cache.json")
        cache = ScheduleCache(path)
        cache.put("k", "sig", Schedule(knobs={"bm": 128}, order=(1, 0)),
                  energy=1.0, tests_passed=True)
        reloaded = ScheduleCache(path)
        best = reloaded.best("k", "sig")
        assert best.knobs["bm"] == 128 and best.order == (1, 0)

    def test_missing_entry(self):
        assert ScheduleCache().best("nope", "sig") is None

    @pytest.mark.parametrize("payload", [
        "", "{not json", '["a", "list"]',
        '{"k::sig": {"not": "a list"}}',            # mistyped entry list
        '{"k::sig": [{"bogus_field": 1}]}',         # malformed entry dict
    ])
    def test_corrupt_cache_file_degrades_to_empty(self, tmp_path, payload):
        """Regression: a corrupt/empty/mistyped store must warn, start empty,
        and still accept + persist new entries (not crash json.load)."""
        path = str(tmp_path / "cache.json")
        with open(path, "w") as f:
            f.write(payload)
        with pytest.warns(RuntimeWarning, match="ignoring unreadable"):
            cache = ScheduleCache(path)
        assert cache.best("k", "sig") is None
        cache.put("k", "sig", Schedule(knobs={"bm": 8}), energy=1.0,
                  tests_passed=True)
        assert ScheduleCache(path).best("k", "sig").knobs["bm"] == 8

    def test_concurrent_put_atomic_flush(self, tmp_path):
        """N threads hammering put() must lose no entries, and the on-disk
        file must be valid JSON at the end (atomic tmp+replace flushes)."""
        import threading

        path = str(tmp_path / "cache.json")
        cache = ScheduleCache(path)
        n_threads, per_thread = 8, 10

        def work(tid):
            for i in range(per_thread):
                cache.put("k", f"sig{tid}", Schedule(knobs={"bm": i}),
                          energy=float(i + 1), tests_passed=True,
                          round_id=i)

        threads = [threading.Thread(target=work, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        reloaded = ScheduleCache(path)
        for t in range(n_threads):
            entries = reloaded.entries("k", f"sig{t}")
            assert len(entries) == per_thread
            assert reloaded.best("k", f"sig{t}").knobs["bm"] == 0


class TestSchedule:
    def test_json_roundtrip(self):
        s = Schedule(knobs={"bm": 128, "bn": 256}, order=(2, 0, 1))
        s2 = Schedule.from_json(s.to_json())
        assert s2 == s

    def test_stale_order_falls_back(self):
        p = make_latency_program(2)   # 4 instrs
        s = Schedule(order=(0, 1, 2, 3, 4, 5))
        assert s.resolve_order(p) == p.default_order()
