"""Tests for Algorithm 1 (simulated annealing), energy, testing, and cache."""

import math
import os

import numpy as np
import pytest

from repro.core import (CostModelEnergy, FaultInjector, GuardedEnergy, Instr,
                        InputSpec, Kind, KnobSpec, MutationPolicy, Program,
                        Schedule, ScheduleCache, SearchSpace, anneal,
                        multi_round, probabilistic_test, reward)
from repro.core import costmodel


def make_latency_program(n_steps=6):
    """A GEMM-like body: per step a load (async) feeding a compute op.

    The default (compiler-like) order is load0,comp0,load1,comp1,... which
    serializes; the optimum prefetches loads ahead — exactly the paper's
    latency-hiding pattern (§2.3)."""
    instrs = []
    for s in range(n_steps):
        instrs.append(Instr(name=f"ld{s}", kind=Kind.MEM, inputs=(),
                            outputs=(f"x{s}",), fn=lambda env: {},
                            buffer=f"B{s}", bytes=1 << 16))
        instrs.append(Instr(name=f"mm{s}", kind=Kind.COMPUTE, inputs=(f"x{s}",),
                            outputs=(f"y{s}",), fn=lambda env: {},
                            flops=1 << 18))
    return Program(instrs)


class TestCostModelSimulator:
    def test_prefetch_is_faster(self):
        p = make_latency_program()
        t_base = costmodel.simulate(p)
        # hand-build a software-pipelined order: all loads first
        loads = [i for i in range(len(p)) if p.instrs[i].kind is Kind.MEM]
        comps = [i for i in range(len(p)) if p.instrs[i].kind is Kind.COMPUTE]
        t_pipe = costmodel.simulate(p, tuple(loads + comps))
        assert t_pipe < t_base

    def test_illegal_order_raises(self):
        p = make_latency_program(2)
        with pytest.raises(ValueError):
            costmodel.simulate(p, (1, 0, 2, 3))  # compute before its load

    def test_roofline_terms(self):
        t = costmodel.roofline_time(flops=197e12, hbm_bytes=819e9,
                                    collective_bytes=50e9, chips=1)
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["memory_s"] == pytest.approx(1.0)
        assert t["collective_s"] == pytest.approx(1.0)
        assert costmodel.dominant_term({"compute_s": 2, "memory_s": 1,
                                        "collective_s": 0}) == "compute_s"


class TestAnnealing:
    def _setup(self, n_steps=6):
        p = make_latency_program(n_steps)
        space = SearchSpace()
        policy = MutationPolicy(space=space, program_for=lambda s: p)
        energy = CostModelEnergy(program_for=lambda s: p)
        return p, policy, energy

    def test_anneal_improves_latency_hiding(self):
        p, policy, energy = self._setup()
        res = anneal(Schedule(), energy, policy.propose,
                     t_max=1.0, t_min=1e-3, cooling=1.02, seed=0)
        assert res.improvement > 0.10          # finds real overlap
        assert res.best_raw <= res.initial_raw
        assert p.is_legal(res.best.order)

    def test_history_rewards_match_paper_formula(self):
        _, policy, energy = self._setup(3)
        res = anneal(Schedule(), energy, policy.propose,
                     t_max=1.0, t_min=0.05, cooling=1.1, seed=1)
        # rewards are -(dE) in normalized units; reward() reproduces them
        assert len(res.history) > 0
        assert all(math.isfinite(h.reward) for h in res.history)

    def test_deterministic_given_seed(self):
        _, policy, energy = self._setup()
        r1 = anneal(Schedule(), energy, policy.propose, seed=7, cooling=1.05)
        r2 = anneal(Schedule(), energy, policy.propose, seed=7, cooling=1.05)
        assert r1.best_raw == r2.best_raw
        assert r1.best.order == r2.best.order

    def test_multi_round_restarts(self):
        _, policy, energy = self._setup()
        results = multi_round(Schedule(), energy, policy.propose, rounds=3,
                              cooling=1.1)
        assert len(results) == 3

    def test_failed_candidates_never_accepted(self):
        p, policy, _ = self._setup(4)
        base = costmodel.simulate(p)

        def energy(s: Schedule) -> float:
            if s.order is not None and s.order != p.default_order():
                return float("inf")        # every mutation "fails tests"
            return base

        res = anneal(Schedule(), energy, policy.propose, cooling=1.1)
        assert res.best.order in (None, p.default_order())
        assert res.improvement == 0.0

    def test_reward_formula(self):
        assert reward(2.0, 1.0, 4.0) == pytest.approx(0.25)
        assert reward(1.0, float("inf"), 4.0) == 0.0   # failed test => 0


class TestMutationPolicy:
    def test_knob_mutation_beyond_paper(self):
        p = make_latency_program(2)
        space = SearchSpace(knobs=(KnobSpec("bm", (128, 256)),))
        policy = MutationPolicy(space=space, program_for=lambda s: p,
                                knob_prob=1.0)
        s = Schedule(knobs={"bm": 128})
        rng = np.random.default_rng(0)
        s2 = policy.propose(s, rng)
        assert s2.knobs["bm"] == 256
        assert s2.order is None            # knob change invalidates order

    def test_faithful_mode_never_touches_knobs(self):
        p = make_latency_program(4)
        space = SearchSpace(knobs=(KnobSpec("bm", (128, 256)),))
        policy = MutationPolicy(space=space, program_for=lambda s: p,
                                knob_prob=0.0)
        s = Schedule(knobs={"bm": 128})
        rng = np.random.default_rng(0)
        for _ in range(20):
            s2 = policy.propose(s, rng)
            assert s2 is None or s2.knobs["bm"] == 128


class TestProbabilisticTesting:
    def test_correct_kernel_passes(self):
        f = lambda x: np.asarray(x) * 2.0
        rep = probabilistic_test(f, f, [InputSpec((8,))], 32,
                                 np.random.default_rng(0))
        assert rep.passed and rep.samples_run == 32

    def test_fault_detected_with_enough_samples(self):
        oracle = lambda x: np.asarray(x) * 2.0
        # fault fires when max|x| > 3.0 — rare for size-8 standard normals
        bad = FaultInjector(oracle, threshold=3.0, corruption=0.5)
        rng = np.random.default_rng(0)
        small = probabilistic_test(bad, oracle, [InputSpec((8,))], 5, rng,
                                   rtol=1e-3, atol=1e-3)
        rng = np.random.default_rng(0)
        big = probabilistic_test(bad, oracle, [InputSpec((8,))], 2000, rng,
                                 rtol=1e-3, atol=1e-3)
        assert small.passed            # false positive at low sample counts
        assert not big.passed          # caught with enough samples (Fig. 2)


class TestScheduleCache:
    def test_greedy_rank_filters_failures(self, tmp_path):
        cache = ScheduleCache(str(tmp_path / "cache.json"))
        s_fast_broken = Schedule(knobs={"bm": 1})
        s_slow_ok = Schedule(knobs={"bm": 2})
        s_fast_ok = Schedule(knobs={"bm": 3})
        cache.put("k", "sig", s_fast_broken, energy=0.5, tests_passed=False)
        cache.put("k", "sig", s_slow_ok, energy=2.0, tests_passed=True)
        cache.put("k", "sig", s_fast_ok, energy=1.0, tests_passed=True)
        best = cache.best("k", "sig")
        assert best.knobs["bm"] == 3

    def test_persistence_roundtrip(self, tmp_path):
        path = str(tmp_path / "cache.json")
        cache = ScheduleCache(path)
        cache.put("k", "sig", Schedule(knobs={"bm": 128}, order=(1, 0)),
                  energy=1.0, tests_passed=True)
        reloaded = ScheduleCache(path)
        best = reloaded.best("k", "sig")
        assert best.knobs["bm"] == 128 and best.order == (1, 0)

    def test_missing_entry(self):
        assert ScheduleCache().best("nope", "sig") is None


class TestSchedule:
    def test_json_roundtrip(self):
        s = Schedule(knobs={"bm": 128, "bn": 256}, order=(2, 0, 1))
        s2 = Schedule.from_json(s.to_json())
        assert s2 == s

    def test_stale_order_falls_back(self):
        p = make_latency_program(2)   # 4 instrs
        s = Schedule(order=(0, 1, 2, 3, 4, 5))
        assert s.resolve_order(p) == p.default_order()
