"""Subprocess body for pipeline-parallel parity tests (8 host devices)."""

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.pipeline import pipeline_apply

N_STAGES, D, N_MICRO, MB = 4, 16, 8, 4


def _setup():
    mesh = jax.make_mesh((N_STAGES, 2), ("stage", "dp"))
    rng = np.random.default_rng(0)
    # n_stages small MLP stages: y = tanh(x @ w + b)
    w = jnp.asarray(rng.standard_normal((N_STAGES, D, D)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((N_STAGES, D)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.standard_normal((N_MICRO * MB, D)), jnp.float32)

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    def reference(params, x):
        h = x
        for i in range(N_STAGES):
            h = stage_fn(jax.tree.map(lambda a: a[i], params), h)
        return h

    return mesh, {"w": w, "b": b}, x, stage_fn, reference


def forward():
    mesh, params, x, stage_fn, reference = _setup()
    want = reference(params, x)
    got = pipeline_apply(stage_fn, params, x, mesh=mesh, axis="stage",
                         n_micro=N_MICRO)
    err = float(jnp.max(jnp.abs(got - want)))
    print(json.dumps({"max_err": err}))


def grad():
    mesh, params, x, stage_fn, reference = _setup()

    def loss_pp(p):
        y = pipeline_apply(stage_fn, p, x, mesh=mesh, axis="stage",
                           n_micro=N_MICRO)
        return jnp.mean(y ** 2)

    def loss_ref(p):
        return jnp.mean(reference(p, x) ** 2)

    g_pp = jax.grad(loss_pp)(params)
    g_ref = jax.grad(loss_ref)(params)
    errs = []
    for a, b in zip(jax.tree.leaves(g_pp), jax.tree.leaves(g_ref)):
        denom = float(jnp.max(jnp.abs(b))) + 1e-9
        errs.append(float(jnp.max(jnp.abs(a - b))) / denom)
    print(json.dumps({"max_rel_err": max(errs)}))


if __name__ == "__main__":
    assert len(jax.devices()) == 8
    {"forward": forward, "grad": grad}[sys.argv[1]]()
