"""KernelSpec registry + TuningSession: declarative kernel integration.

Covers the registry surface (registration, duplicate/unknown errors, shared
instance resolution under schedule_cache scopes), the session orchestrator
(one cache for many kernels, per-workload seeding that is selection- and
order-independent, chains=1 bit-equivalence with direct SipKernel.tune),
TuneConfig.validate, the generic CLI driver, and the registry-routed model
paths (attention / SSD-pallas kernel reuse)."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels
from repro.core import (KernelRegistry, KernelSpec, ScheduleCache, TuneConfig,
                        Workload, active_schedule_cache, registry,
                        schedule_cache, sip_kernel, workload_seed)
from repro.core.schedule import SearchSpace
from repro.tuning import TuningSession

kernels.load_all()

GEMM = "gemm_fused_leaky_relu"
RMS = "rmsnorm_fused"
QUICK = TuneConfig(rounds=1, t_min=0.3, cooling=1.3, step_samples=1,
                   final_samples=4)


def _toy_spec(name="toy"):
    return KernelSpec(name=name, build=lambda s, **st: (lambda *a: a),
                      program_for=lambda s, **st: None,
                      space_for=lambda **st: SearchSpace(),
                      oracle=lambda *a: a,
                      signature_fn=lambda *a: {})


class TestRegistry:
    def test_duplicate_name_rejected(self):
        reg = KernelRegistry()
        reg.register(_toy_spec())
        with pytest.raises(ValueError, match="already registered"):
            reg.register(_toy_spec())

    def test_unknown_kernel_lists_registered(self):
        reg = KernelRegistry()
        reg.register(_toy_spec("present"))
        with pytest.raises(KeyError, match="present"):
            reg.spec("absent")
        with pytest.raises(KeyError, match="unknown kernel"):
            reg.get("absent")

    def test_decorator_registers_and_fills_module(self):
        reg = KernelRegistry()

        @sip_kernel(name="decorated", program_for=lambda s, **st: None,
                    space_for=lambda **st: SearchSpace(),
                    oracle=lambda *a: a, signature_fn=lambda *a: {},
                    workloads=[Workload("w", lambda rng: [],
                                        suites=("smoke",))],
                    registry_=reg)
        def build(schedule, **static):
            return lambda *a: a

        assert isinstance(build, KernelSpec)       # decorator returns the spec
        assert "decorated" in reg
        assert build.module == __name__
        assert [w.name for w in build.workloads_in("smoke")] == ["w"]
        assert build.workloads_in("default") == ()

    def test_get_memoizes_per_cache(self, tmp_path):
        a = registry.get(GEMM)
        assert registry.get(GEMM) is a             # default cache: one object
        with schedule_cache(str(tmp_path / "c.json")) as cache:
            b = registry.get(GEMM)
            assert b is not a and b.cache is cache
            assert registry.get(GEMM) is b         # memoized within the scope
        assert registry.get(GEMM) is a             # scope exit restores

    def test_schedule_cache_path_interning(self, tmp_path):
        """Re-entering a path scope (a server wrapping every request) must
        resolve the SAME store — and the same memoized kernel instance —
        not re-read the JSON and mint fresh instances per scope."""
        p = str(tmp_path / "store.json")
        with schedule_cache(p) as c1:
            k1 = registry.get(GEMM)
        with schedule_cache(p) as c2:
            k2 = registry.get(GEMM)
        assert c2 is c1 and k2 is k1
        # a session over the same path shares the interned store too
        assert TuningSession(cache=p).cache is c1

    def test_spec_call_dispatches_through_owning_registry(self):
        """A spec registered into a custom registry must not consult the
        process-wide one when called."""
        reg = KernelRegistry()
        spec = sip_kernel(name="owned_only", program_for=lambda s, **st: None,
                          space_for=lambda **st: SearchSpace(),
                          oracle=lambda *a: a, signature_fn=lambda *a: {},
                          registry_=reg)(
            lambda schedule, **st: (lambda *a: ("owned", a)))
        assert spec.owner is reg
        assert "owned_only" not in registry
        assert spec(5) == ("owned", (5,))
        assert reg.instance_count() == 1

    def test_concurrent_variant_first_use(self):
        """Concurrent first use of a lazily-registered attention variant
        must race-safely resolve ONE shared instance (no duplicate-name
        ValueError from check-then-register)."""
        import threading
        from repro.kernels.flash_attention import ops as fa_ops
        results, errors = [], []
        barrier = threading.Barrier(4)

        def resolve():
            try:
                barrier.wait()
                results.append(fa_ops.kernel(causal=True, window=48))
            except Exception as exc:          # noqa: BLE001
                errors.append(exc)

        threads = [threading.Thread(target=resolve) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert len({id(k) for k in results}) == 1

    def test_module_singletons_are_late_binding(self, tmp_path):
        """Exported handles (gemm_leaky_relu, rmsnorm, ...) must resolve the
        instance for the schedule_cache scope active at USE time, not the
        one current when the module was imported."""
        from repro.kernels.gemm_fused import ops as gemm_ops
        default_cache = registry.get(GEMM).cache
        with schedule_cache(str(tmp_path / "late.json")) as cache:
            assert gemm_ops.gemm_leaky_relu.cache is cache
        assert gemm_ops.gemm_leaky_relu.cache is default_cache
        x = np.ones((16, 32), np.float32)
        w = np.ones((32, 16), np.float32)
        assert gemm_ops.gemm_leaky_relu(x, w).shape == (16, 16)

    def test_schedule_cache_scoping(self):
        assert active_schedule_cache() is None
        outer, inner = ScheduleCache(), ScheduleCache()
        with schedule_cache(outer):
            assert active_schedule_cache() is outer
            with schedule_cache(inner):            # reentrant; innermost wins
                assert active_schedule_cache() is inner
            assert active_schedule_cache() is outer
        assert active_schedule_cache() is None

    def test_load_all_idempotent_and_complete(self):
        names = kernels.load_all()
        assert kernels.load_all() == names
        for expected in (GEMM, RMS, "flash_attention_causal",
                         "ssd_intra_chunk"):
            assert expected in names


class TestTuneConfigValidate:
    def test_valid_default_passes(self):
        assert TuneConfig().validate() is not None

    @pytest.mark.parametrize("bad", [
        dict(rounds=0), dict(step_samples=-1), dict(chains=0),
        dict(t_min=1.0, t_max=1.0), dict(t_min=2.0), dict(ladder=0.0),
        dict(energy="nope"),
    ])
    def test_rejections(self, bad):
        with pytest.raises(ValueError):
            TuneConfig(**bad).validate()

    def test_sip_kernel_tune_validates_before_work(self):
        kern = registry.spec(RMS).instantiate()
        x = np.zeros((16, 32), np.float32)
        g = np.zeros((32,), np.float32)
        with pytest.raises(ValueError, match="chains"):
            kern.tune([x, g], TuneConfig(chains=0))

    def test_session_validates_on_construction(self):
        with pytest.raises(ValueError, match="energy"):
            TuningSession(config=TuneConfig(energy="nope"))


class TestWorkloadSeeding:
    def test_seed_is_stable_and_distinct(self):
        s = workload_seed(GEMM, "smoke_16x16x32")
        assert s == workload_seed(GEMM, "smoke_16x16x32")
        assert s != workload_seed(RMS, "smoke_16x16x32")
        assert s != workload_seed(GEMM, "other")
        assert s != workload_seed(GEMM, "smoke_16x16x32", base=1)

    def test_results_independent_of_kernel_selection(self, tmp_path):
        """Tuning rmsnorm alone and tuning it after gemm must produce
        IDENTICAL rmsnorm entries — the pre-redesign launcher threaded one
        shared rng through all kernels, so selection changed every input."""
        def rms_entries(path, selection):
            cache = ScheduleCache(str(path))
            TuningSession(cache=cache, config=QUICK).run(
                kernels=selection, suite="smoke")
            spec = registry.spec(RMS)
            wl = spec.workloads_in("smoke")[0]
            args = wl.make_args(np.random.default_rng(
                workload_seed(RMS, wl.name, QUICK.seed)))
            kern = spec.instantiate()
            sig = kern.sig_str(kern.static_of(*args))
            return [(e.schedule_json, e.energy)
                    for e in cache.entries(RMS, sig)]

        alone = rms_entries(tmp_path / "alone.json", [RMS])
        after_gemm = rms_entries(tmp_path / "both.json", [GEMM, RMS])
        assert alone and alone == after_gemm


class TestTuningSession:
    def test_two_kernels_one_cache_end_to_end(self, tmp_path):
        path = tmp_path / "cache.json"
        cache = ScheduleCache(str(path))
        runs = TuningSession(cache=cache, config=QUICK).run(
            kernels=[GEMM, RMS], suite="smoke", verbose=False)
        assert {r.kernel for r in runs} == {GEMM, RMS}
        persisted = json.loads(path.read_text())
        assert {k.split("::", 1)[0] for k in persisted} == {GEMM, RMS}
        # deployment resolves the tuned schedules from the same store
        with schedule_cache(str(path)):
            for run in runs:
                kern = registry.get(run.kernel)
                static = json.loads(run.signature)
                assert kern.cache.best(run.kernel, run.signature) is not None
                assert kern.schedule_for(static) is not None

    def test_session_does_not_pin_global_instances(self, tmp_path):
        """Sessions use a session-local instance memo, so repeated sessions
        (each with its own cache) must not grow registry._instances."""
        before = registry.instance_count()
        TuningSession(cache=str(tmp_path / "c.json"), config=QUICK).run(
            kernels=[RMS], suite="smoke")
        assert registry.instance_count() == before

    def test_windowed_variant_declared_workload_is_tunable(self, tmp_path):
        """register_variant(causal, window, workloads=...) makes a sliding-
        window variant offline-tunable — the declaration lives next to the
        kernel, and the generic driver picks it up by name."""
        from repro.kernels.flash_attention import ops as fa_ops
        spec = fa_ops.register_variant(True, 12, workloads=(
            Workload("smoke_w12", fa_ops._attn_args(1, 2, 2, 16, 8),
                     suites=("smoke",)),))
        runs = TuningSession(cache=str(tmp_path / "w.json"),
                             config=QUICK).run(kernels=[spec.name],
                                               suite="smoke")
        assert len(runs) == 1 and runs[0].kernel == "flash_attention_causal_w12"

    def test_tuning_invalidates_shared_instance_resolution(self, tmp_path):
        """A signature resolved (and memoized) on the shared serving
        instance BEFORE tuning must re-resolve to the tuned schedule after a
        session tunes into the same store through its own instance."""
        path = str(tmp_path / "coherent.json")
        spec = registry.spec(RMS)
        wl = spec.workloads_in("smoke")[0]
        args = list(wl.make_args(np.random.default_rng(0)))
        with schedule_cache(path) as cache:
            shared = registry.get(RMS)
            shared(*args)                  # memoizes the default resolution
            TuningSession(cache=path, config=QUICK).run(
                kernels=[RMS], suite="smoke")
            static = shared.static_of(*args)
            sig = shared.sig_str(static)
            tuned = cache.best(RMS, sig)
            assert tuned is not None
            shared(*args)                  # store version bumped: re-resolves
            assert shared._resolved[sig] is \
                shared._built[(sig, tuned.signature())]

    def test_instance_memo_is_bounded(self):
        """Fresh instance-form caches must not grow registry._instances
        without bound (each entry pins compiled builds + a store)."""
        for _ in range(70):
            registry.get(RMS, cache=ScheduleCache())
        assert registry.instance_count() <= 64

    def test_unknown_kernel_raises_before_tuning(self, tmp_path):
        sess = TuningSession(cache=str(tmp_path / "c.json"), config=QUICK)
        with pytest.raises(KeyError, match="unknown kernel"):
            sess.run(kernels=["nope"], suite="smoke")

    def test_chains1_bit_equivalent_to_direct_tune(self, tmp_path):
        """The session adds orchestration, not search behavior: a chains=1
        session workload reproduces direct SipKernel.tune bit-for-bit."""
        spec = registry.spec(GEMM)
        wl = spec.workloads_in("smoke")[0]
        run = TuningSession(cache=str(tmp_path / "s.json"),
                            config=QUICK).run_workload(GEMM, wl)

        seed = workload_seed(GEMM, wl.name, QUICK.seed)
        args = list(wl.make_args(np.random.default_rng(seed)))
        kern = spec.instantiate(cache=ScheduleCache(str(tmp_path / "d.json")))
        direct = kern.tune(args, dataclasses.replace(QUICK, seed=seed))

        assert len(run.results) == len(direct)
        for got, want in zip(run.results, direct):
            assert got.best.signature() == want.best.signature()
            assert got.best_raw == want.best_raw            # exact, not close
            assert got.initial_raw == want.initial_raw


class TestTuneCLI:
    def test_list_prints_registry(self, capsys):
        from repro.launch import tune
        assert tune.main(["--list"]) == 0
        out = capsys.readouterr().out
        for name in (GEMM, RMS, "flash_attention_causal", "ssd_intra_chunk"):
            assert name in out
        assert "smoke" in out and "default" in out

    def test_unknown_kernel_errors(self, capsys, tmp_path):
        from repro.launch import tune
        with pytest.raises(SystemExit):
            tune.main(["--kernel", "nope",
                       "--cache", str(tmp_path / "c.json")])

    def test_smoke_forwards_guided_flags(self, monkeypatch, tmp_path):
        """--smoke rebuilds the config for the CI gate but must not silently
        drop --guided/--greed (the parsed-and-dropped bug class)."""
        from repro.launch import tune
        seen = {}

        class FakeSession:
            failures: list = []

            def __init__(self, cache=None, config=None, **kw):
                seen["cfg"] = config

            def run(self, kernels=None, suite="default", verbose=False,
                    resume=False):
                seen["suite"] = suite
                return [object()]

        monkeypatch.setattr(tune, "TuningSession", FakeSession)
        assert tune.main(["--smoke", "--guided", "--greed", "0.9",
                          "--cache", str(tmp_path / "c.json")]) == 0
        assert seen["cfg"].guided is True and seen["cfg"].greed == 0.9
        assert seen["suite"] == "smoke" and seen["cfg"].rounds == 1

    def test_smoke_single_kernel_run(self, tmp_path, capsys):
        from repro.launch import tune
        path = tmp_path / "smoke.json"
        assert tune.main(["--smoke", "--kernel", RMS,
                          "--cache", str(path)]) == 0
        assert "persisted" in capsys.readouterr().out
        persisted = json.loads(path.read_text())
        assert all(k.startswith(f"{RMS}::") for k in persisted) and persisted


class TestModelPathsUseRegistry:
    def test_attention_variant_resolves_one_instance(self):
        from repro.kernels.flash_attention import ops as fa_ops
        k1 = fa_ops.kernel(causal=True, window=None)
        assert fa_ops.kernel(causal=True, window=None) is k1
        # lazily-registered variant is cached too
        w1 = fa_ops.kernel(causal=True, window=8)
        assert fa_ops.kernel(causal=True, window=8) is w1
        assert w1 is not k1

    def test_model_attention_reuses_kernel_object(self):
        """Regression: the model path used to construct a fresh SipKernel
        (+ fresh ScheduleCache and build caches) on EVERY pallas call."""
        from repro.models import attention as attn
        from repro.models.config import ModelConfig
        from repro.models import modules as nn
        cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                          n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                          use_pallas=True)
        p = nn.unwrap(attn.init_attention(jax.random.PRNGKey(0), cfg))
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            (1, 16, 32)), jnp.float32)
        o1 = attn.attention(p, x, cfg)
        count = registry.instance_count()
        o2 = attn.attention(p, x, cfg)
        assert registry.instance_count() == count   # no new instances
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))

    def test_mamba_pallas_routing_matches_jnp(self):
        from repro.models import modules as nn
        from repro.models import ssm
        from repro.models.config import ModelConfig
        cfg = ModelConfig(name="m", family="ssm", n_layers=1, d_model=16,
                          n_heads=1, n_kv_heads=1, d_ff=32, vocab=64,
                          ssm_state=8, ssm_headdim=4, ssm_chunk=8)
        p = nn.unwrap(ssm.init_mamba(jax.random.PRNGKey(0), cfg))
        x = jnp.asarray(np.random.default_rng(1).standard_normal(
            (2, 16, 16)) * 0.1, jnp.float32)
        ref = ssm.mamba(p, x, cfg)
        got = ssm.mamba(p, x, dataclasses.replace(cfg, use_pallas=True))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestDeprecationShims:
    def test_ops_make_warns_but_works(self):
        from repro.kernels.gemm_fused import ops as gemm_ops
        from repro.kernels.flash_attention import ops as fa_ops
        with pytest.warns(DeprecationWarning):
            kern = gemm_ops.make()
        assert kern is not registry.get(GEMM)      # unshared, as before
        assert kern.name == GEMM
        with pytest.warns(DeprecationWarning):
            fa = fa_ops.make(causal=True)
        assert fa.name == "flash_attention_causal"
