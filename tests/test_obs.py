"""repro.obs telemetry: metrics registry scoping + histogram edges, tracer
span nesting + Chrome export + validation, engine instrumentation (registry-
backed stats, zero-run metrics guards, trace spans), and the
WorkloadRecorder -> TuningSession round trip."""

import json
import math
import threading

import numpy as np
import pytest

import jax

from repro import obs
from repro.launch import obsreport
from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.obs.metrics import (Histogram, MetricsRegistry, active_registry,
                               exponential_edges, metrics_scope)
from repro.obs.recorder import WorkloadKey, WorkloadRecorder
from repro.obs.trace import (Tracer, active_tracer, load_trace, span,
                             tracing, validate_events, validate_trace)
from repro.serve.engine import ContinuousEngine, ServeConfig

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                  dtype="float32").validate()


@pytest.fixture(scope="module")
def params():
    return nn.unwrap(M.init_lm(jax.random.PRNGKey(0), CFG))


# ================================================================= metrics
class TestMetrics:
    def test_counter_gauge_basics(self):
        reg = MetricsRegistry()
        c = reg.counter("c")
        c.inc()
        c.inc(2)
        assert c.value == 3 and isinstance(c.value, int)
        c.inc(0.5)
        assert c.value == 3.5
        g = reg.gauge("g")
        g.set(7)
        assert g.value == 7.0
        assert reg.counter("c") is c          # get-or-create shares
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("c")

    def test_histogram_under_and_overflow(self):
        """Values below the first and above the last bucket edge are counted
        in the open end buckets, never dropped."""
        h = Histogram("h", edges=[1.0, 2.0, 4.0])
        for v in (0.1, 0.5):                  # below first edge
            h.record(v)
        for v in (100.0, 9e9):                # above last edge
            h.record(v)
        h.record(3.0)
        assert h.count == 5
        snap = h.snapshot()
        assert snap["counts"][0] == 2         # underflow bucket
        assert snap["counts"][-1] == 2        # overflow bucket
        assert snap["min"] == 0.1 and snap["max"] == 9e9
        # percentiles stay finite and within observed range
        for q in (0, 50, 95, 99, 100):
            p = h.percentile(q)
            assert math.isfinite(p) and 0.1 <= p <= 9e9
        h.record(float("inf"))                # non-finite: ignored
        h.record(float("nan"))
        assert h.count == 5

    def test_histogram_empty_and_validation(self):
        h = Histogram("h", edges=[1.0, 2.0])
        assert h.percentile(50) == 0.0 and h.mean == 0.0
        with pytest.raises(ValueError, match="strictly increasing"):
            Histogram("bad", edges=[2.0, 1.0])
        with pytest.raises(ValueError, match="strictly increasing"):
            Histogram("bad", edges=[1.0, 1.0])
        edges = exponential_edges(1e-3, 10.0, 8)
        assert len(edges) == 8 and list(edges) == sorted(edges)

    def test_histogram_concurrent_recording(self):
        """The engine records from its streaming-callback thread while the
        driver thread reads — no lost updates under contention."""
        h = Histogram("h", edges=list(exponential_edges(1e-3, 10.0, 12)))
        n_threads, per_thread = 8, 500

        def work(seed):
            rng = np.random.default_rng(seed)
            for _ in range(per_thread):
                h.record(float(rng.uniform(1e-4, 20.0)))

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert h.count == n_threads * per_thread
        assert sum(h.snapshot()["counts"]) == n_threads * per_thread

    def test_registry_isolation_nested_scopes(self):
        """Nested metrics_scope levels are isolated from each other AND from
        the process default; innermost wins; exit restores."""
        default = active_registry()
        with metrics_scope() as outer:
            assert active_registry() is outer
            outer.counter("x").inc()
            with metrics_scope() as inner:
                assert active_registry() is inner
                inner.counter("x").inc(10)
                assert inner.counter("x").value == 10
            assert active_registry() is outer
            assert outer.counter("x").value == 1
        assert active_registry() is default
        assert "x" not in default or default.counter("x").value not in (1, 10)

    def test_snapshot_save_json(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("a").inc(3)
        reg.histogram("h", edges=[1.0]).record(0.5)
        path = str(tmp_path / "m.json")
        reg.save_json(path)
        with open(path) as f:
            snap = json.load(f)
        assert snap["a"] == {"type": "counter", "value": 3}
        assert snap["h"]["count"] == 1 and "p99" in snap["h"]


# =================================================================== trace
class TestTracer:
    def test_nested_spans_validate(self, tmp_path):
        tr = Tracer()
        with tr.span("outer", kind="test"):
            with tr.span("inner"):
                tr.instant("tick", i=1)
            tr.counter("energy", {"e": 0.5})
        events = tr.events()
        assert [e["ph"] for e in events] == ["I", "X", "C", "X"]
        assert validate_events(events) == []
        chrome = tr.to_chrome()
        assert len(chrome["traceEvents"]) == 4
        # round-trip both file forms
        for name in ("t.json", "t.jsonl"):
            p = str(tmp_path / name)
            tr.save(p)
            assert validate_trace(p) == []
            assert len(load_trace(p)) == 4
        # chrome form is strictly-valid JSON with spans nested by time
        with open(str(tmp_path / "t.json")) as f:
            loaded = json.load(f)
        outer, = [e for e in loaded["traceEvents"] if e["name"] == "outer"]
        inner, = [e for e in loaded["traceEvents"] if e["name"] == "inner"]
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 0.01

    def test_span_args_attach_results(self):
        tr = Tracer()
        with tr.span("s", a=1) as sp:
            sp["b"] = 2
        ev, = tr.events()
        assert ev["args"] == {"a": 1, "b": 2}

    def test_nonfinite_args_stay_strict_json(self):
        tr = Tracer()
        tr.instant("i", bad=float("inf"), worse=float("nan"), ok=1.5)
        line = json.dumps(tr.events()[0])     # must not raise under strict
        ev = json.loads(line)
        assert ev["args"]["ok"] == 1.5
        assert isinstance(ev["args"]["bad"], str)

    def test_validator_catches_malformed(self):
        assert validate_events([{"ph": "Z", "name": "x", "ts": 0.0,
                                 "pid": 1, "tid": 1}])
        assert validate_events([{"ph": "X", "name": "x", "ts": -1.0,
                                 "dur": 1.0, "pid": 1, "tid": 1}])
        assert validate_events([{"ph": "X", "name": "x", "ts": 0.0,
                                 "pid": 1, "tid": 1}])   # missing dur
        # overlapping, non-nesting spans on one track
        bad = [{"ph": "X", "name": "a", "ts": 0.0, "dur": 10.0,
                "pid": 1, "tid": 1, "args": {}},
               {"ph": "X", "name": "b", "ts": 5.0, "dur": 10.0,
                "pid": 1, "tid": 1, "args": {}}]
        assert any("overlaps" in e for e in validate_events(bad))

    def test_scope_helpers_noop_when_inactive(self):
        assert active_tracer() is None
        with span("s", a=1) as sp:            # must not raise
            sp["b"] = 2
        obs.instant("i")
        with tracing() as tr:
            assert active_tracer() is tr
            with span("s"):
                pass
            assert len(tr.events()) == 1
        assert active_tracer() is None

    def test_threaded_spans_get_own_tracks(self):
        tr = Tracer()

        def work():
            with tr.span("child"):
                pass

        with tracing(tr):
            t = threading.Thread(target=work)
            with tr.span("main"):
                t.start()
                t.join()
        events = tr.events()
        tids = {e["tid"] for e in events}
        assert len(tids) == 2                 # one track per thread
        assert validate_events(events) == []

    def test_streaming_jsonl_sink(self, tmp_path):
        p = str(tmp_path / "stream.jsonl")
        tr = Tracer(jsonl_path=p)
        with tr.span("s"):
            pass
        tr.close()
        assert validate_trace(p) == []
        assert len(load_trace(p)) == 1


# ====================================================== engine integration
class TestEngineObservability:
    def test_zero_run_metrics_all_finite(self, params):
        """Satellite regression: a never-stepped engine reports well-defined
        0.0 rates — no ZeroDivisionError, no inf/NaN."""
        eng = ContinuousEngine(params, CFG,
                               ServeConfig(max_len=16, capacity=2))
        m = eng.metrics()
        assert set(m) == {"queue_depth", "slot_occupancy", "mean_occupancy",
                          "mean_queue_depth", "prefill_s", "decode_s",
                          "prefill_frac", "tokens_per_s",
                          "decode_tokens_per_s"}
        for k, v in m.items():
            assert math.isfinite(v), (k, v)
        assert m["tokens_per_s"] == 0.0
        assert m["decode_tokens_per_s"] == 0.0
        assert m["prefill_frac"] == 0.0
        assert m["mean_occupancy"] == 0.0

    def test_stats_and_histograms_from_registry(self, params):
        eng = ContinuousEngine(params, CFG,
                               ServeConfig(max_len=24, capacity=2))
        rng = np.random.default_rng(0)
        hs = [eng.submit(rng.integers(0, CFG.vocab, 5).astype(np.int32), 3)
              for _ in range(3)]
        out = eng.run(max_steps=10_000)
        assert all(len(out[h.uid]) == 3 for h in hs)
        s = eng.stats
        assert s["submitted"] == s["completed"] == 3
        assert s["tokens_out"] == 9
        # the registry is the source of truth behind the stats dict
        assert eng.obs.counter("serve.tokens_out").value == 9
        assert eng.obs.histogram("serve.ttft_s").count == 3
        assert eng.obs.histogram("serve.inter_token_s").count == 6
        snap = eng.obs.snapshot()
        assert snap["serve.prefill_call_s"]["count"] >= 1
        assert snap["serve.decode_step_s"]["count"] >= 1
        m = eng.metrics()
        assert m["tokens_per_s"] > 0 and 0 < m["prefill_frac"] < 1

    def test_engines_do_not_share_counters(self, params):
        e1 = ContinuousEngine(params, CFG,
                              ServeConfig(max_len=16, capacity=1))
        e2 = ContinuousEngine(params, CFG,
                              ServeConfig(max_len=16, capacity=1))
        e1.submit(np.zeros(4, np.int32), 2)
        e1.run(max_steps=100)
        assert e1.stats["submitted"] == 1
        assert e2.stats["submitted"] == 0

    def test_reset_stats_keeps_compiles(self, params):
        eng = ContinuousEngine(params, CFG,
                               ServeConfig(max_len=16, capacity=1))
        eng.submit(np.zeros(4, np.int32), 2)
        eng.run(max_steps=100)
        compiles = eng.stats["prefill_compiles"]
        assert compiles >= 1
        eng.reset_stats()
        s = eng.stats
        assert s["prefill_compiles"] == compiles
        assert s["tokens_out"] == 0 and s["steps"] == 0
        assert eng.obs.histogram("serve.ttft_s").count == 0

    def test_serve_run_emits_valid_spans(self, params, tmp_path):
        tr = Tracer()
        with tracing(tr):
            eng = ContinuousEngine(params, CFG,
                                   ServeConfig(max_len=16, capacity=2))
            eng.submit(np.zeros(4, np.int32), 2)
            eng.submit(np.ones(6, np.int32), 2)
            eng.run(max_steps=100)
        events = tr.events()
        names = {e["name"] for e in events}
        assert "serve.prefill" in names and "serve.decode" in names
        assert validate_events(events) == []
        p = str(tmp_path / "serve.json")
        tr.save(p)
        assert validate_trace(p) == []


# ======================================================== workload recorder
class TestWorkloadRecorder:
    def test_engine_hook_and_roundtrip(self, params, tmp_path):
        rec = WorkloadRecorder()
        eng = ContinuousEngine(params, CFG,
                               ServeConfig(max_len=24, capacity=2),
                               recorder=rec)
        rng = np.random.default_rng(1)
        for plen in (5, 5, 8):
            eng.submit(rng.integers(0, CFG.vocab, plen).astype(np.int32), 3)
        eng.run(max_steps=10_000)
        mix = rec.mix()
        kinds = {k.kind for k in mix}
        assert kinds == {"submit", "prefill", "decode"}
        prefill_rows = sum(k.batch * n for k, n in mix.items()
                           if k.kind == "prefill")
        assert prefill_rows == 3              # every request prefilled once
        path = str(tmp_path / "live.jsonl")
        rec.save(path)
        assert obsreport.validate_workloads(path) == []
        loaded = WorkloadRecorder.load(path)
        assert loaded.mix() == mix
        assert loaded.summary()["submitted"] == 3

    def test_to_workloads_into_tuning_session(self, tmp_path):
        """Acceptance: recorder output round-trips into a TuningSession
        workload list — tuned entries land in the schedule cache."""
        from repro.core.cache import ScheduleCache
        from repro.core.jit import TuneConfig
        from repro.kernels.gemm_fused import ops as gemm_ops
        from repro.tuning.session import TuningSession

        rec = WorkloadRecorder()
        rec.record("prefill", prompt_len=16, batch=2, dtype="float32",
                   occupancy=2)
        rec.record("prefill", prompt_len=16, batch=2, dtype="float32",
                   occupancy=1)
        rec.record("decode", batch=4, dtype="float32", occupancy=3)
        path = str(tmp_path / "live.jsonl")
        rec.save(path)

        def gemm_args_for(key: WorkloadKey):
            if key.kind != "prefill":
                return None                   # decode mix tunes other kernels

            def make_args(rng):
                x = rng.standard_normal((key.prompt_len, 32)).astype(
                    np.float32)
                w = rng.standard_normal((32, 16)).astype(np.float32)
                return [x, w]
            return make_args

        wls = WorkloadRecorder.load(path).to_workloads(gemm_args_for)
        assert len(wls) == 1 and wls[0].name.startswith("live_prefill_p16")
        assert wls[0].suites == ("live",)
        cache = ScheduleCache()
        session = TuningSession(cache=cache, config=TuneConfig(
            rounds=1, t_min=0.5, cooling=1.4, step_samples=0,
            final_samples=2))
        run = session.run_workload(gemm_ops.NAME, wls[0])
        assert run.workload == wls[0].name
        assert cache.entries(gemm_ops.NAME, run.signature)

    def test_record_cap_keeps_mix_complete(self):
        rec = WorkloadRecorder(max_records=3)
        for _ in range(10):
            rec.record("decode", batch=1, occupancy=1)
        assert len(rec) == 3 and rec.dropped == 7
        assert sum(rec.mix().values()) == 10  # aggregation never truncated


# =============================================================== obsreport
class TestObsreport:
    def test_validate_cli_ok_and_invalid(self, tmp_path, capsys):
        tr = Tracer()
        with tr.span("a"):
            pass
        good = str(tmp_path / "good.json")
        tr.save(good)
        assert obsreport.main([good, "--validate"]) == 0
        bad = str(tmp_path / "bad.json")
        with open(bad, "w") as f:
            json.dump({"traceEvents": [{"ph": "X", "name": "x", "ts": 0.0,
                                        "pid": 1, "tid": 1}]}, f)
        assert obsreport.main([bad, "--validate"]) == 1
        out = capsys.readouterr().out
        assert "INVALID" in out

    def test_summary_cli(self, tmp_path, capsys):
        tr = Tracer()
        with tr.span("tune.round", kernel="k"):
            tr.counter("search.energy/chain0", {"energy": 0.9})
            tr.counter("search.energy/chain0", {"energy": 0.7})
        p = str(tmp_path / "t.json")
        tr.save(p)
        reg = MetricsRegistry()
        reg.histogram("h").record(0.01)
        mp = str(tmp_path / "m.json")
        reg.save_json(mp)
        assert obsreport.main([p, "--metrics-json", mp]) == 0
        out = capsys.readouterr().out
        assert "tune.round" in out and "search.energy/chain0" in out
        assert "p99" in out or "p95" in out

    def test_workloads_validation_catches_bad_lines(self, tmp_path):
        p = str(tmp_path / "w.jsonl")
        with open(p, "w") as f:
            f.write(json.dumps({"kind": "warp", "t": 0.0}) + "\n")
        errs = obsreport.validate_workloads(p)
        assert errs and any("bad kind" in e for e in errs)
