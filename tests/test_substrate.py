"""Substrate tests: optimizer, data pipeline, checkpointing, FT manager."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import DataConfig, DataIterator, batch_at
from repro.ft.manager import Action, FTConfig, FTManager
from repro.optim import adamw


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        opt = adamw.init_opt_state(params)
        cfg = adamw.OptConfig(peak_lr=0.2, warmup_steps=1, decay_steps=200,
                              weight_decay=0.0, clip_norm=100.0)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(100):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw.adamw_update(g, opt, params, cfg)
        assert float(loss(params)) < 1e-2

    def test_lr_schedule(self):
        cfg = adamw.OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=110,
                              min_lr_ratio=0.1)
        assert float(adamw.lr_at(jnp.int32(5), cfg)) == pytest.approx(0.5)
        assert float(adamw.lr_at(jnp.int32(10), cfg)) == pytest.approx(1.0)
        assert float(adamw.lr_at(jnp.int32(110), cfg)) == pytest.approx(0.1)

    def test_clipping(self):
        g = {"w": jnp.array([3.0, 4.0])}            # norm 5
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(5.0)
        assert float(adamw.global_norm(clipped)) == pytest.approx(1.0)

    def test_weight_decay_moves_zero_grad_params(self):
        params = {"w": jnp.array([1.0])}
        opt = adamw.init_opt_state(params)
        cfg = adamw.OptConfig(peak_lr=0.1, warmup_steps=1, weight_decay=0.5)
        g = {"w": jnp.array([0.0])}
        p2, _, _ = adamw.adamw_update(g, opt, params, cfg)
        assert float(p2["w"][0]) < 1.0


class TestDataPipeline:
    def test_deterministic_per_step(self):
        cfg = DataConfig(global_batch=4, seq_len=64)
        a = batch_at(cfg, 7)
        b = batch_at(cfg, 7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        cfg = DataConfig(global_batch=4, seq_len=64)
        assert not np.array_equal(batch_at(cfg, 0)["tokens"],
                                  batch_at(cfg, 1)["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(global_batch=2, seq_len=32)
        b = batch_at(cfg, 0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_slicing_partitions_batch(self):
        cfg = DataConfig(global_batch=8, seq_len=16)
        slices = [batch_at(cfg, 3, host=h, n_hosts=4)["tokens"]
                  for h in range(4)]
        assert all(s.shape == (2, 16) for s in slices)
        flat = [s.tobytes() for s in slices]
        assert len(set(flat)) == 4                  # hosts see distinct data

    def test_iterator_resume(self):
        from repro.models.config import ModelConfig
        mcfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                           n_heads=2, n_kv_heads=2, d_ff=64, vocab=128)
        dcfg = DataConfig(global_batch=2, seq_len=16, vocab=128)
        it = DataIterator(mcfg, dcfg)
        batches = [next(it) for _ in range(5)]
        it2 = DataIterator(mcfg, dcfg, start_step=3)   # resume mid-stream
        np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                      np.asarray(next(it2)["tokens"]))

    def test_vocab_bounds(self):
        cfg = DataConfig(global_batch=4, seq_len=256, vocab=100)
        b = batch_at(cfg, 0)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


class TestCheckpoint:
    def _tree(self, v=1.0):
        return {"params": {"w": jnp.full((4, 4), v), "b": jnp.arange(3.0)},
                "opt": {"step": jnp.int32(7)}}

    def test_roundtrip(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(10, self._tree(2.5))
        step, restored = cm.restore_latest(self._tree(0.0))
        assert step == 10
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.full((4, 4), 2.5, np.float32))
        assert int(restored["opt"]["step"]) == 7

    def test_integrity_detection(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, self._tree())
        # corrupt the arrays file
        path = os.path.join(str(tmp_path), "step_00000001", "arrays.npz")
        data = open(path, "rb").read()
        with open(path, "wb") as f:
            f.write(data[:len(data) // 2])
        assert not cm.verify(1)
        with pytest.raises(IOError):
            cm.restore(1, self._tree())

    def test_restore_latest_skips_corrupt(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, self._tree(1.0))
        cm.save(2, self._tree(2.0))
        path = os.path.join(str(tmp_path), "step_00000002", "arrays.npz")
        with open(path, "wb") as f:
            f.write(b"garbage")
        corrupt_seen = []
        step, restored = cm.restore_latest(self._tree(0.0),
                                           on_corrupt=corrupt_seen.append)
        assert step == 1 and corrupt_seen == [2]
        assert float(restored["params"]["w"][0, 0]) == 1.0

    def test_gc_keeps_newest(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            cm.save(s, self._tree(float(s)))
        assert cm.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(5, self._tree(3.0), blocking=False)
        cm.wait()
        assert cm.latest_step() == 5 and cm.verify(5)

    def test_dtype_cast_on_restore(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, {"w": jnp.ones((2,), jnp.float32)})
        out = cm.restore(1, {"w": jnp.zeros((2,), jnp.bfloat16)})
        assert out["w"].dtype == jnp.bfloat16


class TestFTManager:
    def _mgr(self, n=8):
        self.t = [0.0]
        clock = lambda: self.t[0]
        return FTManager(n, FTConfig(heartbeat_timeout_s=10.0), clock), clock

    def test_healthy_continue(self):
        mgr, _ = self._mgr()
        for i in range(8):
            mgr.heartbeat(i, 1.0)
        action, info = mgr.decide()
        assert action is Action.CONTINUE and not info

    def test_dead_worker_triggers_restart(self):
        mgr, _ = self._mgr()
        self.t[0] = 100.0
        for i in range(7):
            mgr.heartbeat(i, 1.0)
        # worker 7 silent since t=0
        action, info = mgr.decide()
        assert action in (Action.RESTART_FROM_CKPT, Action.ELASTIC_RESHAPE)
        assert info["dead"] == [7]

    def test_elastic_reshape_on_capacity_loss(self):
        cfg = FTConfig(heartbeat_timeout_s=10.0,
                       mesh_ladder=(((2, 16, 16), ("pod", "data", "model")),
                                    ((16, 16), ("data", "model"))))
        t = [0.0]
        mgr = FTManager(64, cfg, clock=lambda: t[0])   # 64 hosts * 8 = 512
        t[0] = 100.0
        for i in range(40):                             # 24 hosts lost
            mgr.heartbeat(i, 1.0)
        action, info = mgr.decide()
        assert action is Action.ELASTIC_RESHAPE
        assert info["mesh"][0] == (16, 16)              # falls back to 1 pod

    def test_straggler_detection(self):
        mgr, _ = self._mgr(8)
        for step in range(20):
            for i in range(8):
                mgr.heartbeat(i, 1.0 if i != 3 else 10.0)
        assert mgr.stragglers() == [3]
        action, info = mgr.decide()
        assert action is Action.CONTINUE and info["stragglers"] == [3]

    def test_restart_budget(self):
        cfg = FTConfig(heartbeat_timeout_s=1.0, max_restarts=1)
        t = [0.0]
        mgr = FTManager(4, cfg, clock=lambda: t[0])
        t[0] = 10.0
        mgr.decide()                                    # restart 1
        for w in mgr.workers.values():
            w.alive = True
        t[0] = 20.0
        with pytest.raises(RuntimeError):
            mgr.decide()
