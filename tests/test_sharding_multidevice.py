"""Sharding correctness on a real (placeholder-device) mesh.

These tests need >1 device, which requires XLA_FLAGS before jax initializes —
so they run in a SUBPROCESS with --xla_force_host_platform_device_count=8
and assert on its output.  Covered: partition rules, sharded-vs-single-device
numeric equivalence of a train step, compressed gradient collectives, and
elastic checkpoint resharding across different mesh shapes.
"""

import json
import os
import subprocess
import sys

import pytest

SUBPROC = os.path.join(os.path.dirname(__file__), "sharded_subprocess.py")


def run_subproc(mode: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, SUBPROC, mode], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
class TestShardedExecution:
    def test_train_step_sharded_matches_single(self):
        r = run_subproc("train_parity")
        assert r["max_rel_err"] < 2e-4, r

    def test_compressed_psum_close_to_exact(self):
        r = run_subproc("compressed_psum")
        assert r["rel_err"] < 2e-2, r
        assert r["exact_is_exact"] < 1e-6, r

    def test_manual_tp_matches_single_device(self):
        """dist.tp shard_map prefill+decode: exact greedy tokens at mesh 2
        and 4; compressed seams within int8 tolerance."""
        r = run_subproc("tp_parity")
        for n in (2, 4):
            assert r[f"mesh{n}_tokens_equal"] is True, r
            assert r[f"mesh{n}_logit_err"] < 1e-4, r
            assert r[f"mesh{n}_compressed_rel"] < 5e-2, r

    def test_sharded_serve_token_identical(self):
        """The tentpole differential gate: tensor-parallel ContinuousEngine
        (contiguous AND paged, shard_map AND gspmd) produces the 1-device
        engine's exact greedy tokens at two mesh shapes and two arrival
        orderings; compressed-collective serving completes every request."""
        r = run_subproc("serve_sharded")
        assert all(r.values()), {k: v for k, v in r.items() if not v}

    def test_elastic_reshard_roundtrip(self):
        r = run_subproc("elastic")
        assert r["identical"] is True, r

    def test_supervised_elastic_reshape_finishes_near_baseline(self):
        """Permanent loss of half the workers mid-run: the supervisor must
        reshape onto the (2,2) ladder mesh, reshard the restore, and finish
        with (near-)baseline final loss — the elastic differential gate."""
        r = run_subproc("elastic_supervised")
        assert r["step"] == 12, r
        assert "elastic_reshape" in r["events"], r
        assert r["final_mesh"] == [2, 2], r
        assert abs(r["final_loss"] - r["base_loss"]) < 5e-3 * abs(
            r["base_loss"]), r


class TestPartitionRules:
    def test_resolve_spec_rules(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.dist.partition import resolve_spec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        assert resolve_spec(("vocab", "embed"), mesh) == P("model", "data")
        assert resolve_spec(("batch", "seq", None), mesh) == P("data", None, None)

    def test_divisibility_fallback(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.dist.partition import resolve_spec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # dims of size 1 cannot shard over axes of size 1? size 1 % 1 == 0,
        # so this passes; use a fake larger mesh on 1 device is impossible —
        # exercise the arithmetic directly instead.
        assert resolve_spec(("heads",), mesh, shape=(7,)) == P("model")

    def test_no_axis_reuse_in_one_spec(self):
        import jax
        from repro.dist.partition import resolve_spec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = resolve_spec(("vocab", "mlp"), mesh)   # both map to 'model'
        used = [s for s in spec if s is not None]
        assert len(used) == len(set(used)) == 1

    def test_pod_dropped_on_single_pod_mesh(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.dist.partition import resolve_spec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        assert resolve_spec(("batch",), mesh) == P("data")

    def test_shard_noop_without_mesh(self):
        import jax.numpy as jnp
        from repro.dist.partition import shard
        x = jnp.ones((4, 4))
        assert shard(x, "batch", None) is x
