"""Quantized-collective unit tests (single-device parts) + hypothesis
property tests on the system's numeric invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.dist.collectives import dequantize_int8, quantize_int8
from repro.models.attention import rope


class TestInt8Quantization:
    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((1000,)), jnp.float32)
        q, s, pad = quantize_int8(x)
        y = dequantize_int8(q, s, pad, x.shape)
        # per-block symmetric int8: |err| <= scale/2 = max|block| / 254
        err = np.max(np.abs(np.asarray(y) - np.asarray(x)))
        assert err <= float(jnp.max(jnp.abs(x))) / 254 + 1e-7

    def test_zero_preserved(self):
        x = jnp.zeros((100,), jnp.float32)
        q, s, pad = quantize_int8(x)
        np.testing.assert_array_equal(
            np.asarray(dequantize_int8(q, s, pad, x.shape)), 0.0)

    @given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                                   max_side=65),
                      elements=st.floats(-1e4, 1e4, width=32)))
    @settings(max_examples=40, deadline=None)
    def test_property_relative_error(self, x):
        xj = jnp.asarray(x)
        q, s, pad = quantize_int8(xj, block=64)
        y = np.asarray(dequantize_int8(q, s, pad, xj.shape))
        scale_bound = np.asarray(s).max() * 0.5 + 1e-6
        assert np.max(np.abs(y - x)) <= scale_bound + 1e-4 * np.max(np.abs(x) + 1)


class TestRopeProperties:
    @given(st.integers(0, 500), st.integers(0, 500), st.integers(0, 40))
    @settings(max_examples=25, deadline=None)
    def test_rope_is_relative(self, p1, p2, delta):
        """<rope(q, p1+d), rope(k, p2+d)> == <rope(q, p1), rope(k, p2)> —
        the dot product depends only on the position difference."""
        rng = np.random.default_rng(42)
        q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
        d1 = float(jnp.sum(rope(q, jnp.array([p1]), 1e4) *
                           rope(k, jnp.array([p2]), 1e4)))
        d2 = float(jnp.sum(rope(q, jnp.array([p1 + delta]), 1e4) *
                           rope(k, jnp.array([p2 + delta]), 1e4)))
        assert abs(d1 - d2) < 1e-3 * (abs(d1) + 1)

    def test_rope_preserves_norm(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((2, 8, 4, 64)), jnp.float32)
        out = rope(q, jnp.arange(8), 1e4)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(out)),
                                   np.linalg.norm(np.asarray(q)), rtol=1e-5)


class TestSoftmaxXentInvariants:
    @given(hnp.arrays(np.float32, (4, 16),
                      elements=st.floats(-30, 30, width=32)))
    @settings(max_examples=30, deadline=None)
    def test_xent_shift_invariance(self, logits):
        """loss(logits + c) == loss(logits) — the model's loss must be
        invariant to logit shifts (logsumexp formulation)."""
        from repro.models.model import _xent
        labels = jnp.asarray(np.arange(4) % 16, jnp.int32)
        l1 = _xent(jnp.asarray(logits), labels)
        l2 = _xent(jnp.asarray(logits) + 7.5, labels)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-4, atol=1e-4)

    @given(st.integers(0, 15))
    @settings(max_examples=15, deadline=None)
    def test_xent_nonnegative_and_exact_for_onehot(self, label):
        from repro.models.model import _xent
        logits = jnp.full((1, 16), -30.0).at[0, label].set(30.0)
        l = float(_xent(logits, jnp.asarray([label], jnp.int32))[0])
        assert 0 <= l < 1e-6
