"""Quantized-collective unit tests (single-device parts) + hypothesis
property tests on the system's numeric invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.dist.collectives import (compressed_psum, dequantize_int8,
                                    quantize_int8)
from repro.models.attention import rope


class TestInt8Quantization:
    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((1000,)), jnp.float32)
        q, s, pad = quantize_int8(x)
        y = dequantize_int8(q, s, pad, x.shape)
        # per-block symmetric int8: |err| <= scale/2 = max|block| / 254
        err = np.max(np.abs(np.asarray(y) - np.asarray(x)))
        assert err <= float(jnp.max(jnp.abs(x))) / 254 + 1e-7

    def test_zero_preserved(self):
        x = jnp.zeros((100,), jnp.float32)
        q, s, pad = quantize_int8(x)
        np.testing.assert_array_equal(
            np.asarray(dequantize_int8(q, s, pad, x.shape)), 0.0)

    @given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                                   max_side=65),
                      elements=st.floats(-1e4, 1e4, width=32)))
    @settings(max_examples=40, deadline=None)
    def test_property_relative_error(self, x):
        xj = jnp.asarray(x)
        q, s, pad = quantize_int8(xj, block=64)
        y = np.asarray(dequantize_int8(q, s, pad, xj.shape))
        scale_bound = np.asarray(s).max() * 0.5 + 1e-6
        assert np.max(np.abs(y - x)) <= scale_bound + 1e-4 * np.max(np.abs(x) + 1)


class TestCompressedPsumDtypeParity:
    """Regression: compressed_psum must come back in the INPUT dtype, like
    jax.lax.psum — the internal f32 dequantize+accumulate leaking out would
    silently double every downstream bf16 buffer it feeds."""

    def _psum_1dev(self, x):
        from jax.sharding import PartitionSpec as P
        from repro.dist.compat import shard_map
        mesh = jax.make_mesh((1,), ("pod",))
        return shard_map(lambda v: compressed_psum(v, "pod"), mesh=mesh,
                         in_specs=P(*([None] * x.ndim)),
                         out_specs=P(*([None] * x.ndim)),
                         check_vma=False)(x)

    def test_bf16_stays_bf16(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((8, 32)), jnp.bfloat16)
        out = self._psum_1dev(x)
        assert out.dtype == jnp.bfloat16, out.dtype

    def test_f32_stays_f32_and_single_shard_is_roundtrip(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((130,)), jnp.float32)
        out = self._psum_1dev(x)
        assert out.dtype == jnp.float32
        # one shard: the "sum" is just quantize->dequantize
        q, s, pad = quantize_int8(x)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(dequantize_int8(q, s, pad, x.shape)))


class TestNonFiniteContract:
    """quantize_int8 SANITIZES non-finite elements (see dist.collectives):
    scales see only finite magnitudes, NaN -> 0, ±Inf clamps to the block's
    finite extreme — one bad element never poisons its block."""

    def test_nan_quantizes_to_zero_others_survive(self):
        x = np.linspace(-2.0, 2.0, 64).astype(np.float32)
        x[13] = np.nan
        q, s, pad = quantize_int8(jnp.asarray(x))
        y = np.asarray(dequantize_int8(q, s, pad, x.shape))
        assert np.isfinite(y).all()
        assert y[13] == 0.0
        ok = np.delete(np.arange(64), 13)
        assert np.max(np.abs(y[ok] - x[ok])) <= 2.0 / 254 + 1e-7

    def test_inf_clamps_to_finite_extreme(self):
        x = np.linspace(-1.0, 1.0, 64).astype(np.float32)
        x[0], x[1] = np.inf, -np.inf
        q, s, pad = quantize_int8(jnp.asarray(x))
        y = np.asarray(dequantize_int8(q, s, pad, x.shape))
        amax = np.max(np.abs(x[2:]))
        assert np.isfinite(y).all()
        np.testing.assert_allclose(y[0], amax, rtol=1e-2)
        np.testing.assert_allclose(y[1], -amax, rtol=1e-2)

    def test_scale_ignores_nonfinite(self):
        # without sanitize the scale would be inf/nan and the whole block 0
        x = np.full((64,), 0.5, np.float32)
        x[7] = np.inf
        _, s, _ = quantize_int8(jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(s), 0.5 / 127.0, rtol=1e-6)

    def test_all_nonfinite_block_is_zeroed(self):
        x = np.full((64,), np.nan, np.float32)
        x[::2] = np.inf
        q, s, pad = quantize_int8(jnp.asarray(x))
        y = np.asarray(dequantize_int8(q, s, pad, x.shape))
        np.testing.assert_array_equal(y, 0.0)

    def test_nonfinite_cannot_cross_blocks(self):
        x = np.ones((128,), np.float32)
        x[3] = np.nan       # block 0 poisoned element
        q, s, pad = quantize_int8(jnp.asarray(x))
        y = np.asarray(dequantize_int8(q, s, pad, x.shape))
        np.testing.assert_allclose(y[64:], 1.0, rtol=1e-2)

    def test_compressed_psum_stays_finite(self):
        from jax.sharding import PartitionSpec as P
        from repro.dist.compat import shard_map
        x = jnp.asarray(np.r_[np.nan, np.inf, np.ones(62)], jnp.float32)
        mesh = jax.make_mesh((1,), ("pod",))
        out = shard_map(lambda v: compressed_psum(v, "pod"), mesh=mesh,
                        in_specs=P(None), out_specs=P(None),
                        check_vma=False)(x)
        assert np.isfinite(np.asarray(out)).all()


@st.composite
def _quant_inputs(draw):
    """Shapes that pad (total size not a multiple of the block), all-zero
    blocks, and both serving dtypes."""
    shape = draw(hnp.array_shapes(min_dims=1, max_dims=3, max_side=70))
    kind = draw(st.sampled_from(["random", "zeros", "mixed"]))
    if kind == "zeros":
        x = np.zeros(shape, np.float32)
    else:
        x = draw(hnp.arrays(np.float32, shape,
                            elements=st.floats(-1e4, 1e4, width=32)))
        if kind == "mixed" and x.size >= 64:
            x.reshape(-1)[:64] = 0.0          # an exactly-zero block
    dtype = draw(st.sampled_from([np.float32, jnp.bfloat16]))
    return x, dtype


class TestQuantizationErrorBoundProperty:
    @given(_quant_inputs())
    @settings(max_examples=60, deadline=None)
    def test_per_element_error_bound(self, case):
        """dequantize(quantize(x)) honors the PER-ELEMENT bound
        max|block| / 254 for every element of every block — across padding
        shapes, all-zero blocks, and bf16/f32 inputs."""
        x, dtype = case
        xj = jnp.asarray(x).astype(dtype)
        xf = np.asarray(xj, np.float32)       # what quantize actually sees
        q, s, pad = quantize_int8(xj, block=64)
        y = np.asarray(dequantize_int8(q, s, pad, xj.shape, dtype=jnp.float32))
        flat_x = np.concatenate([xf.reshape(-1),
                                 np.zeros(pad, np.float32)]).reshape(-1, 64)
        flat_y = np.concatenate([y.reshape(-1),
                                 np.zeros(pad, np.float32)]).reshape(-1, 64)
        bound = np.max(np.abs(flat_x), axis=1, keepdims=True) / 254.0
        # the bound is exact in real arithmetic; f32 division can land an
        # element a half-ULP past the rounding midpoint, hence the 1e-5
        # relative slack
        assert (np.abs(flat_y - flat_x) <= bound * (1 + 1e-5) + 1e-6).all()

    @given(_quant_inputs())
    @settings(max_examples=30, deadline=None)
    def test_zero_blocks_roundtrip_exactly(self, case):
        x, dtype = case
        xj = jnp.asarray(x).astype(dtype)
        q, s, pad = quantize_int8(xj, block=64)
        y = np.asarray(dequantize_int8(q, s, pad, xj.shape))
        zero_in = np.asarray(xj, np.float32) == 0.0
        np.testing.assert_array_equal(y[zero_in], 0.0)


class TestRopeProperties:
    @given(st.integers(0, 500), st.integers(0, 500), st.integers(0, 40))
    @settings(max_examples=25, deadline=None)
    def test_rope_is_relative(self, p1, p2, delta):
        """<rope(q, p1+d), rope(k, p2+d)> == <rope(q, p1), rope(k, p2)> —
        the dot product depends only on the position difference."""
        rng = np.random.default_rng(42)
        q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
        d1 = float(jnp.sum(rope(q, jnp.array([p1]), 1e4) *
                           rope(k, jnp.array([p2]), 1e4)))
        d2 = float(jnp.sum(rope(q, jnp.array([p1 + delta]), 1e4) *
                           rope(k, jnp.array([p2 + delta]), 1e4)))
        assert abs(d1 - d2) < 1e-3 * (abs(d1) + 1)

    def test_rope_preserves_norm(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((2, 8, 4, 64)), jnp.float32)
        out = rope(q, jnp.arange(8), 1e4)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(out)),
                                   np.linalg.norm(np.asarray(q)), rtol=1e-5)


class TestSoftmaxXentInvariants:
    @given(hnp.arrays(np.float32, (4, 16),
                      elements=st.floats(-30, 30, width=32)))
    @settings(max_examples=30, deadline=None)
    def test_xent_shift_invariance(self, logits):
        """loss(logits + c) == loss(logits) — the model's loss must be
        invariant to logit shifts (logsumexp formulation)."""
        from repro.models.model import _xent
        labels = jnp.asarray(np.arange(4) % 16, jnp.int32)
        l1 = _xent(jnp.asarray(logits), labels)
        l2 = _xent(jnp.asarray(logits) + 7.5, labels)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-4, atol=1e-4)

    @given(st.integers(0, 15))
    @settings(max_examples=15, deadline=None)
    def test_xent_nonnegative_and_exact_for_onehot(self, label):
        from repro.models.model import _xent
        logits = jnp.full((1, 16), -30.0).at[0, label].set(30.0)
        l = float(_xent(logits, jnp.asarray([label], jnp.int32))[0])
        assert 0 <= l < 1e-6
