"""Train-step / serve-step contracts and the end-to-end loop with
checkpoint/restart determinism."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig
from repro.launch import steps
from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train.loop import TrainConfig, make_train_state, train

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                   dtype="float32")


def _batch(b=4, s=16, vocab=128, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, vocab, (b, s)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, vocab, (b, s)), jnp.int32)}


class TestTrainStep:
    def test_microbatch_equivalence(self):
        """num_microbatches=1 vs 4 must produce (near-)identical updates."""
        params, opt = make_train_state(TINY)
        batch = _batch(8)
        p1, o1, m1 = steps.train_step(params, opt, batch, cfg=TINY,
                                      opt_cfg=adamw.OptConfig(),
                                      num_microbatches=1)
        p4, o4, m4 = steps.train_step(params, opt, batch, cfg=TINY,
                                      opt_cfg=adamw.OptConfig(),
                                      num_microbatches=4)
        assert m1["loss"] == pytest.approx(float(m4["loss"]), rel=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)

    def test_loss_decreases_over_steps(self):
        params, opt = make_train_state(TINY)
        batch = _batch(8)                       # overfit one batch
        jfn = jax.jit(lambda p, o, b: steps.train_step(
            p, o, b, cfg=TINY,
            opt_cfg=adamw.OptConfig(peak_lr=1e-2, warmup_steps=1)))
        losses = []
        for _ in range(20):
            params, opt, m = jfn(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5

    @pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-2.7b",
                                      "zamba2-7b", "dbrx-132b",
                                      "seamless-m4t-large-v2"])
    def test_cache_sds_matches_prefill_structure(self, arch):
        """cache_sds must predict prefill's cache pytree exactly (this is the
        contract the decode dry-run relies on)."""
        cfg = configs.get_smoke(arch)
        max_len = 48
        key = jax.random.PRNGKey(0)
        pspecs = nn.unwrap(M.init_lm_shapes(key, cfg))
        batch = steps.batch_sds(
            cfg, configs.ShapeSpec("t", "prefill", 32, 2), with_labels=False)
        _, cache_shapes = jax.eval_shape(
            lambda p, b: M.prefill(p, b, cfg, max_len=max_len), pspecs, batch)
        predicted = steps.cache_sds(cfg, 2, max_len)
        got = jax.tree.map(lambda x: (x.shape, str(x.dtype)), cache_shapes)
        want = jax.tree.map(lambda x: (x.shape, str(x.dtype)), predicted)
        assert jax.tree.structure(got) == jax.tree.structure(want)
        mism = [(a, b) for a, b in zip(jax.tree.leaves(got),
                                       jax.tree.leaves(want)) if a != b]
        assert not mism, mism


class TestServeStep:
    def test_serve_step_shapes(self):
        cfg = TINY
        params, _ = make_train_state(cfg)
        caches = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            steps.cache_sds(cfg, 2, 32))
        logits, new_caches = steps.serve_step(
            params, caches, jnp.zeros((2,), jnp.int32), cfg=cfg)
        assert logits.shape == (2, cfg.vocab)
        assert new_caches["k"].shape == caches["k"].shape
        assert int(new_caches["len"][0]) == 1


class TestTrainLoopFT:
    def test_restart_bit_exact(self, tmp_path):
        """Interrupted + resumed training must equal uninterrupted training
        (checkpoint + stateless data pipeline => bit-exact restart)."""
        dcfg = DataConfig(global_batch=4, seq_len=16, vocab=128, seed=9)
        ocfg = adamw.OptConfig(peak_lr=1e-3, warmup_steps=2)

        t_all = TrainConfig(total_steps=8, ckpt_every=100, log_every=100,
                            ckpt_dir=str(tmp_path / "a"), async_ckpt=False)
        run_a = train(TINY, dcfg, t_all, ocfg)

        t_half = dataclasses.replace(t_all, total_steps=4, ckpt_every=4,
                                     ckpt_dir=str(tmp_path / "b"))
        train(TINY, dcfg, t_half, ocfg)
        t_resume = dataclasses.replace(t_half, total_steps=8)
        run_b = train(TINY, dcfg, t_resume, ocfg)   # resumes from step 4

        np.testing.assert_allclose(run_a["final_loss"], run_b["final_loss"],
                                   rtol=1e-6)
        for a, b in zip(jax.tree.leaves(run_a["params"]),
                        jax.tree.leaves(run_b["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)

    def test_loss_goes_down(self, tmp_path):
        dcfg = DataConfig(global_batch=4, seq_len=32, vocab=128)
        tcfg = TrainConfig(total_steps=30, ckpt_every=100, log_every=100,
                           ckpt_dir=str(tmp_path / "c"), async_ckpt=False)
        res = train(TINY, dcfg, tcfg,
                    adamw.OptConfig(peak_lr=3e-3, warmup_steps=5))
        assert res["history"][-1]["loss"] < res["history"][0]["loss"]
