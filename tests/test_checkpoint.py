"""CheckpointManager: overlapped async saves (donated-safe), integrity
verification with corrupt-fallback, and GC that never strands the directory
without a restorable checkpoint."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.ft.chaos import corrupt_checkpoint_dir


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(16, 8)),
                                        jnp.float32),
                       "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)},
            "opt": {"mu": jnp.zeros((16, 8)), "count": jnp.asarray(seed)}}


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestAsyncSave:
    def test_async_save_restores_identically_to_blocking(self, tmp_path):
        t = _tree(1)
        ba = CheckpointManager(str(tmp_path / "a"))
        ba.save(5, t, blocking=True)
        bb = CheckpointManager(str(tmp_path / "b"))
        bb.save(5, t, blocking=False)
        bb.wait()
        sa, ra = ba.restore_latest(_tree())
        sb, rb = bb.restore_latest(_tree())
        assert sa == sb == 5
        _assert_tree_equal(ra, rb)

    def test_async_save_survives_donation_of_originals(self, tmp_path):
        """The train step donates (params, opt) buffers to jit; the snapshot
        must own fresh copies, so deleting the originals right after save()
        returns — the worst-case donation — must not corrupt the write."""
        mgr = CheckpointManager(str(tmp_path))
        t = _tree(2)
        expect = jax.tree.map(lambda x: np.asarray(x), t)
        mgr.save(3, t, blocking=False)
        for leaf in jax.tree.leaves(t):
            leaf.delete()                  # donation invalidates the buffer
        mgr.wait()
        assert mgr.verify(3)
        _, restored = mgr.restore_latest(_tree())
        _assert_tree_equal(restored, expect)

    def test_save_returns_caller_blocked_seconds(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        blocked = mgr.save(1, _tree(), blocking=False)
        assert blocked >= 0.0
        mgr.wait()
        assert mgr.verify(1)

    def test_back_to_back_async_saves_serialize(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=10)
        for s in range(1, 5):
            mgr.save(s, _tree(s), blocking=False)
        mgr.wait()
        assert mgr.all_steps() == [1, 2, 3, 4]
        assert all(mgr.verify(s) for s in range(1, 5))


class TestRestoreFallback:
    @pytest.mark.parametrize("mode", ["truncate", "bitflip", "manifest"])
    def test_corrupt_newest_falls_back_to_previous(self, tmp_path, mode):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        mgr.save(1, _tree(1))
        mgr.save(2, _tree(2))
        corrupt_checkpoint_dir(str(tmp_path / "step_00000002"), mode)
        assert not mgr.verify(2)
        seen = []
        step, restored = mgr.restore_latest(_tree(), on_corrupt=seen.append)
        assert step == 1 and seen == [2]
        _assert_tree_equal(restored, _tree(1))

    def test_all_corrupt_returns_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        mgr.save(1, _tree(1))
        corrupt_checkpoint_dir(str(tmp_path / "step_00000001"), "truncate")
        seen = []
        step, restored = mgr.restore_latest(_tree(), on_corrupt=seen.append)
        assert (step, restored) == (None, None) and seen == [1]

    def test_latest_pointing_at_deleted_dir_falls_back(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        mgr.save(1, _tree(1))
        mgr.save(2, _tree(2))
        shutil.rmtree(tmp_path / "step_00000002")   # LATEST now dangles
        assert mgr.latest_step() == 1
        step, restored = mgr.restore_latest(_tree())
        assert step == 1
        _assert_tree_equal(restored, _tree(1))

    def test_stray_files_do_not_break_step_listing(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        mgr.save(1, _tree())
        (tmp_path / "step_junk").mkdir()            # racing writer debris
        (tmp_path / "step_00000002.tmp").mkdir()
        assert mgr.all_steps() == [1]
        assert mgr.latest_step() == 1

    def test_restore_missing_leaf_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"a": jnp.zeros(3)})
        with pytest.raises(KeyError, match="missing leaf"):
            mgr.restore(1, {"a": jnp.zeros(3), "b": jnp.zeros(3)})


class TestGC:
    def test_gc_prunes_old_steps(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(s))
        assert mgr.all_steps() == [3, 4]

    def test_gc_never_deletes_the_only_verified_checkpoint(self, tmp_path):
        """If every kept (newest) step is corrupt, GC must retain the newest
        verified older step — never leave the directory unrestorable."""
        mgr = CheckpointManager(str(tmp_path), keep=1)
        mgr.save(1, _tree(1))
        mgr.save(2, _tree(2))              # gc pass 1: keeps {1 verified, 2}
        assert mgr.all_steps() == [2]
        # rebuild the history: 2 good, then 3 lands corrupt on disk
        mgr.keep = 2
        mgr.save(3, _tree(3))
        corrupt_checkpoint_dir(str(tmp_path / "step_00000003"), "truncate")
        mgr.keep = 1
        mgr._gc()                          # doomed=[2], kept=[3] unverifiable
        assert 2 in mgr.all_steps()        # the only verified step survived
        step, _ = mgr.restore_latest(_tree())
        assert step == 2

    def test_gc_normal_path_unaffected_by_verified_keeps(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3):
            mgr.save(s, _tree(s))
        assert mgr.all_steps() == [2, 3]   # newest kept verifies; 1 pruned
