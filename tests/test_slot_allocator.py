"""Property-based tests (hypothesis; runs on the vendored stub too) for the
continuous-batching slot allocator: no slot aliasing, FIFO admission under
full occupancy, and liveness — every admitted request completes."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.slots import SlotPool


class TestInvariants:
    @given(st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_no_slot_aliasing(self, capacity, seed):
        """Across a random submit/admit/release schedule, a slot is held by
        at most one item, in range, and never re-issued before release."""
        rng = np.random.default_rng(seed)
        pool = SlotPool(capacity)
        held: dict[int, int] = {}          # shadow model: slot -> item
        next_item = 0
        for _ in range(40):
            op = rng.integers(3)
            if op == 0:
                pool.submit(next_item)
                next_item += 1
            elif op == 1:
                for slot, item in pool.admit():
                    assert 0 <= slot < capacity
                    assert slot not in held, "slot issued twice"
                    held[slot] = item
            elif op == 2 and held:
                slot = int(rng.choice(sorted(held)))
                assert pool.release(slot) == held.pop(slot)
            assert pool.occupancy == len(held) <= capacity

    @given(st.integers(1, 6), st.integers(1, 20), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_fifo_admission_under_full_occupancy(self, capacity, extra, seed):
        """Fill every slot, queue ``extra`` more, then release in random
        order: admissions must still come strictly in submit order."""
        rng = np.random.default_rng(seed)
        pool = SlotPool(capacity)
        for i in range(capacity + extra):
            pool.submit(i)
        admitted = [item for _, item in pool.admit()]
        assert admitted == list(range(capacity))       # full occupancy
        assert pool.admit() == []                      # nothing free
        while pool.queue_depth or pool.occupancy:
            occupied = [s for s, _ in pool.held()]
            if occupied:
                pool.release(int(rng.choice(occupied)))
            admitted += [item for _, item in pool.admit()]
        assert admitted == list(range(capacity + extra))

    @given(st.integers(1, 6), st.lists(st.integers(1, 9), min_size=1,
                                       max_size=24),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_every_admitted_request_completes(self, capacity, durations,
                                              seed):
        """Engine-shaped simulation: admit, decrement each held item's
        remaining budget per step, release at zero.  Terminates with every
        submitted item admitted exactly once and completed."""
        del seed
        pool = SlotPool(capacity)
        remaining = dict(enumerate(durations))
        for uid in remaining:
            pool.submit(uid)
        admitted, completed = [], []
        for _ in range(sum(durations) + len(durations) + 1):
            if pool.idle:
                break
            admitted += [item for _, item in pool.admit()]
            for slot, uid in list(pool.held()):
                remaining[uid] -= 1
                if remaining[uid] == 0:
                    pool.release(slot)
                    completed.append(uid)
        assert pool.idle, "simulation did not drain"
        assert sorted(admitted) == sorted(completed) == list(remaining)
        assert admitted == list(remaining)             # FIFO admission too


class TestApi:
    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError, match="capacity"):
            SlotPool(0)

    def test_release_unheld_raises(self):
        pool = SlotPool(2)
        with pytest.raises(KeyError):
            pool.release(0)

    def test_lowest_slot_first(self):
        pool = SlotPool(3)
        for i in range(3):
            pool.submit(i)
        assert [s for s, _ in pool.admit()] == [0, 1, 2]
        pool.release(1)
        pool.submit(3)
        assert pool.admit() == [(1, 3)]
