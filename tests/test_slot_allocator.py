"""Property-based tests (hypothesis; runs on the vendored stub too) for the
continuous-batching slot allocator: no slot aliasing, FIFO admission under
full occupancy, and liveness — every admitted request completes."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.slots import SlotPool


class TestInvariants:
    @given(st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_no_slot_aliasing(self, capacity, seed):
        """Across a random submit/admit/release schedule, a slot is held by
        at most one item, in range, and never re-issued before release."""
        rng = np.random.default_rng(seed)
        pool = SlotPool(capacity)
        held: dict[int, int] = {}          # shadow model: slot -> item
        next_item = 0
        for _ in range(40):
            op = rng.integers(3)
            if op == 0:
                pool.submit(next_item)
                next_item += 1
            elif op == 1:
                for slot, item in pool.admit():
                    assert 0 <= slot < capacity
                    assert slot not in held, "slot issued twice"
                    held[slot] = item
            elif op == 2 and held:
                slot = int(rng.choice(sorted(held)))
                assert pool.release(slot) == held.pop(slot)
            assert pool.occupancy == len(held) <= capacity

    @given(st.integers(1, 6), st.integers(1, 20), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_fifo_admission_under_full_occupancy(self, capacity, extra, seed):
        """Fill every slot, queue ``extra`` more, then release in random
        order: admissions must still come strictly in submit order."""
        rng = np.random.default_rng(seed)
        pool = SlotPool(capacity)
        for i in range(capacity + extra):
            pool.submit(i)
        admitted = [item for _, item in pool.admit()]
        assert admitted == list(range(capacity))       # full occupancy
        assert pool.admit() == []                      # nothing free
        while pool.queue_depth or pool.occupancy:
            occupied = [s for s, _ in pool.held()]
            if occupied:
                pool.release(int(rng.choice(occupied)))
            admitted += [item for _, item in pool.admit()]
        assert admitted == list(range(capacity + extra))

    @given(st.integers(1, 6), st.lists(st.integers(1, 9), min_size=1,
                                       max_size=24),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_every_admitted_request_completes(self, capacity, durations,
                                              seed):
        """Engine-shaped simulation: admit, decrement each held item's
        remaining budget per step, release at zero.  Terminates with every
        submitted item admitted exactly once and completed."""
        del seed
        pool = SlotPool(capacity)
        remaining = dict(enumerate(durations))
        for uid in remaining:
            pool.submit(uid)
        admitted, completed = [], []
        for _ in range(sum(durations) + len(durations) + 1):
            if pool.idle:
                break
            admitted += [item for _, item in pool.admit()]
            for slot, uid in list(pool.held()):
                remaining[uid] -= 1
                if remaining[uid] == 0:
                    pool.release(slot)
                    completed.append(uid)
        assert pool.idle, "simulation did not drain"
        assert sorted(admitted) == sorted(completed) == list(remaining)
        assert admitted == list(remaining)             # FIFO admission too


class TestApi:
    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError, match="capacity"):
            SlotPool(0)

    def test_release_unheld_raises(self):
        pool = SlotPool(2)
        with pytest.raises(KeyError):
            pool.release(0)

    def test_lowest_slot_first(self):
        pool = SlotPool(3)
        for i in range(3):
            pool.submit(i)
        assert [s for s, _ in pool.admit()] == [0, 1, 2]
        pool.release(1)
        pool.submit(3)
        assert pool.admit() == [(1, 3)]


# ===================================================== paged KV cache pool
from repro.serve.pages import PagePool, PrefixCache  # noqa: E402


class TestPagePool:
    @given(st.integers(2, 24), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_no_two_writers_alias_a_page(self, num_pages, seed):
        """Across a random alloc/retain/release/cow schedule, a writable
        (refcount-1) page is owned by exactly one allocation, every page id
        is issued to at most one live *writer*, and the reserved trash page
        is never handed out."""
        rng = np.random.default_rng(seed)
        pool = PagePool(num_pages, page_size=4)
        refs: dict[int, int] = {}          # shadow model: page -> refcount
        for _ in range(60):
            op = rng.integers(4)
            if op == 0:
                n = int(rng.integers(1, 4))
                got = pool.alloc(n)
                if got is None:
                    assert pool.free_pages < n
                else:
                    assert len(got) == n == len(set(got))
                    for p in got:
                        assert p != 0, "trash page allocated"
                        assert p not in refs, "free-list re-issued a live page"
                        refs[p] = 1
            elif op == 1 and refs:
                p = int(rng.choice(sorted(refs)))
                pool.retain(p)
                refs[p] += 1
            elif op == 2 and refs:
                p = int(rng.choice(sorted(refs)))
                pool.release(p)
                refs[p] -= 1
                if refs[p] == 0:
                    del refs[p]
            elif op == 3 and refs:
                p = int(rng.choice(sorted(refs)))
                fresh = pool.cow(p)
                if fresh is not None:
                    # alloc precedes the ref move, so the fresh page is
                    # always a different live-free page, never the trash
                    assert fresh not in refs and fresh != 0 and fresh != p
                    refs[p] -= 1
                    if refs[p] == 0:
                        del refs[p]
                    refs[fresh] = 1
            for p, r in refs.items():
                assert pool.refcount(p) == r
                assert pool.writable(p) == (r == 1)
            assert pool.used_pages == len(refs)
            assert pool.free_pages == pool.usable_pages - len(refs)

    @given(st.integers(2, 16), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_refcount_zero_exactly_at_eviction(self, num_pages, seed):
        """A page returns to the free list exactly when its last reference
        is released — never before (shared release frees nothing) and never
        without it (no leaks once all refs are gone)."""
        rng = np.random.default_rng(seed)
        pool = PagePool(num_pages, page_size=4)
        live: dict[int, int] = {}
        for _ in range(50):
            if rng.integers(2) == 0:
                got = pool.alloc(1)
                if got is not None:
                    live[got[0]] = 1
                    extra = int(rng.integers(0, 3))
                    for _ in range(extra):
                        pool.retain(got[0])
                    live[got[0]] += extra
            elif live:
                p = int(rng.choice(sorted(live)))
                before = pool.free_pages
                freed = pool.release(p)
                live[p] -= 1
                if live[p] == 0:
                    assert freed == 1 and pool.free_pages == before + 1
                    del live[p]
                else:
                    assert freed == 0 and pool.free_pages == before
        # drain: every page must come back exactly once
        for p, r in list(live.items()):
            for i in range(r):
                assert pool.release(p) == (1 if i == r - 1 else 0)
        assert pool.free_pages == pool.usable_pages

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_free_list_never_double_frees(self, seed):
        """Releasing a page past refcount zero raises instead of corrupting
        the free list, and the free list never holds duplicates."""
        rng = np.random.default_rng(seed)
        pool = PagePool(8, page_size=4)
        pages = pool.alloc(int(rng.integers(1, 7)))
        pool.release(pages)
        for p in pages:
            with pytest.raises(ValueError):
                pool.release(p)
        assert len(pool._free) == len(set(pool._free))
        assert pool.free_pages == pool.usable_pages

    def test_alloc_is_all_or_nothing(self):
        pool = PagePool(4, page_size=4)      # 3 usable
        assert pool.alloc(4) is None
        assert pool.free_pages == 3          # nothing leaked by the failure
        got = pool.alloc(3)
        assert sorted(got) == [1, 2, 3]
        assert pool.alloc(1) is None

    def test_reserved_trash_page_is_untouchable(self):
        pool = PagePool(4, page_size=4)
        for fn in (pool.retain, pool.release):
            with pytest.raises(ValueError):
                fn(0)
        with pytest.raises(ValueError):
            pool.retain(4)                   # out of range too


class TestPrefixCache:
    def _pool(self):
        return PagePool(32, page_size=4)

    def test_lookup_roundtrip_retains_for_caller(self):
        pool = self._pool()
        cache = PrefixCache(pool)
        toks = np.arange(13, dtype=np.int32)          # 3 shareable blocks
        pages = pool.alloc(4)
        cache.insert(toks, pages[:3])
        hit = cache.lookup(toks)
        assert hit == pages[:3]
        assert all(pool.refcount(p) == 3 for p in hit)  # us + cache + lookup
        # a diverging prompt matches only the common chain
        other = np.concatenate([toks[:8], [99, 99, 99, 99, 0]]).astype(np.int32)
        assert cache.lookup(other) == pages[:2]

    def test_tail_token_never_shared(self):
        """Exactly page-aligned prompts share all but their final page: the
        admitting request must always compute its first-token logits."""
        pool = self._pool()
        cache = PrefixCache(pool)
        toks = np.arange(8, dtype=np.int32)           # 2 pages, 1 shareable
        assert len(cache._keys(toks)) == 1
        assert len(cache._keys(toks[:5])) == 1
        assert len(cache._keys(toks[:4])) == 0

    def test_evict_frees_exclusive_entries_first(self):
        pool = self._pool()
        cache = PrefixCache(pool)
        a = pool.alloc(1)[0]
        b = pool.alloc(1)[0]
        cache.insert(np.arange(5, dtype=np.int32), [a])
        cache.insert(np.arange(50, 55, dtype=np.int32), [b])
        pool.release(a)
        pool.release(b)
        pool.retain(b)                    # b now shared with a "slot"
        assert cache.evictable_pages == 1
        freed = cache.evict(1)
        assert freed == 1
        assert pool.refcount(b) == 2      # shared entry survived
        assert len(cache) == 1

    def test_evict_keeps_shared_entries_when_demand_exceeds(self):
        """Asking for more pages than are reclaimable stops at the shared
        entries instead of stripping the whole cache: releasing a page a
        live slot still references frees nothing, so popping those entries
        would wipe all prefix-sharing state while reclaiming zero pages."""
        pool = self._pool()
        cache = PrefixCache(pool)
        pages = pool.alloc(3)
        for i, p in enumerate(pages):
            cache.insert(np.arange(i * 100, i * 100 + 5, dtype=np.int32), [p])
            pool.release(p)               # cache holds the only ref ...
        pool.retain(pages[1])             # ... except these two, shared
        pool.retain(pages[2])             # with in-flight "slots"
        freed = cache.evict(3)            # only 1 page is reclaimable
        assert freed == 1
        assert len(cache) == 2            # shared entries survive
        assert pool.refcount(pages[1]) == 2
        assert pool.refcount(pages[2]) == 2
        assert cache.evict(1) == 0        # and stay until their slot ends
        assert len(cache) == 2
        pool.release(pages[1])            # slot finished: now reclaimable
        assert cache.evict(1) == 1
        assert len(cache) == 1

    def test_insert_requires_enough_pages(self):
        cache = PrefixCache(self._pool())
        with pytest.raises(ValueError, match="blocks"):
            cache.insert(np.arange(13, dtype=np.int32), [1])
