"""Dry-run machinery tests that don't need 512 devices: collective parsing,
cost extrapolation arithmetic, cell enumeration, and a REAL single-cell
lower+compile in a 512-device subprocess (slow, exercised fully by
`python -m repro.launch.dryrun --all`)."""

import json
import os
import subprocess
import sys

import pytest


class TestCollectiveParsing:
    def _parse(self, text):
        # import inside: repro.launch.dryrun sets XLA_FLAGS at import, which
        # is harmless here (jax is already initialized by other tests)
        from repro.launch import dryrun
        return dryrun.parse_collectives(text)

    def test_basic_ops(self):
        hlo = """
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(%x), replica_groups={}
  %all-gather.2 = bf16[64,128]{1,0} all-gather(%y), dimensions={0}
  %reduce-scatter.3 = f32[32]{0} reduce-scatter(%z), dimensions={0}
  %collective-permute.4 = bf16[16,16]{1,0} collective-permute(%w)
  %add.5 = f32[4]{0} add(%a, %b)
"""
        c = self._parse(hlo)
        assert c["all-reduce"] == 2.0 * 1024 * 512 * 4       # weight 2x
        assert c["all-gather"] == 64 * 128 * 2
        assert c["reduce-scatter"] == 32 * 4
        assert c["collective-permute"] == 16 * 16 * 2
        assert c["total"] == sum(c[k] for k in
                                 ("all-reduce", "all-gather", "reduce-scatter",
                                  "all-to-all", "collective-permute"))

    def test_async_start_done_counted_once(self):
        hlo = """
  %all-gather-start.1 = bf16[8,8]{1,0} all-gather-start(%x)
  %all-gather-done.1 = bf16[8,8]{1,0} all-gather-done(%all-gather-start.1)
"""
        c = self._parse(hlo)
        assert c["all-gather"] == 8 * 8 * 2

    def test_tuple_shapes(self):
        hlo = "  %all-reduce.9 = (f32[10]{0}, f32[20]{0}) all-reduce(%a, %b)\n"
        c = self._parse(hlo)
        assert c["all-reduce"] == 2.0 * (10 + 20) * 4

    def test_non_collectives_ignored(self):
        c = self._parse("  %fusion.1 = f32[100]{0} fusion(%x), kind=kLoop\n")
        assert c["total"] == 0


class TestProbeExtrapolation:
    def test_probe_cfg_families(self):
        from repro import configs
        from repro.launch.dryrun import probe_cfg
        c1, units = probe_cfg(configs.get("qwen3-1.7b"), 1)
        assert c1.n_layers == 1 and units == 28 and not c1.scan_layers
        ch, uh = probe_cfg(configs.get("zamba2-7b"), 2)
        assert ch.n_layers == 12 and uh == pytest.approx(81 / 6)
        ce, ue = probe_cfg(configs.get("seamless-m4t-large-v2"), 2)
        assert (ce.enc_layers, ce.dec_layers, ue) == (2, 2, 24)

    def test_linear_extrapolation_math(self):
        # cost(L) = c1 + (L-1)(c2-c1): exact for layered costs a + L*b
        a, b, L = 7.0, 3.0, 40
        c1, c2 = a + b, a + 2 * b
        assert c1 + (L - 1) * (c2 - c1) == a + L * b


class TestCells:
    def test_40_cells(self):
        from repro import configs
        cells = list(configs.cells())
        assert len(cells) == 40
        runnable = [c for c in cells if c[3]]
        skipped = [c for c in cells if not c[3]]
        assert len(skipped) == 7          # 7 full-attn archs skip long_500k
        assert all(s.name == "long_500k" for _, _, s, ok, _ in skipped)
        assert len(runnable) == 33


@pytest.mark.slow
class TestRealDryRunCell:
    def test_one_cell_compiles_on_512_devices(self, tmp_path):
        """Full fidelity: run one real dry-run cell in a subprocess."""
        out = str(tmp_path / "r.json")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "h2o-danube-1.8b", "--shape", "long_500k", "--mesh", "multi",
             "--out", out],
            env=env, capture_output=True, text=True, timeout=1200)
        assert r.returncode == 0, r.stderr[-2000:]
        rec = json.load(open(out))["h2o-danube-1.8b|long_500k|multi"]
        assert rec["status"] == "ok", rec
        assert rec["chips"] == 512
        assert rec["flops_per_device"] > 0
