"""Crash-safe tuning: the search-state journal, killed-and-resumed session
convergence (resumed cache == uninterrupted cache, byte-identical), the
per-candidate deadline/quarantine wrapper, and keep-going error tolerance."""

import json
import threading
import time

import pytest

from repro import kernels
from repro.core import ScheduleCache, TuneConfig, registry, workload_seed
from repro.core.energy import FAILED, QuarantineEnergy
from repro.core.registry import KernelRegistry, Workload
from repro.core.schedule import Schedule
from repro.tuning import (SearchState, SimulatedCrash, TuningSession,
                          state_path_for)

kernels.load_all()

GEMM = "gemm_fused_leaky_relu"
RMS = "rmsnorm_fused"
QUICK = TuneConfig(rounds=1, t_min=0.3, cooling=1.3, step_samples=1,
                   final_samples=4)


class TestSearchState:
    def test_roundtrip(self, tmp_path):
        p = str(tmp_path / "s.state.json")
        st = SearchState(path=p, fingerprint={"suite": "smoke"})
        st.mark_in_progress("k", "w", "sig0")
        st.mark_completed("k", "w", signature="sig0", seed=7, best_energy=1.5)
        st.mark_in_progress("k", "w2", "sig2")
        st.save_quarantine("k", "w2", {"bad1", "bad2"})
        st2 = SearchState.load(p)
        assert st2.fingerprint == {"suite": "smoke"}
        assert st2.completed_keys() == {("k", "w")}
        assert st2.stale_in_progress("k", "w2") == {
            "kernel": "k", "workload": "w2", "signature": "sig2"}
        assert st2.stale_in_progress("k", "other") is None
        assert st2.quarantine_for("k", "w2") == {"bad1", "bad2"}
        assert st2.quarantine_for("k", "w") == set()

    def test_mark_failed_clears_in_progress(self, tmp_path):
        p = str(tmp_path / "s.state.json")
        st = SearchState(path=p)
        st.mark_in_progress("k", "w", "sig")
        st.mark_failed("k", "w", "boom")
        st2 = SearchState.load(p)
        assert st2.in_progress is None
        assert st2.failed == [{"kernel": "k", "workload": "w",
                               "error": "boom"}]

    def test_unreadable_or_wrong_version_loads_none(self, tmp_path):
        missing = SearchState.load(str(tmp_path / "nope.json"))
        assert missing is None
        garbled = tmp_path / "bad.json"
        garbled.write_text("{not json")
        assert SearchState.load(str(garbled)) is None
        old = tmp_path / "old.json"
        old.write_text(json.dumps({"version": -1}))
        assert SearchState.load(str(old)) is None

    def test_default_path_sits_next_to_cache(self):
        assert state_path_for("/x/cache.json") == "/x/cache.json.state.json"


class TestQuarantineEnergy:
    def test_crash_is_quarantined_and_skipped(self):
        calls = []

        def bomb(s):
            calls.append(s)
            raise RuntimeError("segfault stand-in")

        seen = []
        q = QuarantineEnergy(bomb, on_quarantine=lambda sig, msg:
                             seen.append((sig, msg)))
        s = Schedule()
        assert q(s) == FAILED
        assert q(s) == FAILED              # second call answered from the list
        assert len(calls) == 1
        assert q.quarantine_stats() == {"timeouts": 0, "crashes": 1,
                                        "skips": 1, "quarantined": 1}
        assert seen[0][0] == s.signature()
        assert "segfault stand-in" in seen[0][1]

    def test_deadline_times_out_wedged_evaluation(self):
        release = threading.Event()

        def wedged(s):
            release.wait(5.0)              # simulates a hung compile
            return 1.0

        q = QuarantineEnergy(wedged, deadline_s=0.1)
        t0 = time.perf_counter()
        assert q(Schedule()) == FAILED
        assert time.perf_counter() - t0 < 2.0
        assert q.quarantine_stats()["timeouts"] == 1
        release.set()

    def test_fresh_worker_after_timeout(self):
        """One wedged schedule costs one deadline, not the session: the
        next evaluation runs on a fresh worker and succeeds."""
        bad = Schedule(knobs={"wedge": True})
        ok = Schedule(knobs={"wedge": False})
        assert bad.signature() != ok.signature()

        def energy(s):
            if s.signature() == bad.signature():
                time.sleep(5.0)
            return 0.25

        q = QuarantineEnergy(energy, deadline_s=0.1)
        assert q(bad) == FAILED
        assert q(ok) == 0.25
        assert q.quarantine_stats() == {"timeouts": 1, "crashes": 0,
                                        "skips": 0, "quarantined": 1}

    def test_passthrough_without_deadline(self):
        q = QuarantineEnergy(lambda s: 0.5)
        assert q(Schedule()) == 0.5
        assert q._pool is None             # no thread machinery engaged

    def test_caller_owned_quarantine_preloads_skips(self):
        s = Schedule()
        q = QuarantineEnergy(lambda s: 0.5, quarantine={s.signature()})
        assert q(s) == FAILED
        assert q.quarantine_stats()["skips"] == 1

    def test_invalid_deadline_rejected(self):
        with pytest.raises(ValueError, match="deadline_s"):
            QuarantineEnergy(lambda s: 0.5, deadline_s=0.0)
        with pytest.raises(ValueError, match="eval_deadline_s"):
            TuneConfig(eval_deadline_s=-1.0).validate()


def _cache_bytes(path):
    return (json.dumps(json.loads(path.read_text()), sort_keys=True)
            if path.exists() else None)


class TestSessionResume:
    def test_killed_then_resumed_equals_uninterrupted(self, tmp_path):
        """THE crash-safe acceptance gate: a session killed mid-journal
        (entries written, completion not recorded) and resumed must produce
        a byte-identical ScheduleCache to an uninterrupted session."""
        base = tmp_path / "base.json"
        TuningSession(cache=str(base), config=QUICK,
                      state=str(tmp_path / "base.state.json")).run(
            kernels=[GEMM, RMS], suite="smoke")

        crashy = tmp_path / "crashy.json"
        state = str(tmp_path / "crashy.state.json")
        with pytest.raises(SimulatedCrash):
            TuningSession(cache=str(crashy), config=QUICK, state=state,
                          die_after=1).run(kernels=[GEMM, RMS], suite="smoke")
        # torn state on disk: first workload's entries written, journal
        # still says in_progress
        st = SearchState.load(state)
        assert st.in_progress is not None
        assert st.completed == []

        resumed = TuningSession(cache=str(crashy), config=QUICK,
                                state=state).run(kernels=[GEMM, RMS],
                                                 suite="smoke", resume=True)
        assert len(resumed) == 2           # purge + rerun first, then second
        assert _cache_bytes(crashy) == _cache_bytes(base)
        st = SearchState.load(state)
        assert st.in_progress is None
        assert st.completed_keys() == {(GEMM, r.workload) if r.kernel == GEMM
                                       else (RMS, r.workload)
                                       for r in resumed}

    def test_resume_skips_completed_workloads(self, tmp_path):
        cache = tmp_path / "c.json"
        state = str(tmp_path / "c.state.json")
        first = TuningSession(cache=str(cache), config=QUICK,
                              state=state).run(kernels=[RMS], suite="smoke")
        assert len(first) == 1
        again = TuningSession(cache=str(cache), config=QUICK,
                              state=state).run(kernels=[RMS], suite="smoke",
                                               resume=True)
        assert again == []                 # nothing left to do

    def test_fingerprint_mismatch_warns_and_restarts(self, tmp_path):
        cache = tmp_path / "c.json"
        state = str(tmp_path / "c.state.json")
        TuningSession(cache=str(cache), config=QUICK, state=state).run(
            kernels=[RMS], suite="smoke")
        other = TuningSession(cache=str(cache),
                              config=QUICK, state=state)
        with pytest.warns(RuntimeWarning, match="fingerprint"):
            rerun = other.run(kernels=[GEMM], suite="smoke", resume=True)
        assert len(rerun) == 1 and rerun[0].kernel == GEMM

    def test_stale_in_progress_purges_partial_entries(self, tmp_path):
        """Partial cache rounds of the workload that was in flight when the
        session died must be dropped before the re-run (otherwise the
        resumed store holds duplicate rounds the uninterrupted run lacks)."""
        cache_path = tmp_path / "c.json"
        state = str(tmp_path / "c.state.json")
        with pytest.raises(SimulatedCrash):
            TuningSession(cache=str(cache_path), config=QUICK, state=state,
                          die_after=1).run(kernels=[RMS], suite="smoke")
        partial = json.loads(cache_path.read_text())
        assert partial                     # torn: entries exist pre-resume
        resumed = TuningSession(cache=str(cache_path), config=QUICK,
                                state=state)
        runs = resumed.run(kernels=[RMS], suite="smoke", resume=True)
        assert len(runs) == 1
        final = json.loads(cache_path.read_text())
        for key in final:                  # same rounds, not doubled ones
            assert len(final[key]) == len(partial[key])

    def test_quarantine_persists_into_journal(self, tmp_path):
        state_p = str(tmp_path / "s.state.json")
        st = SearchState(path=state_p)
        st.save_quarantine(RMS, "w", {"sig-of-known-bad"})
        sess = TuningSession(cache=str(tmp_path / "c.json"), config=QUICK,
                             state=st)
        assert sess.state.quarantine_for(RMS, "w") == {"sig-of-known-bad"}


class TestKeepGoing:
    def _registry_with_broken_kernel(self):
        reg = KernelRegistry()

        class _BoomSpec:
            name = "boom"
            module = "tests.boom"

            def workloads_in(self, suite):
                return (Workload("w", lambda rng: [], suites=(suite,)),)

            def instantiate(self, cache=None):
                raise RuntimeError("driver fell over")

        good = registry.spec(RMS)

        class _Reg:
            def names(self):
                return ["boom", RMS]

            def spec(self, name):
                return {"boom": _BoomSpec(), RMS: good}[name]

        return _Reg()

    def test_keep_going_records_failure_and_continues(self, tmp_path):
        state = str(tmp_path / "s.state.json")
        sess = TuningSession(cache=str(tmp_path / "c.json"), config=QUICK,
                             registry_=self._registry_with_broken_kernel(),
                             state=state, keep_going=True)
        runs = sess.run(suite="smoke")
        assert [r.kernel for r in runs] == [RMS]   # survivor still tuned
        assert sess.failures[0]["kernel"] == "boom"
        assert "driver fell over" in sess.failures[0]["error"]
        st = SearchState.load(state)
        assert st.failed[0]["kernel"] == "boom"
        assert st.in_progress is None

    def test_without_keep_going_failure_is_fatal(self, tmp_path):
        sess = TuningSession(cache=str(tmp_path / "c.json"), config=QUICK,
                             registry_=self._registry_with_broken_kernel())
        with pytest.raises(RuntimeError, match="driver fell over"):
            sess.run(suite="smoke")


class TestTuneCLIResume:
    def test_die_after_exit_code_then_resume_converges(self, tmp_path):
        from repro.launch import tune
        base = tmp_path / "base.json"
        assert tune.main(["--smoke", "--kernel", GEMM, "--kernel", RMS,
                          "--cache", str(base)]) == 0

        crashy = tmp_path / "crashy.json"
        argv = ["--smoke", "--kernel", GEMM, "--kernel", RMS,
                "--cache", str(crashy)]
        assert tune.main(argv + ["--die-after", "1"]) == \
            SimulatedCrash.EXIT_CODE
        assert tune.main(argv + ["--resume"]) == 0
        assert _cache_bytes(crashy) == _cache_bytes(base)
