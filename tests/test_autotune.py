"""repro.autotune: the always-on tuning service and its parts.

Distribution staleness math, cross-process stream tailing, history
warm-starts (with the legality property the service's safety depends on),
the promotion gate (margin, quarantine permanence — the acceptance
criterion), batch commits (one version bump), and the full
drain->tune->gate->promote->evict cycle against a real kernel.
"""

import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.autotune.gate import PromotionGate, incumbent_energy
from repro.autotune.history import (TuneHistory, feature_distance,
                                    features_of)
from repro.autotune.log import EventLog, load_events, validate_events
from repro.autotune.service import (AutotuneConfig, AutotuneService,
                                    WorkloadDistribution, _fast_tune_config,
                                    jsonl_source)
from repro.core.cache import PendingPut, ScheduleCache
from repro.core.registry import KernelSpec, Workload
from repro.core.schedule import KnobSpec, Schedule, SearchSpace
from repro.obs.recorder import WorkloadKey, tail_jsonl
from repro.tuning.state import SearchState

K1 = WorkloadKey(kind="prefill", prompt_len=16, batch=1, dtype="float32")
K2 = WorkloadKey(kind="prefill", prompt_len=8, batch=2, dtype="float32")


class TestWorkloadDistribution:
    def test_update_is_monotonic(self):
        """Re-delivery of an older cumulative snapshot never un-counts."""
        dist = WorkloadDistribution(half_life_s=10.0)
        dist.update({K1: (5, 2.0)})
        dist.update({K1: (3, 1.0)})          # stale: lower count, older t
        assert dist.weights(2.0)[K1] == pytest.approx(5.0)
        dist.update({K1: (9, 4.0)})
        assert dist.weights(4.0)[K1] == pytest.approx(9.0)

    def test_staleness_halves_per_half_life(self):
        dist = WorkloadDistribution(half_life_s=10.0)
        dist.update({K1: (8, 0.0), K2: (8, 10.0)})
        w = dist.weights(10.0)               # K1 is one half-life stale
        assert w[K1] == pytest.approx(4.0)
        assert w[K2] == pytest.approx(8.0)
        shares = dist.shares(10.0)
        assert sum(shares.values()) == pytest.approx(1.0)
        assert shares[K2] == pytest.approx(2 * shares[K1])

    def test_empty_shares(self):
        assert WorkloadDistribution().shares(0.0) == {}


class TestStreamTailing:
    def test_tail_leaves_partial_line(self, tmp_path):
        p = str(tmp_path / "mix.jsonl")
        full = json.dumps({"kind": "prefill", "t": 1.0}) + "\n"
        with open(p, "w") as f:
            f.write(full * 2 + '{"kind": "pre')     # torn mid-write
        recs, off = tail_jsonl(p, 0)
        assert len(recs) == 2 and off == 2 * len(full)
        with open(p, "a") as f:                      # writer finishes the line
            f.write('fill", "t": 2.0}\n')
        recs, off2 = tail_jsonl(p, off)
        assert len(recs) == 1 and recs[0]["t"] == 2.0
        assert tail_jsonl(p, off2) == ([], off2)     # drained

    def test_tail_missing_file_and_corrupt_line(self, tmp_path):
        assert tail_jsonl(str(tmp_path / "nope.jsonl"), 0) == ([], 0)
        p = str(tmp_path / "mix.jsonl")
        with open(p, "w") as f:
            f.write('not json\n' + json.dumps({"kind": "decode"}) + "\n")
        recs, _ = tail_jsonl(p, 0)
        assert [r["kind"] for r in recs] == ["decode"]

    def test_jsonl_source_accumulates_cumulative_snapshot(self, tmp_path):
        p = str(tmp_path / "mix.jsonl")
        src = jsonl_source(p)
        assert src() == ({}, 0.0)
        rec = {"kind": "prefill", "prompt_len": 16, "batch": 1,
               "dtype": "float32"}
        with open(p, "w") as f:
            f.write(json.dumps({**rec, "t": 1.0}) + "\n")
        snap, now = src()
        assert snap[K1] == (1, 1.0) and now == 1.0
        with open(p, "a") as f:
            f.write(json.dumps({**rec, "t": 3.0}) + "\n")
        snap, now = src()
        assert snap[K1] == (2, 3.0) and now == 3.0   # cumulative, not delta


SPACE = SearchSpace(knobs=(KnobSpec("bq", (4, 8)), KnobSpec("bk", (4, 8))))
FEATS_16 = features_of({"sq": 16, "dtype": "float32"})
FEATS_8 = features_of({"sq": 8, "dtype": "float32"})


def _hist_record(hist, *, sig="s16", feats=FEATS_16, knobs=None, order=None,
                 accepted=True, improvement=0.1):
    hist.record(kernel="k", signature=sig, workload="w",
                schedule=Schedule(knobs=knobs or {"bq": 8, "bk": 4},
                                  order=order),
                energy=1.0, improvement=improvement, accepted=accepted,
                features=feats)


class TestTuneHistory:
    def test_roundtrip_and_corrupt_degrade(self, tmp_path):
        p = str(tmp_path / "hist.json")
        hist = TuneHistory(p)
        _hist_record(hist)
        again = TuneHistory(p)
        assert len(again) == 1 and again.records[0].kernel == "k"
        with open(p, "w") as f:
            f.write("{broken")
        assert len(TuneHistory(p)) == 0              # loud would kill service

    def test_warm_start_exact_signature_keeps_order(self):
        hist = TuneHistory()
        _hist_record(hist, order=(1, 0, 2))
        got = hist.warm_start("k", "s16", SPACE, FEATS_16)
        assert got is not None and got.order == (1, 0, 2)

    def test_warm_start_neighbor_strips_order(self):
        """Orders index a specific program's instructions — a cross-shape
        recall must drop them or the target kernel would mis-apply it."""
        hist = TuneHistory()
        _hist_record(hist, sig="s16", feats=FEATS_16, order=(1, 0, 2))
        got = hist.warm_start("k", "s8", SPACE, FEATS_8)
        assert got is not None and got.order is None
        assert got.knobs == {"bq": 8, "bk": 4}       # knobs do transfer

    def test_warm_start_nearest_neighbor_wins(self):
        hist = TuneHistory()
        _hist_record(hist, sig="s16", feats=FEATS_16, knobs={"bq": 8})
        far = features_of({"sq": 4096, "dtype": "bfloat16"})
        _hist_record(hist, sig="sfar", feats=far, knobs={"bq": 4})
        got = hist.warm_start("k", "s8", SPACE, FEATS_8)
        assert got.knobs == {"bq": 8}                # s16 is nearer than sfar

    def test_warm_start_filters_illegal_and_unaccepted(self):
        hist = TuneHistory()
        _hist_record(hist, knobs={"bq": 999})            # not in SPACE
        _hist_record(hist, knobs={"bq": 4}, accepted=False)
        assert hist.warm_start("k", "s16", SPACE, FEATS_16) is None
        assert hist.warm_start("other", "s16", SPACE, FEATS_16) is None

    def test_greed_fits_per_kernel(self):
        hist = TuneHistory()
        for _ in range(8):
            _hist_record(hist, improvement=0.4)
        assert hist.greed_for("k") > 0.5             # wins -> greedier
        assert hist.greed_for("unseen", default=0.7) == 0.7

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_warm_start_is_always_legal_for_target_space(self, seed):
        """THE safety property: whatever junk history holds, a warm start is
        always a point of the TARGET kernel's knob space, and carries an
        instruction order only on an exact signature match."""
        rng = np.random.default_rng(seed)
        hist = TuneHistory()
        for i in range(int(rng.integers(1, 6))):
            knobs = {f"n{j}": int(rng.integers(0, 6))
                     for j in range(int(rng.integers(0, 4)))}
            order = (tuple(int(x) for x in rng.permutation(3))
                     if rng.random() < 0.5 else None)
            _hist_record(hist, sig=f"s{int(rng.integers(0, 3))}",
                         feats={"x": float(rng.random())}, knobs=knobs,
                         order=order, accepted=bool(rng.random() < 0.8))
        target = SearchSpace(knobs=tuple(
            KnobSpec(f"n{j}", tuple(range(int(rng.integers(1, 5)))))
            for j in range(int(rng.integers(0, 4)))))
        sig = f"s{int(rng.integers(0, 3))}"
        got = hist.warm_start("k", sig, target, {"x": 0.5})
        if got is not None:
            assert target.contains(got.knobs)
            if got.order is not None:
                recs = [r for r in hist.records
                        if r.accepted and r.signature == sig]
                assert any(Schedule.from_json(r.schedule_json).order
                           == got.order for r in recs)


def _fake_spec(name="fake_id"):
    """Identity kernel whose schedule can be wrong on purpose: the bad=1
    knob adds 1.0, so verification against the identity oracle fails."""
    space = SearchSpace(knobs=(KnobSpec("bad", (0, 1)),))
    def build(schedule, **static):
        off = float(schedule.knobs.get("bad", 0))
        return lambda x: np.asarray(x) + off
    return KernelSpec(name=name, build=build,
                      program_for=lambda s, **st_: None,
                      space_for=lambda **st_: space,
                      oracle=lambda x: np.asarray(x),
                      signature_fn=lambda x: {"n": int(np.asarray(x).shape[0])})


WL = Workload(name="w",
              make_args=lambda rng: [rng.standard_normal(8).astype(np.float32)],
              suites=("live",))


class TestPromotionGate:
    def test_untuned_key_promotes_on_verify(self):
        gate = PromotionGate(ScheduleCache(), samples=4)
        d = gate.evaluate(_fake_spec(), WL, "sig", Schedule(knobs={"bad": 0}),
                          1.0)
        assert d.promoted and d.reason == "promoted" and d.samples == 4
        assert d.incumbent_energy is None

    def test_margin_vs_incumbent(self):
        live = ScheduleCache()
        live.put("fake_id", "sig", Schedule(knobs={"bad": 0}), 1.0,
                 tests_passed=True)
        assert incumbent_energy(live, "fake_id", "sig") == 1.0
        gate = PromotionGate(live, margin=0.05, samples=2)
        close = gate.evaluate(_fake_spec(), WL, "sig",
                              Schedule(knobs={"bad": 0}), 0.97)
        assert not close.promoted and close.reason == "insufficient_margin"
        clear = gate.evaluate(_fake_spec(), WL, "sig",
                              Schedule(knobs={"bad": 0}), 0.90)
        assert clear.promoted

    def test_failing_schedule_quarantined_and_never_promoted(self, tmp_path):
        """Acceptance: a wrong-output candidate is quarantined, journaled,
        and permanently blocked — even across a state reload, and even if it
        later shows up with a winning energy."""
        state = SearchState(path=str(tmp_path / "state.json"))
        live = ScheduleCache()
        gate = PromotionGate(live, samples=4, state=state)
        bad = Schedule(knobs={"bad": 1})
        d1 = gate.evaluate(_fake_spec(), WL, "sig", bad, 1e-9)
        assert not d1.promoted and d1.reason == "verify_failed"
        assert d1.max_err >= 1.0
        assert live.version == 0                     # gate never touches live
        # quarantine is now permanent: no second verification run
        d2 = gate.evaluate(_fake_spec(), WL, "sig", bad, 1e-12)
        assert not d2.promoted and d2.reason == "quarantined_prior"
        reloaded = SearchState.load(str(tmp_path / "state.json"))
        gate2 = PromotionGate(live, samples=4, state=reloaded)
        d3 = gate2.evaluate(_fake_spec(), WL, "sig", bad, 1e-12)
        assert not d3.promoted and d3.reason == "quarantined_prior"
        assert incumbent_energy(live, "fake_id", "sig") is None


class TestBatchCommit:
    def test_commit_bumps_version_once(self, tmp_path):
        cache = ScheduleCache(str(tmp_path / "c.json"))
        v0 = cache.version
        cache.commit([PendingPut(kernel_name="k", signature=f"s{i}",
                                 schedule=Schedule(), energy=1.0,
                                 tests_passed=True) for i in range(3)])
        assert cache.version == v0 + 1
        assert not cache.changed_since(cache.version)
        assert cache.changed_since(v0)
        assert len(ScheduleCache(str(tmp_path / "c.json"))._data) == 3

    def test_empty_commit_is_a_noop(self):
        cache = ScheduleCache()
        v0 = cache.version
        cache.commit([])
        assert cache.version == v0 and not cache.changed_since(v0)


# ---------------------------------------------------------------- e2e cycle
ATTN = dict(b=1, hq=2, hkv=2, s=16, d=8)


def _attn_target(key):
    from repro.autotune.adapters import TuneTarget, _attn_args
    from repro.kernels.flash_attention import ops as fa_ops
    name = fa_ops.ensure_registered(causal=True, window=None)
    return TuneTarget(name, Workload(
        name=key.name,
        make_args=_attn_args(key.batch, ATTN["hq"], ATTN["hkv"],
                             key.prompt_len, ATTN["d"], key.dtype),
        suites=("live",)))


def _service(live, source, **over):
    history = over.pop("history", None)
    cfg = AutotuneConfig(budget=over.pop("budget", 2), samples=2,
                         interval_s=1.0, share_floor=0.2,
                         tune=_fast_tune_config(), **over)
    return AutotuneService(live, source=source, target_for=_attn_target,
                           config=cfg, history=history)


class TestServiceCycle:
    def test_full_cycle_promotes_with_one_version_bump(self):
        keys = {K1: (10, 1.0), K2: (6, 1.0)}
        svc = _service(ScheduleCache(), lambda: (keys, 2.0))
        v0 = svc.live.version
        summary = svc.run_once()
        assert summary["tuned"] == 2 and summary["promoted"] == 2
        # both promotions landed in ONE commit -> ONE engine re-trace
        assert svc.live.version == v0 + 1
        for key in (K1, K2):
            kernel, sig = svc._promoted[key]
            assert svc.live.best(kernel, sig) is not None
        assert svc.metrics()["promotions"] == 2
        assert validate_events(svc.log.events) == []
        kinds = [e["kind"] for e in svc.log.events]
        assert kinds.count("tuned") == 2 and kinds[-1] == "cycle"
        assert len(svc.history) == 2                 # both gated runs journal

    def test_eviction_below_share_floor(self):
        feed = {"now": 2.0, "keys": {K1: (10, 1.0), K2: (10, 1.0)}}
        svc = _service(ScheduleCache(),
                       lambda: (feed["keys"], feed["now"]), budget=2)
        svc.run_once()
        assert len(svc._promoted) == 2
        # K2 goes quiet for many half-lives; K1 keeps firing
        feed["keys"] = {K1: (500, 5000.0), K2: (10, 1.0)}
        feed["now"] = 5000.0
        summary = svc.run_once()
        assert summary["evicted"] == 1
        assert K2 not in svc._promoted and K1 in svc._promoted
        kernel, sig = svc._promoted[K1]
        assert svc.live.best(kernel, sig) is not None
        assert svc.metrics()["evictions"] == 1
        assert any(e["kind"] == "evicted" for e in svc.log.events)

    def test_warm_start_hits_across_services(self, tmp_path):
        hist = TuneHistory(str(tmp_path / "hist.json"))
        svc1 = _service(ScheduleCache(), lambda: ({K1: (10, 1.0)}, 2.0),
                        budget=1, history=hist)
        svc1.run_once()
        assert svc1.metrics()["warm_start_hits"] == 0
        # a fresh service (new session) over the SAME history warm-starts
        svc2 = _service(ScheduleCache(),
                        lambda: ({K1: (10, 1.0)}, 2.0), budget=1,
                        history=TuneHistory(str(tmp_path / "hist.json")))
        svc2.run_once()
        assert svc2.metrics()["warm_start_hits"] == 1
        assert any(e["kind"] == "warm_start" for e in svc2.log.events)

    def test_unmappable_keys_skipped_once(self):
        sub = WorkloadKey(kind="submit", prompt_len=0, batch=1, dtype="int32")
        calls = []
        def target_for(key):
            calls.append(key)
            return None
        svc = AutotuneService(
            ScheduleCache(), source=lambda: ({sub: (5, 1.0)}, 2.0),
            target_for=target_for,
            config=AutotuneConfig(samples=2, tune=_fast_tune_config()))
        assert svc.run_once()["candidates"] == 0
        assert svc.run_once()["candidates"] == 0
        assert calls == [sub]                        # never re-asked

    def test_event_log_journal_roundtrip(self, tmp_path):
        p = str(tmp_path / "events.jsonl")
        with EventLog(p) as log:
            log.emit("cycle", cycle=1, candidates=0, tuned=0, promoted=0,
                     quarantined=0)
            with pytest.raises(ValueError, match="unknown autotune event"):
                log.emit("nonsense")
        events = load_events(p)
        assert validate_events(events) == []
        assert validate_events([{"kind": "promoted", "t": 1.0}]) != []
