"""Fault-tolerance layer: chaos harness, supervisor recovery, and the
chaos-differential gate (an injected failure must not change what the model
learns — kill→restart runs finish with the uninterrupted final loss)."""

import dataclasses
import functools

import numpy as np
import pytest

from repro.data.pipeline import DataConfig
from repro.ft import (Action, ChaosEngine, Fault, FaultPlan, FTConfig,
                      FTManager, NonFiniteLossError, ReshapeRequired,
                      RestartBudgetExhausted, RestartRequired, Supervisor,
                      SupervisorConfig, WorkerKilled)
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train.loop import TrainConfig, train

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                   dtype="float32")


def _cfgs(tmp_path, steps=10, ckpt_every=4):
    dcfg = DataConfig(global_batch=2, seq_len=16, vocab=TINY.vocab)
    tcfg = TrainConfig(total_steps=steps, ckpt_every=ckpt_every,
                      ckpt_dir=str(tmp_path), log_every=1000)
    ocfg = adamw.OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=steps)
    return dcfg, tcfg, ocfg


class TestFaultPlan:
    def test_parse_grammar(self):
        plan = FaultPlan.parse(
            "crash@7, kill@10:w2:perm, straggle@3:w1:x4:d5, "
            "nan@12:sticky, corrupt@5:bitflip")
        kinds = [f.kind for f in plan]
        assert kinds == ["crash", "kill", "straggle", "nan", "corrupt"]
        crash, kill, strag, nan, corrupt = plan.faults
        assert crash.step == 7
        assert (kill.worker, kill.permanent) == (2, True)
        assert (strag.worker, strag.factor, strag.duration) == (1, 4.0, 5)
        assert nan.sticky
        assert corrupt.mode == "bitflip"

    def test_spec_roundtrip(self):
        spec = "crash@7,kill@10:w2:perm,straggle@3:w1:x4:d5,nan@12:sticky," \
               "corrupt@5:bitflip"
        plan = FaultPlan.parse(spec)
        assert FaultPlan.parse(plan.to_spec()) == plan

    def test_parse_errors(self):
        with pytest.raises(ValueError, match="missing '@step'"):
            FaultPlan.parse("crash")
        with pytest.raises(ValueError, match="not an int"):
            FaultPlan.parse("crash@soon")
        with pytest.raises(ValueError, match="unknown option"):
            FaultPlan.parse("crash@3:q9")
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultPlan.parse("meteor@3")
        with pytest.raises(ValueError, match="empty fault spec"):
            FaultPlan.parse(" , ")
        with pytest.raises(ValueError, match="total_steps"):
            FaultPlan.parse("random:3")

    def test_random_is_deterministic(self):
        a = FaultPlan.random(7, total_steps=100, n_workers=4)
        b = FaultPlan.random(7, total_steps=100, n_workers=4)
        assert a == b
        assert a != FaultPlan.random(8, total_steps=100, n_workers=4)
        assert all(0 < f.step < 100 for f in a)
        # the CLI spelling resolves to the same plan
        assert FaultPlan.parse("random:7", n_workers=4, total_steps=100) == a

    def test_fault_validation(self):
        with pytest.raises(ValueError, match="unknown corrupt mode"):
            Fault(kind="corrupt", step=3, mode="gently")
        with pytest.raises(ValueError, match=">= 0"):
            Fault(kind="crash", step=-1)


class TestChaosEngine:
    def test_crash_fires_exactly_once(self):
        eng = ChaosEngine(FaultPlan.parse("crash@5"))
        for s in range(5):
            eng.on_step_start(s)
        with pytest.raises(WorkerKilled) as ei:
            eng.on_step_start(5)
        assert ei.value.step == 5
        eng.on_attempt_start()              # supervisor relaunches
        eng.on_step_start(5)                # replayed step: no re-kill
        assert len(eng.events) == 1

    def test_transient_kill_rejoins_permanent_does_not(self):
        eng = ChaosEngine(FaultPlan.parse("kill@2:w1,kill@3:w2:perm"))
        for s in range(4):
            eng.on_step_start(s)
        assert eng.heartbeat_suppressed(1) and eng.heartbeat_suppressed(2)
        eng.on_attempt_start()
        assert not eng.heartbeat_suppressed(1)      # transient came back
        assert eng.heartbeat_suppressed(2)          # permanent did not

    def test_straggler_window(self):
        eng = ChaosEngine(FaultPlan.parse("straggle@4:w1:x3:d2"))
        assert eng.latency_factor(1, 3) == 1.0
        assert eng.latency_factor(1, 4) == 3.0
        assert eng.latency_factor(1, 5) == 3.0
        assert eng.latency_factor(1, 6) == 1.0      # window closed
        assert eng.latency_factor(0, 4) == 1.0      # other workers untouched

    def test_oneshot_nan_fires_once(self):
        eng = ChaosEngine(FaultPlan.parse("nan@3"))
        assert np.isnan(eng.filter_loss(3, 1.0))
        assert eng.filter_loss(3, 1.0) == 1.0       # replay after rollback

    def test_sticky_nan_keyed_to_original_batch(self):
        """A sticky nan models a genuinely bad batch: it re-fires whenever
        step N's original batch is used, and only the supervisor's
        skip-window substitution makes progress possible."""
        eng = ChaosEngine(FaultPlan.parse("nan@3:sticky"))
        assert np.isnan(eng.filter_loss(3, 1.0))
        assert np.isnan(eng.filter_loss(3, 1.0))            # still bad
        assert eng.filter_loss(3, 1.0, substituted=True) == 1.0

    def test_corrupt_targets_first_ckpt_at_or_after_step(self, tmp_path):
        import jax.numpy as jnp
        from repro.checkpoint.ckpt import CheckpointManager
        mgr = CheckpointManager(str(tmp_path), keep=5)
        mgr.save(4, {"w": jnp.arange(8.0)})
        eng = ChaosEngine(FaultPlan.parse("corrupt@3"))
        assert not eng.wants_corrupt(2)
        assert eng.wants_corrupt(4)
        eng.corrupt_checkpoint(str(tmp_path), 4)
        assert not mgr.verify(4)
        assert not eng.wants_corrupt(8)             # fired once


class TestFTManagerConfig:
    def test_default_config_not_shared(self):
        """Regression: ``cfg: FTConfig = FTConfig()`` in the signature made
        every default-constructed manager share ONE mutable config — tuning
        a knob on one silently retuned all of them."""
        a, b = FTManager(n_workers=2), FTManager(n_workers=2)
        assert a.cfg is not b.cfg
        a.cfg.heartbeat_timeout_s = 1e-9
        assert b.cfg.heartbeat_timeout_s == FTConfig().heartbeat_timeout_s

    def test_refresh_resets_liveness_not_restarts(self):
        t = [0.0]
        ft = FTManager(n_workers=2, cfg=FTConfig(heartbeat_timeout_s=5.0),
                       clock=lambda: t[0])
        ft.heartbeat(0, 0.1)
        ft.heartbeat(1, 0.1)
        t[0] = 100.0                       # supervisor backoff elapsed
        ft.refresh()
        action, _ = ft.decide()
        assert action is Action.CONTINUE   # a pause is not a death


class _FlakyTrain:
    """A train_fn that raises a scripted failure per attempt, recording the
    (mesh, skip_data_steps) each attempt received."""

    def __init__(self, failures):
        self.failures = list(failures)
        self.calls = []

    def __call__(self, *, mesh=None, skip_data_steps=frozenset()):
        self.calls.append({"mesh": mesh, "skip": set(skip_data_steps)})
        if self.failures:
            raise self.failures.pop(0)
        return {"final_loss": 1.0, "step": 10, "history": []}


class TestSupervisor:
    def _sup(self, fn, **kw):
        sleeps = []
        kw.setdefault("cfg", SupervisorConfig(max_restarts=4,
                                              backoff_base_s=0.1,
                                              backoff_max_s=0.4))
        sup = Supervisor(fn, sleep=sleeps.append, **kw)
        return sup, sleeps

    def test_restart_until_success_with_bounded_backoff(self):
        fn = _FlakyTrain([WorkerKilled("w0", step=3),
                          RestartRequired("w1", step=5),
                          WorkerKilled("w0", step=7)])
        sup, sleeps = self._sup(fn)
        res = sup.run()
        assert res["supervisor"]["attempts"] == 4
        assert [e["kind"] for e in res["supervisor"]["events"]] == \
            ["restart"] * 3
        assert sleeps == [0.1, 0.2, 0.4]            # capped at backoff_max_s

    def test_nan_rollback_widens_skip_window(self):
        fn = _FlakyTrain([NonFiniteLossError(6, float("nan"))])
        sup, _ = self._sup(fn, cfg=SupervisorConfig(nan_skip_window=2))
        res = sup.run()
        assert fn.calls[0]["skip"] == set()
        assert fn.calls[1]["skip"] == {6, 7}
        assert res["supervisor"]["skip_data_steps"] == [6, 7]

    def test_reshape_rebuilds_mesh_from_factory(self):
        target = ((2, 2), ("data", "model"))
        fn = _FlakyTrain([ReshapeRequired("lost", target=target, step=4)])
        built = []

        def factory(t):
            built.append(t)
            return f"mesh{t[0]}"

        sup, _ = self._sup(fn, mesh_factory=factory, mesh="mesh-big")
        res = sup.run()
        assert built == [target]
        assert fn.calls[0]["mesh"] == "mesh-big"
        assert fn.calls[1]["mesh"] == "mesh(2, 2)"
        assert [e["kind"] for e in res["supervisor"]["events"]] == \
            ["elastic_reshape"]

    def test_budget_exhausted_raises(self):
        fn = _FlakyTrain([WorkerKilled("again", step=1)] * 99)
        sup, _ = self._sup(fn)
        with pytest.raises(RestartBudgetExhausted, match="4 restarts"):
            sup.run()

    def test_chaos_and_ft_reset_per_attempt(self):
        eng = ChaosEngine(FaultPlan.parse("kill@1:w1"))
        eng.on_step_start(1)                        # worker 1 suppressed
        t = [0.0]
        ft = FTManager(n_workers=2, cfg=FTConfig(heartbeat_timeout_s=5.0),
                       clock=lambda: t[0])
        ft.heartbeat(0, 0.1)
        t[0] = 50.0
        fn = _FlakyTrain([])
        sup, _ = self._sup(fn, ft=ft, chaos=eng)
        sup.run()
        assert not eng.heartbeat_suppressed(1)      # transient kill rejoined
        assert ft.decide()[0] is Action.CONTINUE    # refresh() reset liveness


class TestChaosDifferential:
    """The robustness acceptance gate: recovery must reproduce the
    uninterrupted run, not merely survive."""

    def test_crash_and_corrupt_recover_bit_identically(self, tmp_path):
        dcfg, tcfg0, ocfg = _cfgs(tmp_path / "base", steps=10)
        base = train(TINY, dcfg, tcfg0, ocfg)

        _, tcfg, _ = _cfgs(tmp_path / "chaos", steps=10)
        chaos = ChaosEngine(FaultPlan.parse("corrupt@4,crash@6"))
        ft = FTManager(n_workers=1)
        sup = Supervisor(
            functools.partial(train, TINY, dcfg, tcfg, ocfg, ft=ft,
                              chaos=chaos),
            ft=ft, chaos=chaos, sleep=lambda s: None)
        res = sup.run()
        # crash at 6 restarted; ckpt 4 was corrupted so the restart fell
        # back further — yet replayed data gives the exact same trajectory
        assert res["supervisor"]["attempts"] >= 2
        assert res["step"] == 10
        assert res["final_loss"] == base["final_loss"]
        assert [m["loss"] for m in res["history"][-3:]] == \
            [m["loss"] for m in base["history"][-3:]]

    def test_sticky_nan_needs_skip_window_to_finish(self, tmp_path):
        dcfg, tcfg, ocfg = _cfgs(tmp_path, steps=8, ckpt_every=3)
        chaos = ChaosEngine(FaultPlan.parse("nan@4:sticky"))
        ft = FTManager(n_workers=1)
        sup = Supervisor(
            functools.partial(train, TINY, dcfg, tcfg, ocfg, ft=ft,
                              chaos=chaos),
            ft=ft, chaos=chaos, sleep=lambda s: None)
        res = sup.run()
        assert res["step"] == 8
        assert np.isfinite(res["final_loss"])
        assert res["supervisor"]["skip_data_steps"] == [4]
        kinds = [e["kind"] for e in res["supervisor"]["events"]]
        assert "nonfinite_rollback" in kinds

    def test_worker_death_triggers_restart_via_ft(self, tmp_path):
        """kill@N suppresses heartbeats; the FT manager (not chaos itself)
        must notice and order a restart — exercising the real decide() path."""
        dcfg, tcfg, ocfg = _cfgs(tmp_path, steps=8, ckpt_every=3)
        chaos = ChaosEngine(FaultPlan.parse("kill@4:w1"))
        t = [0.0]
        ft = FTManager(n_workers=2, cfg=FTConfig(heartbeat_timeout_s=0.5,
                                                 chips_per_worker=1),
                       clock=lambda: t[0])
        orig = ft.heartbeat

        def ticking_heartbeat(w, lat):
            t[0] += 0.3                    # decide() sees w1 time out fast
            orig(w, lat)

        ft.heartbeat = ticking_heartbeat
        sup = Supervisor(
            functools.partial(train, TINY, dcfg, tcfg, ocfg, ft=ft,
                              chaos=chaos),
            ft=ft, chaos=chaos, sleep=lambda s: None)
        res = sup.run()
        assert res["step"] == 8
        assert any(e["kind"] == "restart"
                   for e in res["supervisor"]["events"])


class TestTrainLoopKnobs:
    def test_log_history_bounds_returned_history(self, tmp_path):
        dcfg, tcfg, ocfg = _cfgs(tmp_path, steps=6, ckpt_every=100)
        tcfg = dataclasses.replace(tcfg, log_history=2)
        res = train(TINY, dcfg, tcfg, ocfg)
        assert len(res["history"]) == 2
        assert np.isfinite(res["final_loss"])

    def test_launch_train_cli_supervised_chaos(self, tmp_path, monkeypatch):
        from repro.launch import train as train_cli
        monkeypatch.setattr(train_cli.configs, "arch_names", lambda: ["tiny"])
        monkeypatch.setattr(train_cli.configs, "get_smoke", lambda n: TINY)
        rc = train_cli.main([
            "--arch", "tiny", "--smoke", "--steps", "6", "--batch", "2",
            "--seq", "16", "--ckpt-dir", str(tmp_path / "c"),
            "--ckpt-every", "3", "--chaos", "crash@3,corrupt@3",
            "--backoff-base", "0"])
        assert rc == 0
